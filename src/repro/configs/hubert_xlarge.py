"""hubert-xlarge [audio] — arXiv:2106.07447 (unverified tier).

48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 — encoder-only masked
prediction over codebook targets; the CNN waveform frontend is a stub per
the assignment (input_specs provides precomputed frame embeddings).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    encoder_only=True,
    frontend="audio",
    act="gelu",
    gated_ffn=False,
)
