"""Architecture registry: ``--arch <id>`` resolution."""
from repro.models.config import SHAPE_BY_NAME, SHAPES, ArchConfig, ShapeCfg

from . import (deepseek_v3_671b, granite_34b, hubert_xlarge, mamba2_2_7b,
               minicpm_2b, moonshot_v1_16b_a3b, nemotron_4_15b, qwen1_5_110b,
               qwen2_vl_72b, zamba2_2_7b)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (deepseek_v3_671b, moonshot_v1_16b_a3b, granite_34b,
              nemotron_4_15b, qwen1_5_110b, minicpm_2b, qwen2_vl_72b,
              mamba2_2_7b, zamba2_2_7b, hubert_xlarge)
}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[: -len("-smoke")]].smoke()
    return ARCHS[name]
