"""granite-34b [dense] — arXiv:2405.04324 (hf-verified).

88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152 — code model.
Non-gated GELU MLP (the published 34B total only reconciles with the
GPTBigCode-style 2·d·d_ff MLP, not a gated SwiGLU); MQA per the assignment.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    act="gelu",
    gated_ffn=False,
    rope_theta=1e4,
)
