"""qwen2-vl-72b [vlm] — arXiv:2409.12191 (hf-verified).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE (sectioned
t/h/w rotary), dynamic resolution.  Backbone only: the vision frontend is a
stub per the assignment (input_specs provides precomputed patch embeddings).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    mrope=True,
    frontend="vision",
    rope_theta=1e6,
)
