"""zamba2-2.7b [hybrid] — arXiv:2411.15242 (hf-verified).

54L d_model=2560 (Mamba2 blocks) + shared attention block (32H, kv 32,
MLP d_ff=10240) applied every 6 SSM layers with shared weights;
ssm_state=64, vocab=32000.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    attn_every=6,
    shared_attn_d_ff=10240,
)
