"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B (hf-verified).

48L d_model=2048 16H (GQA kv=16) d_ff=1408(expert) vocab=163840,
MoE 64e top-6 + 2 shared experts, first layer dense (d_ff 11264).

Note: the assignment pins 48 layers (the HF checkpoint has 27); we follow
the assignment, which yields 28.4B total / 4.8B active params.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=11264,               # dense first layer
    vocab=163840,
    n_experts=64,
    experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=5e4,
)
