"""minicpm-2b [dense] — arXiv:2404.06395 (hf-verified).

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753 — llama-like with
depth-scaled residuals; WSD LR schedule implemented in repro.optim.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    residual_scale=1.4 / (40 ** 0.5),   # scale_depth / sqrt(L)
    rope_theta=1e4,
)
