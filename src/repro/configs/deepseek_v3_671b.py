"""deepseek-v3-671b [moe] — arXiv:2412.19437 (hf-verified).

61L d_model=7168 128H d_ff=2048(expert) vocab=129280, MoE 256e top-8,
1 shared expert, MLA (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 /
v_head 128), 3 leading dense layers (dense d_ff 18432), MTP depth 1.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,               # dense layers (first 3)
    vocab=129280,
    n_experts=256,
    experts_per_token=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp=True,
    rope_theta=1e4,
)
