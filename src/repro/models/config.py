"""Architecture configuration system.

One ``ArchConfig`` per assigned architecture (exact public-literature
numbers live in ``repro.configs.<id>``), plus reduced smoke variants and the
four input-shape cells each architecture pairs with.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention details
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    mrope: bool = False         # Qwen2-VL sectioned (t,h,w) RoPE
    encoder_only: bool = False

    # activation / ffn
    act: str = "silu"           # silu (gated) | relu2 (non-gated) | gelu
    gated_ffn: bool = True

    # residual scaling (MiniCPM depth-scaled residuals)
    residual_scale: float = 1.0

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # leading dense layers in MoE stacks

    # MLA (DeepSeek-V3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # Multi-token prediction (DeepSeek-V3 MTP)
    mtp: bool = False

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    attn_every: int = 0          # hybrid: shared attn block every k ssm layers
    shared_attn_d_ff: int = 0    # zamba2 shared block MLP width

    # modality frontend stub
    frontend: str = ""           # "" | "audio" | "vision"

    # numerics
    norm_eps: float = 1e-5

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (attention-free or hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embed
        if not self.encoder_only:
            total += d * v  # lm head
        for layer in range(self.n_layers):
            total += self._layer_params(layer)
        if self.family == "hybrid" and self.attn_every:
            total += self._attn_params() + 2 * d * self.shared_attn_d_ff
        if self.mtp:
            total += self._layer_params(self.n_layers - 1) + 2 * d * d
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla:
            q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.qk_rope_dim)
            kv = d * (self.kv_lora_rank + self.qk_rope_dim) \
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim
                                                      + self.v_head_dim)
            o = self.n_heads * self.v_head_dim * d
            return q + kv + o
        hd = self.hd
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d

    def _ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.gated_ffn else 2
        return mult * self.d_model * d_ff

    def _ssm_params(self) -> int:
        d, di, ds = self.d_model, self.d_inner, self.ssm_state
        n_groups = 1
        in_proj = d * (2 * di + 2 * n_groups * ds + self.ssm_heads)
        conv = 4 * (di + 2 * n_groups * ds)
        extra = 3 * self.ssm_heads  # A_log, D, dt_bias
        out = di * d
        return in_proj + conv + extra + out + di

    def _layer_params(self, layer: int) -> int:
        if self.family == "ssm":
            return self._ssm_params()
        if self.family == "hybrid":
            return self._ssm_params()
        ffn = (self._ffn_params(self.d_ff)
               if (not self.is_moe or layer < self.first_dense_layers)
               else (self.n_experts + self.n_shared_experts)
               * self._ffn_params(self.moe_d_ff) // 1)
        return self._attn_params() + ffn

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-to experts count)."""
        if not self.is_moe:
            return self.param_count()
        d, v = self.d_model, self.vocab
        total = v * d + d * v
        for layer in range(self.n_layers):
            if layer < self.first_dense_layers:
                ffn = self._ffn_params(self.d_ff)
            else:
                ffn = (self.experts_per_token + self.n_shared_experts) \
                    * self._ffn_params(self.moe_d_ff)
            total += self._attn_params() + ffn
        return total

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(4, self.n_kv_heads if self.n_kv_heads else 4)),
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=8 if self.is_moe else 0,
            experts_per_token=2 if self.is_moe else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_d_ff=32 if self.is_moe else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            q_lora_rank=32 if self.mla else 0,
            kv_lora_rank=16 if self.mla else 0,
            qk_nope_dim=16 if self.mla else 0,
            qk_rope_dim=8 if self.mla else 0,
            v_head_dim=16 if self.mla else 0,
            ssm_state=16 if self.is_ssm else 0,
            ssm_head_dim=16 if self.is_ssm else 64,
            ssm_chunk=16 if self.is_ssm else 128,
            attn_every=2 if self.family == "hybrid" else 0,
            shared_attn_d_ff=128 if self.family == "hybrid" else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCfg, ...] = (
    ShapeCfg("train_4k", 4_096, 256, "train"),
    ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    ShapeCfg("decode_32k", 32_768, 128, "decode"),
    ShapeCfg("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_applicable(cfg: ArchConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """The assignment's own skip rules (documented in DESIGN.md §5)."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention"
    return True, ""
