"""Transformer building blocks: norms, RoPE/M-RoPE, flash-style attention
(GQA + MLA), FFN variants, dropless MoE.

Design constraints (see DESIGN.md §6):
  * every model body is a ``lax.scan`` over stacked layer params — O(1) HLO
  * attention streams over KV chunks with online softmax so the 32k/500k
    shape cells never materialize an S×S score matrix
  * MoE uses sort + ``lax.ragged_dot`` (dropless, TPU-native)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig

Array = jnp.ndarray

# flash-attention KV streaming chunk (perf lever: larger chunks rewrite the
# f32 online-softmax accumulators fewer times; VMEM/temp grows with chunk)
DEFAULT_KV_CHUNK = 1024


def set_kv_chunk(n: int) -> None:
    global DEFAULT_KV_CHUNK
    DEFAULT_KV_CHUNK = int(n)


# ---------------------------------------------------------------------------
# norms & misc
# ---------------------------------------------------------------------------
def rms_norm(x: Array, w: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


# ---------------------------------------------------------------------------
# RoPE (+ sectioned M-RoPE for Qwen2-VL)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, pos: Array, theta: float) -> Array:
    """x: [..., S, H, D]; pos: broadcastable to [..., S]."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    ang = pos[..., None].astype(jnp.float32) * inv          # [..., S, D/2]
    ang = ang[..., None, :]                                  # head axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, pos3: Array, theta: float,
                sections=(16, 24, 24)) -> Array:
    """Qwen2-VL M-RoPE: rotary pairs split into (t, h, w) sections.

    x: [B, S, H, D]; pos3: [B, 3, S] position ids per section.
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                               # [D/2]
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])[: d // 2]
    # pick, per rotary pair, the section's position id
    pos = jnp.take_along_axis(
        pos3.astype(jnp.float32),                            # [B, 3, S]
        jnp.broadcast_to(sec[None, :, None],
                         (x.shape[0], d // 2, x.shape[1])).astype(jnp.int32),
        axis=1)                                              # [B, D/2, S]
    ang = pos.transpose(0, 2, 1)[..., None, :] * inv[None, None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# flash-style attention (no S×S materialization)
# ---------------------------------------------------------------------------
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool,
                    q_offset: int | Array = 0, kv_len: Optional[Array] = None,
                    kv_chunk: Optional[int] = None) -> Array:
    """Online-softmax attention streaming over KV chunks.

    q: [B, Sq, H, D]; k/v: [B, Sk, Hkv, D] (GQA: H % Hkv == 0).
    ``q_offset``: absolute position of q[0] (decode / chunked prefill).
    ``kv_len``: effective kv length (decode with preallocated cache).
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = h // hkv
    scale = 1.0 / math.sqrt(d)
    kv_chunk = DEFAULT_KV_CHUNK if kv_chunk is None else kv_chunk
    nchunks = max(1, -(-sk // kv_chunk))
    ck = min(kv_chunk, sk)
    pad = nchunks * ck - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(b, nchunks, ck, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nchunks, ck, hkv, dv), 1, 0)

    # GQA grouping: q [B, Sq, G, R, D] so shared KV heads are never repeated
    qg = (q * scale).astype(jnp.float32).reshape(b, sq, hkv, rep, d)
    qpos = jnp.asarray(q_offset) + jnp.arange(sq)

    def step(carry, inputs):
        m, l, acc = carry                          # [B,G,R,Sq], ..,[...,dv]
        kj, vj, j = inputs
        kpos = j * ck + jnp.arange(ck)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kj.astype(jnp.float32))
        mask = jnp.ones((sq, ck), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        mask &= (kpos < sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, rep, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, rep, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)       # [B,G,R,Sq,dv]
    out = out.reshape(b, h, sq, dv).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)                         # [B, Sq, H, dv]


# ---------------------------------------------------------------------------
# GQA attention layer (optionally with KV cache)
# ---------------------------------------------------------------------------
def attn_forward(cfg: ArchConfig, p: dict, x: Array, pos: Array,
                 cache: Optional[dict] = None,
                 cache_pos: Optional[Array] = None,
                 pos3: Optional[Array] = None) -> Tuple[Array, Optional[dict]]:
    """x: [B, S, D].  With ``cache``, writes new kv at ``cache_pos`` and
    attends over the cache (decode / incremental prefill)."""
    b, s, d = x.shape
    hd, h, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.mrope and pos3 is not None:
        q = apply_mrope(q, pos3, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.rope_theta)
    elif not cfg.encoder_only:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)

    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
            cache["k"].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
            cache["v"].dtype), cache_pos, axis=1)
        o = flash_attention(q, ck, cv, causal=True, q_offset=cache_pos,
                            kv_len=cache_pos + s)
        cache = dict(k=ck, v=cv)
    else:
        o = flash_attention(q, k, v, causal=not cfg.encoder_only)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V3): low-rank q/kv with compressed KV cache
# ---------------------------------------------------------------------------
def mla_forward(cfg: ArchConfig, p: dict, x: Array, pos: Array,
                cache: Optional[dict] = None,
                cache_pos: Optional[Array] = None) -> Tuple[Array, Optional[dict]]:
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    # --- queries through the q-LoRA path
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_norm"],
                  cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])           # [B,S,H,dn+dr]
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    # --- compressed kv latent + shared rope key
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdkv"]), p["kv_norm"],
                   cfg.norm_eps)                             # [B,S,r]
    k_rope = apply_rope(jnp.einsum("bsd,dk->bsk", x, p["wkr"])[:, :, None, :],
                        pos, cfg.rope_theta)[:, :, 0]        # [B,S,dr]

    if cache is not None:
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_pos, axis=1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], k_rope.astype(cache["kr"].dtype), cache_pos, axis=1)
        # absorbed decode: score = q_nope·W_uk^T·ckv + q_rope·k_rope,
        # attention output stays in latent space, expanded once via W_uv.
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])
        q_eff = jnp.concatenate([q_lat, q_rope], -1)         # [B,S,H,r+dr]
        k_eff = jnp.concatenate(
            [ckv_c[:, :, None, :], kr_c[:, :, None, :]], -1)  # [B,S,1,r+dr]
        o_lat = flash_attention(q_eff, k_eff, ckv_c[:, :, None, :],
                                causal=True, q_offset=cache_pos,
                                kv_len=cache_pos + s)         # [B,S,H,r]
        o = jnp.einsum("bshr,rhk->bshk", o_lat, p["wuv"])     # [B,S,H,dv]
        cache = dict(ckv=ckv_c, kr=kr_c)
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"])
        v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, dr))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        o = flash_attention(q_full, k, v, causal=True)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, cache


# ---------------------------------------------------------------------------
# FFN + MoE
# ---------------------------------------------------------------------------
def ffn_forward(cfg: ArchConfig, p: dict, x: Array) -> Array:
    f = act_fn(cfg.act)
    if cfg.gated_ffn:
        return jnp.einsum(
            "bsf,fd->bsd",
            f(jnp.einsum("bsd,df->bsf", x, p["wg"]))
            * jnp.einsum("bsd,df->bsf", x, p["wu"]), p["wd"])
    return jnp.einsum("bsf,fd->bsd",
                      f(jnp.einsum("bsd,df->bsf", x, p["wu"])), p["wd"])


def moe_forward(cfg: ArchConfig, p: dict, x: Array) -> Array:
    """Dropless MoE: router top-k -> sort tokens by expert -> ragged_dot.

    x: [B, S, D].  Expert weights: [E, D, F] / [E, F, D] (+gate for swiglu).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    xt = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates, choice = jax.lax.top_k(jax.nn.sigmoid(logits), k)   # DSv3-style
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    t = b * s * k
    flat_exp = choice.reshape(t)
    flat_tok = jnp.repeat(jnp.arange(b * s), k)
    order = jnp.argsort(flat_exp)
    sort_exp = flat_exp[order]
    sort_tok = flat_tok[order]
    xs = xt[sort_tok]                                           # [T, D]
    group_sizes = jnp.bincount(sort_exp, length=e).astype(jnp.int32)

    f = act_fn(cfg.act)
    if cfg.gated_ffn:
        hg = jax.lax.ragged_dot(xs, p["wg"], group_sizes)
        hu = jax.lax.ragged_dot(xs, p["wu"], group_sizes)
        hidden = f(hg) * hu
    else:
        hidden = f(jax.lax.ragged_dot(xs, p["wu"], group_sizes))
    ys = jax.lax.ragged_dot(hidden, p["wd"], group_sizes)       # [T, D]

    gate_flat = gates.reshape(t)[order]
    out = jnp.zeros((b * s, d), ys.dtype).at[sort_tok].add(
        ys * gate_flat[:, None].astype(ys.dtype))

    if cfg.n_shared_experts:
        sh = dict(wg=p["shared_wg"], wu=p["shared_wu"], wd=p["shared_wd"]) \
            if cfg.gated_ffn else dict(wu=p["shared_wu"], wd=p["shared_wd"])
        out = out + ffn_forward(cfg, sh, x).reshape(b * s, d)
    return out.reshape(b, s, d).astype(x.dtype)
