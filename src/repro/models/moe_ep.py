"""Expert-parallel MoE via shard_map + all-to-all dispatch (GShard-style).

The pjit/GSPMD baseline cannot partition ``ragged_dot`` along the expert
axis — it all-gathers the expert weights per layer (measured: ~64 TB of
per-device traffic on deepseek-v3 train_4k; EXPERIMENTS.md §Perf).  This
module runs the routed-expert block in a manual shard_map region:

  1. route locally (top-k over sigmoid router scores)
  2. pack each token-choice into the send buffer of the shard owning the
     expert (static capacity, overflowing choices dropped + renormalized)
  3. ``all_to_all`` over the 'model' axis -> each shard receives the tokens
     for *its* E/n experts
  4. local sort-by-expert + ``ragged_dot`` (single device: no partitioning
     problem)
  5. ``all_to_all`` back, combine weighted by gates

Wire per layer ≈ 2 · T_local · k · D · 2 bytes (both directions), vs the
baseline's full expert-weight gather (3 · E · D · F · 2 bytes).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .config import ArchConfig
from .layers import act_fn, ffn_forward

CAPACITY_FACTOR = 1.25

# set by the launch layer when a mesh is active (None -> pjit fallback path)
_EP_MESH = None
_DP_AXES: Tuple[str, ...] = ("data",)
_TP_AXIS = "model"


def set_ep_mesh(mesh, dp_axes, tp_axis="model"):
    global _EP_MESH, _DP_AXES, _TP_AXIS
    _EP_MESH = mesh
    _DP_AXES = tuple(dp_axes)
    _TP_AXIS = tp_axis


def get_ep_mesh():
    return _EP_MESH


def ep_axes(mesh, n_experts: int) -> Tuple[str, ...]:
    """Mesh axes carrying expert parallelism: the largest suffix of
    (pod, data, model) whose size divides n_experts.  DeepSeek-V3's 256
    experts on a 256-chip pod -> one expert per device: no expert-weight
    gathers at all, and ragged_dot's dense weight-grad cost divides by the
    per-device expert count."""
    axes = list(mesh.axis_names)
    while axes:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if n_experts % size == 0:
            return tuple(axes)
        axes.pop(0)  # drop pod, then data — model stays innermost
    return ()


def _local_expert_block(cfg: ArchConfig, recv_x, recv_eid, recv_valid,
                        wg, wu, wd):
    """Compute local experts for received tokens.  recv_x: [R, D]."""
    r = recv_x.shape[0]
    e_loc = wu.shape[0]
    eid = jnp.where(recv_valid, recv_eid, e_loc)  # invalid -> pad group
    order = jnp.argsort(eid)
    xs = jnp.take(recv_x, order, axis=0)
    gsz = jnp.bincount(eid[order], length=e_loc + 1).astype(jnp.int32)[:e_loc]
    # pad group absorbs the tail rows automatically (ragged_dot ignores
    # rows beyond sum(group_sizes))
    f = act_fn(cfg.act)
    if cfg.gated_ffn:
        h = f(jax.lax.ragged_dot(xs, wg, gsz)) \
            * jax.lax.ragged_dot(xs, wu, gsz)
    else:
        h = f(jax.lax.ragged_dot(xs, wu, gsz))
    ys = jax.lax.ragged_dot(h, wd, gsz)
    inv = jnp.argsort(order)
    return jnp.take(ys, inv, axis=0)


def moe_forward_ep(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Drop-in replacement for moe_forward when an EP mesh is active.

    x: [B, S, D] (global, under pjit).  Routed experts run expert-parallel
    over the TP axis; shared experts stay on the TP-sharded dense path.
    """
    mesh, dp, tp = _EP_MESH, _DP_AXES, _TP_AXIS
    ep = ep_axes(mesh, cfg.n_experts) or (tp,)
    n_shards = 1
    for a in ep:
        n_shards *= mesh.shape[a]
    e_loc = cfg.n_experts // n_shards
    k = cfg.experts_per_token
    b, s, d = x.shape

    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    msize = mesh.shape[tp]
    b_shard = dp if b % dp_size == 0 else None
    s_shard = tp if s % msize == 0 and s >= msize else None
    x_spec = P(b_shard, s_shard, None)

    def local_fn(x_loc, router, wg, wu, wd):
        bl, sl, _ = x_loc.shape
        t = bl * sl
        cap = max(int(t * k * CAPACITY_FACTOR) // n_shards, 8)
        xt = x_loc.reshape(t, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        gates, choice = jax.lax.top_k(jax.nn.sigmoid(logits), k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        flat_choice = choice.reshape(t * k)
        flat_gate = gates.reshape(t * k)
        flat_tok = jnp.repeat(jnp.arange(t), k)
        dest = flat_choice // e_loc                       # owning shard
        # slot within the destination buffer: rank among same-dest entries
        order = jnp.argsort(dest)
        rank_sorted = jnp.arange(t * k) - jax.lax.cummax(
            jnp.where(jnp.concatenate([jnp.ones((1,), bool),
                                       dest[order][1:] != dest[order][:-1]]),
                      jnp.arange(t * k), 0))
        rank = jnp.zeros((t * k,), jnp.int32).at[order].set(
            rank_sorted.astype(jnp.int32))
        keep = rank < cap                                  # capacity drop
        slot = jnp.where(keep, dest * cap + rank, n_shards * cap)

        send_x = jnp.zeros((n_shards * cap + 1, d), x_loc.dtype) \
            .at[slot].set(jnp.take(xt, flat_tok, axis=0))[:-1]
        send_eid = jnp.full((n_shards * cap + 1,), 0, jnp.int32) \
            .at[slot].set((flat_choice % e_loc).astype(jnp.int32))[:-1]
        send_valid = jnp.zeros((n_shards * cap + 1,), bool) \
            .at[slot].set(keep)[:-1]

        recv_x = jax.lax.all_to_all(
            send_x.reshape(n_shards, cap, d), ep, 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(
            send_eid.reshape(n_shards, cap), ep, 0, 0, tiled=False)
        recv_valid = jax.lax.all_to_all(
            send_valid.reshape(n_shards, cap), ep, 0, 0, tiled=False)

        ys = _local_expert_block(
            cfg, recv_x.reshape(n_shards * cap, d),
            recv_eid.reshape(n_shards * cap),
            recv_valid.reshape(n_shards * cap), wg, wu, wd)
        ys = jnp.where(recv_valid.reshape(-1, 1), ys, 0.0)

        back = jax.lax.all_to_all(
            ys.reshape(n_shards, cap, d), ep, 0, 0, tiled=False)
        back = back.reshape(n_shards * cap, d)

        out = jnp.zeros((t, d), jnp.float32)
        contrib = jnp.take(
            jnp.concatenate([back, jnp.zeros((1, d), back.dtype)]),
            jnp.minimum(slot, n_shards * cap), axis=0)
        contrib = contrib.astype(jnp.float32) \
            * (flat_gate * keep)[:, None]
        out = out.at[flat_tok].add(contrib)
        return out.reshape(bl, sl, d).astype(x_loc.dtype)

    wg = p.get("wg")
    e_spec = P(ep, None, None)
    args = [x, p["router"].astype(jnp.float32)]
    in_specs = [x_spec, P(None, None)]
    if cfg.gated_ffn:
        args += [p["wg"], p["wu"], p["wd"]]
        in_specs += [e_spec, e_spec, e_spec]
        fn = local_fn
    else:
        args += [jnp.zeros((0,)), p["wu"], p["wd"]]
        in_specs += [P(None), e_spec, e_spec]
        fn = local_fn

    routed = shard_map(fn, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=x_spec, check_rep=False)(*args)

    if cfg.n_shared_experts:
        sh = dict(wu=p["shared_wu"], wd=p["shared_wd"])
        if cfg.gated_ffn:
            sh["wg"] = p["shared_wg"]
        routed = routed + ffn_forward(cfg, sh, x)
    return routed
