"""Model zoo: dense/MoE/MLA transformers, Mamba2 SSD, hybrid, encoder."""
from .config import (SHAPE_BY_NAME, SHAPES, ArchConfig, ShapeCfg,
                     cell_is_applicable)
from .model import (decode_step, forward, init_cache, init_params, layer_plan,
                    loss_fn)
