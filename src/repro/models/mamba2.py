"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Chunked SSD algorithm: the sequence is split into chunks of length Q;
intra-chunk interactions use the quadratic (attention-like) form on the MXU,
inter-chunk state is carried by a linear scan — exactly the paper's
decomposition.  Decode runs the O(1)-per-token recurrence with a
(conv window, SSM state) cache.

Shapes: d_inner = expand * d_model, H = d_inner / head_dim heads,
state N = ssm_state, single B/C group (n_groups = 1).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import rms_norm

Array = jnp.ndarray
CONV_W = 4


def _dw_conv(x: Array, w: Array, state: Optional[Array] = None):
    """Causal depthwise conv, window CONV_W.  x: [B, S, C], w: [CONV_W, C].

    With ``state`` [B, CONV_W-1, C] (decode), returns (y, new_state).
    """
    if state is not None:
        xin = jnp.concatenate([state, x], axis=1)
        new_state = xin[:, -(CONV_W - 1):]
    else:
        xin = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
        new_state = xin[:, -(CONV_W - 1):]
    y = sum(xin[:, i : i + x.shape[1]] * w[i] for i in range(CONV_W))
    return y, new_state


def ssd_chunked(xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                chunk: int) -> Array:
    """Chunked SSD scan.

    xh: [B, S, H, P]; dt: [B, S, H] (post-softplus); A: [H] (negative);
    Bm/Cm: [B, S, N] (single group, broadcast over heads).
    Returns y: [B, S, H, P].
    """
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)
    xq = xh.reshape(b, nc, chunk, h, p)
    dtq = dt.reshape(b, nc, chunk, h)
    Bq = Bm.reshape(b, nc, chunk, n)
    Cq = Cm.reshape(b, nc, chunk, n)

    dA = dtq * A[None, None, None, :]               # [B,C,Q,H] (negative)
    cum = jnp.cumsum(dA, axis=2)                    # within-chunk cumsum
    scores = jnp.einsum("bcqn,bckn->bcqk", Cq.astype(jnp.float32),
                        Bq.astype(jnp.float32))            # [B,C,Q,Q]
    xdt = xq.astype(jnp.float32) * dtq[..., None]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def head_intra(inputs):
        # per-head [B,C,Q,Q] decay matrix — never materialize the H axis
        cum_h, xdt_h = inputs                       # [B,C,Q], [B,C,Q,P]
        seg = cum_h[:, :, :, None] - cum_h[:, :, None, :]
        L = jnp.where(mask[None, None], jnp.exp(seg), 0.0)
        return jnp.einsum("bcqk,bckp->bcqp", scores * L, xdt_h)

    y_intra = jax.lax.map(
        head_intra,
        (jnp.moveaxis(cum, -1, 0), jnp.moveaxis(xdt, -2, 0)))
    y_intra = jnp.moveaxis(y_intra, 0, -2)                 # [B,C,Q,H,P]

    # chunk-final states: S_c = sum_k exp(cum_end - cum_k) * B_k x_k dt_k
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)           # [B,C,Q,H]
    states = jnp.einsum("bckn,bckh,bckhp->bcnhp", Bq.astype(jnp.float32),
                        decay_end, xdt)                    # [B,C,N,H,P]

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [B,C,H]

    def scan_fn(carry, inp):
        st, dec = inp                                      # [B,N,H,P], [B,H]
        new = carry * dec[:, None, :, None] + st
        return new, carry                                  # emit state *before*

    init = jnp.zeros((b, n, h, p), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init, (jnp.moveaxis(states, 1, 0),
                        jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # [B,C,N,H,P]

    # contribution of carried state to each position
    decay_in = jnp.exp(cum)                                # [B,C,Q,H]
    y_inter = jnp.einsum("bcqn,bcqh,bcnhp->bcqhp",
                         Cq.astype(jnp.float32), decay_in, prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(xh.dtype)


def mamba_forward(cfg: ArchConfig, p: dict, x: Array,
                  cache: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    """One Mamba2 block.  x: [B, S, D].  Decode when ``cache`` is given
    (S == 1): conv window + SSM state recurrence."""
    b, s, d = x.shape
    di, n, hdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = cfg.ssm_heads
    proj = jnp.einsum("bsd,dc->bsc", x, p["in_proj"])
    z, xc, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xc, Bm, Cm], -1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _dw_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out + p["conv_b"])
    xc, Bm, Cm = jnp.split(conv_out, [di, di + n], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # [H]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    xh = xc.reshape(b, s, h, hdim)

    if cache is None:
        pad = (-s) % cfg.ssm_chunk
        if pad:
            xh2 = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt2 = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B2 = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            C2 = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        else:
            xh2, dt2, B2, C2 = xh, dt, Bm, Cm
        y = ssd_chunked(xh2, dt2, A, B2, C2, cfg.ssm_chunk)[:, :s]
        new_ssm = None
    else:
        # O(1) recurrence: state [B,H,P,N]
        st = cache["ssm"].astype(jnp.float32)
        dA = jnp.exp(dt[:, 0, :, None, None] * A[None, :, None, None])
        upd = jnp.einsum("bhp,bn,bh->bhpn", xh[:, 0].astype(jnp.float32),
                         Bm[:, 0].astype(jnp.float32), dt[:, 0])
        st = st * dA + upd
        y = jnp.einsum("bhpn,bn->bhp", st, Cm[:, 0].astype(jnp.float32))
        y = y[:, None]                                       # [B,1,H,P]
        new_ssm = st
        y = y.astype(xh.dtype)

    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, di) * jax.nn.silu(z)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = dict(conv=new_conv.astype(cache["conv"].dtype),
                         ssm=new_ssm.astype(cache["ssm"].dtype))
    return out, new_cache


def mamba_param_shapes(cfg: ArchConfig):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    return dict(
        in_proj=(d, 2 * di + 2 * n + h),
        conv_w=(CONV_W, conv_ch),
        conv_b=(conv_ch,),
        A_log=(h,),
        D=(h,),
        dt_bias=(h,),
        out_norm=(di,),
        out_proj=(di, d),
    )
