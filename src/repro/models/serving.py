"""Serving-plan head padding: make KV caches shardable on the model axis.

Decode cells whose kv-head count doesn't divide the TP axis (minicpm's 36
MHA heads; GQA kv=8 on a 16-way axis) replicate the whole cache per device
— the measured 322 GB/device on minicpm decode_32k (§Perf).  Two
mathematically inert weight transforms fix this at serving time:

  * MHA: pad q+kv heads to the next multiple of the axis.  Padded heads
    have zero W_q/W_k/W_v rows and zero W_o rows -> contribute nothing.
  * GQA (hkv < axis): replicate kv heads up to the axis size and regroup.
    Replicated kv heads are identical -> attention per q head unchanged.

Per-device cache drops by (new local kv heads / old replicated kv heads);
e.g. qwen decode 8 replicated -> 1 local (8x), minicpm 36 -> 3 (12x).
MQA (kv=1) gains nothing (1 head replicated either way) — documented.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .config import ArchConfig


def serving_padded(cfg: ArchConfig, msize: int) -> ArchConfig:
    """Config transform for decode on an msize-way TP axis."""
    if not cfg.n_heads or cfg.mla or cfg.encoder_only:
        return cfg
    hkv, nh = cfg.n_kv_heads, cfg.n_heads
    if hkv % msize == 0:
        return cfg
    if hkv == nh:                       # MHA: pad q and kv together
        nh2 = -(-nh // msize) * msize
        return dataclasses.replace(cfg, n_heads=nh2, n_kv_heads=nh2,
                                   head_dim=cfg.hd)
    if hkv < msize and nh % msize == 0 and (nh // hkv) % (nh // msize) == 0:
        return dataclasses.replace(cfg, n_kv_heads=msize, head_dim=cfg.hd)
    return cfg


def pad_attn_params(cfg: ArchConfig, padded: ArchConfig, p: dict) -> dict:
    """Transform one attention block's weights (training layout -> serving
    layout).  Zero-pad q/o heads; replicate kv heads with regrouping."""
    if padded is cfg:
        return p
    hd = cfg.hd
    nh0, nh1 = cfg.n_heads, padded.n_heads
    kv0, kv1 = cfg.n_kv_heads, padded.n_kv_heads
    out = dict(p)

    def pad_h(w, axis, target):
        padw = [(0, 0)] * w.ndim
        padw[axis] = (0, target - w.shape[axis])
        return jnp.pad(w, padw)

    if nh1 > nh0:
        out["wq"] = pad_h(p["wq"], 1, nh1)
        out["wo"] = pad_h(p["wo"], 0, nh1)
        if "bq" in p:
            out["bq"] = pad_h(p["bq"], 0, nh1)
    if kv1 != kv0:
        if kv0 == nh0:                 # MHA path: zero-pad kv too
            out["wk"] = pad_h(p["wk"], 1, kv1)
            out["wv"] = pad_h(p["wv"], 1, kv1)
            if "bk" in p:
                out["bk"] = pad_h(p["bk"], 0, kv1)
                out["bv"] = pad_h(p["bv"], 0, kv1)
        else:                          # GQA: replicate + regroup
            r0, r1 = nh0 // kv0, padded.n_heads // kv1
            src = (jnp.arange(kv1) * r1) // r0
            out["wk"] = jnp.take(p["wk"], src, axis=1)
            out["wv"] = jnp.take(p["wv"], src, axis=1)
            if "bk" in p:
                out["bk"] = jnp.take(p["bk"], src, axis=0)
                out["bv"] = jnp.take(p["bv"], src, axis=0)
    return out


def pad_params_for_serving(cfg: ArchConfig, padded: ArchConfig,
                           params: dict) -> dict:
    """Whole-model weight transform (training -> serving head layout)."""
    if padded is cfg:
        return params
    out = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy tree
    for kind, stack in params.get("stacks", {}).items():
        if kind in ("ssm", "hybrid_group") or "attn" not in stack:
            continue
        out["stacks"][kind] = dict(stack)
        out["stacks"][kind]["attn"] = jax.vmap(
            lambda ap: pad_attn_params(cfg, padded, ap))(stack["attn"])
    if "shared_attn" in params:
        out["shared_attn"] = dict(params["shared_attn"])
        out["shared_attn"]["attn"] = pad_attn_params(
            cfg, padded, params["shared_attn"]["attn"])
    return out
