"""Model assembly: init / forward / decode for all assigned families.

Every stack is a ``lax.scan`` over layer-stacked params (O(1) HLO size), with
a configurable remat policy per layer.  Heterogeneous stacks (MoE models with
leading dense layers; Zamba2's shared-attention hybrid) are a short Python
sequence of scans.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (attn_forward, ffn_forward, mla_forward, moe_forward,
                     rms_norm)
from .mamba2 import CONV_W, mamba_forward, mamba_param_shapes

Array = jnp.ndarray
PyTree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(cfg: ArchConfig, key, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)
    if cfg.mla:
        dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        return dict(
            wdq=_dense_init(ks[0], (d, cfg.q_lora_rank), dtype),
            q_norm=jnp.ones((cfg.q_lora_rank,), dtype),
            wuq=_dense_init(ks[1], (cfg.q_lora_rank, h, dn + dr), dtype),
            wdkv=_dense_init(ks[2], (d, cfg.kv_lora_rank), dtype),
            kv_norm=jnp.ones((cfg.kv_lora_rank,), dtype),
            wkr=_dense_init(ks[3], (d, dr), dtype),
            wuk=_dense_init(ks[4], (cfg.kv_lora_rank, h, dn), dtype),
            wuv=_dense_init(ks[5], (cfg.kv_lora_rank, h, dv), dtype),
            wo=_dense_init(ks[6], (h, dv, d), dtype),
        )
    p = dict(
        wq=_dense_init(ks[0], (d, h, hd), dtype),
        wk=_dense_init(ks[1], (d, hkv, hd), dtype),
        wv=_dense_init(ks[2], (d, hkv, hd), dtype),
        wo=_dense_init(ks[3], (h, hd, d), dtype),
    )
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((h, hd), dtype), bk=jnp.zeros((hkv, hd), dtype),
                 bv=jnp.zeros((hkv, hd), dtype))
    return p


def _ffn_params(cfg: ArchConfig, key, d_ff, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = dict(wu=_dense_init(ks[0], (d, d_ff), dtype),
             wd=_dense_init(ks[1], (d_ff, d), dtype))
    if cfg.gated_ffn:
        p["wg"] = _dense_init(ks[2], (d, d_ff), dtype)
    return p


def _moe_params(cfg: ArchConfig, key, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    p = dict(
        router=_dense_init(ks[0], (d, e), jnp.float32),
        wu=_dense_init(ks[1], (e, d, f), dtype),
        wd=_dense_init(ks[2], (e, f, d), dtype),
    )
    if cfg.gated_ffn:
        p["wg"] = _dense_init(ks[3], (e, d, f), dtype)
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        p.update(shared_wu=_dense_init(ks[4], (d, fs), dtype),
                 shared_wd=_dense_init(ks[5], (fs, d), dtype))
        if cfg.gated_ffn:
            p["shared_wg"] = _dense_init(ks[6], (d, fs), dtype)
    return p


def _block_params(cfg: ArchConfig, key, kind: str, dtype):
    """One transformer block: kind in {dense, moe, ssm, shared_attn}."""
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "ssm":
        shapes = mamba_param_shapes(cfg)
        ks = jax.random.split(k1, len(shapes))
        mp = {}
        for kk, (name, shp) in zip(ks, sorted(shapes.items())):
            if name in ("conv_b", "dt_bias"):
                mp[name] = jnp.zeros(shp, dtype)
            elif name == "A_log":
                mp[name] = jnp.zeros(shp, jnp.float32)
            elif name == "D":
                mp[name] = jnp.ones(shp, dtype)
            elif name == "out_norm":
                mp[name] = jnp.ones(shp, dtype)
            else:
                mp[name] = _dense_init(kk, shp, dtype)
        return dict(ln=jnp.ones((d,), dtype), mamba=mp)
    if kind == "dense":
        ffn = _ffn_params(cfg, k2, cfg.d_ff, dtype)
    elif kind == "moe":
        ffn = _moe_params(cfg, k2, dtype)
    elif kind == "shared_attn":
        ffn = _ffn_params(cfg, k2, cfg.shared_attn_d_ff, dtype)
    else:
        raise ValueError(kind)
    return dict(ln1=jnp.ones((d,), dtype),
                attn=_attn_params(cfg, k1, dtype),
                ln2=jnp.ones((d,), dtype),
                ffn=ffn)


def layer_plan(cfg: ArchConfig):
    """The sequence of (kind, count) scans composing the model body."""
    if cfg.family == "ssm":
        return [("ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        g = cfg.attn_every
        return [("hybrid_group", cfg.n_layers // g)]
    if cfg.is_moe:
        nd = cfg.first_dense_layers
        return [("dense", nd), ("moe", cfg.n_layers - nd)]
    return [("dense", cfg.n_layers)]


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> PyTree:
    keys = jax.random.split(key, 8)
    params: Dict[str, PyTree] = {}
    if cfg.frontend != "audio":
        params["embed"] = _dense_init(keys[0], (cfg.vocab, cfg.d_model),
                                      dtype, scale=0.02)
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    params["lm_head"] = _dense_init(keys[1], (cfg.d_model, cfg.vocab), dtype)

    stacks = {}
    for i, (kind, count) in enumerate(layer_plan(cfg)):
        if count == 0:
            continue
        ks = jax.random.split(jax.random.fold_in(keys[2], i), count)
        if kind == "hybrid_group":
            per = cfg.attn_every
            def one_group(k):
                kin = jax.random.split(k, per)
                return jax.vmap(lambda kk: _block_params(cfg, kk, "ssm",
                                                         dtype))(kin)
            stacks[kind] = jax.vmap(one_group)(ks)
        else:
            stacks[kind] = jax.vmap(
                lambda kk: _block_params(cfg, kk, kind, dtype))(ks)
    params["stacks"] = stacks
    if cfg.family == "hybrid":
        params["shared_attn"] = _block_params(cfg, keys[3], "shared_attn",
                                              dtype)
    if cfg.mtp:
        params["mtp"] = dict(
            proj=_dense_init(keys[4], (2 * cfg.d_model, cfg.d_model), dtype),
            norm1=jnp.ones((cfg.d_model,), dtype),
            norm2=jnp.ones((cfg.d_model,), dtype),
            block=_block_params(cfg, keys[5],
                                "moe" if cfg.is_moe else "dense", dtype),
        )
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
REMAT_POLICIES = {
    "none": None,
    "dots": "dots",
    "full": "full",
}


def _maybe_remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # full recompute


def _block_forward(cfg: ArchConfig, p, x, pos, kind, cache=None,
                   cache_pos=None, pos3=None):
    if kind == "ssm":
        h, new_cache = mamba_forward(cfg, p["mamba"],
                                     rms_norm(x, p["ln"], cfg.norm_eps),
                                     cache)
        return x + cfg.residual_scale * h, new_cache
    attn_fn = mla_forward if cfg.mla else attn_forward
    kw = dict(cache=cache, cache_pos=cache_pos)
    if not cfg.mla:
        kw["pos3"] = pos3
    a, new_cache = attn_fn(cfg, p["attn"],
                           rms_norm(x, p["ln1"], cfg.norm_eps), pos, **kw)
    x = x + cfg.residual_scale * a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        from .moe_ep import get_ep_mesh, moe_forward_ep
        if get_ep_mesh() is not None:
            f = moe_forward_ep(cfg, p["ffn"], h)   # expert-parallel path
        else:
            f = moe_forward(cfg, p["ffn"], h)      # single-host fallback
    else:
        f = ffn_forward(cfg, p["ffn"], h)
    return x + cfg.residual_scale * f, new_cache


def _run_stacks(cfg: ArchConfig, params, x, pos, *, caches=None,
                cache_pos=None, pos3=None, remat="full", constrain=None):
    """Scan the layer stacks; returns (x, new_caches).

    ``constrain``: optional sharding constraint applied to the layer carry
    (Megatron-style sequence sharding between blocks)."""
    new_caches = {} if caches is not None else None
    cst = constrain if constrain is not None else (lambda t: t)
    for kind, count in layer_plan(cfg):
        if count == 0:
            continue
        stack = params["stacks"][kind]

        if kind == "hybrid_group":
            def group_body(carry, xs):
                h = carry
                gp, gc = xs

                def inner(carry2, xs2):
                    lp, lc = xs2
                    out, nc = _block_forward(cfg, lp, carry2, pos, "ssm",
                                             cache=lc, cache_pos=cache_pos)
                    return out, nc

                h, ncs = jax.lax.scan(
                    inner, h, (gp, gc["ssm"] if gc is not None else None))
                # shared attention block (same weights every group)
                h, nat = _block_forward(
                    cfg, params["shared_attn"], h, pos, "dense",
                    cache=gc["attn"] if gc is not None else None,
                    cache_pos=cache_pos, pos3=pos3)
                nc_out = dict(ssm=ncs, attn=nat) if gc is not None else None
                return cst(h), nc_out

            body = _maybe_remat(group_body, remat)
            gc_in = caches[kind] if caches is not None else None
            x, ncs = jax.lax.scan(body, x, (stack, gc_in))
            if caches is not None:
                new_caches[kind] = ncs
        else:
            def layer_body(carry, xs):
                lp, lc = xs
                out, nc = _block_forward(cfg, lp, carry, pos, kind,
                                         cache=lc, cache_pos=cache_pos,
                                         pos3=pos3)
                return cst(out), nc

            body = _maybe_remat(layer_body, remat)
            lc_in = caches[kind] if caches is not None else None
            x, ncs = jax.lax.scan(body, x, (stack, lc_in))
            if caches is not None:
                new_caches[kind] = ncs
    return x, new_caches


def embed_inputs(cfg: ArchConfig, params, batch) -> Tuple[Array, Array,
                                                          Optional[Array]]:
    """Returns (hidden, pos, pos3)."""
    if cfg.frontend == "audio":
        x = batch["frames"]
        b, s = x.shape[:2]
        pos = jnp.arange(s)[None, :]
        return x, pos, None
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        # scatter precomputed patch embeddings over placeholder positions
        pe, pp = batch["patch_embeds"], batch["patch_pos"]

        def put(row_x, row_e, row_p):
            return row_x.at[row_p].set(row_e.astype(row_x.dtype))

        x = jax.vmap(put)(x, pe, pp)
    pos = jnp.arange(s)[None, :]
    pos3 = batch.get("pos3") if cfg.mrope else None
    return x, pos, pos3


def forward(cfg: ArchConfig, params, batch, *, remat="full",
            constrain=None) -> Array:
    """Train/prefill forward -> logits [B, S, V]."""
    x, pos, pos3 = embed_inputs(cfg, params, batch)
    x, _ = _run_stacks(cfg, params, x, pos, pos3=pos3, remat=remat,
                       constrain=constrain)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def loss_fn(cfg: ArchConfig, params, batch, *, remat="full",
            constrain=None) -> Array:
    """Next-token CE (causal LMs) or masked-prediction CE (encoder)."""
    x, pos, pos3 = embed_inputs(cfg, params, batch)
    h, _ = _run_stacks(cfg, params, x, pos, pos3=pos3, remat=remat,
                       constrain=constrain)
    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", hn, params["lm_head"])
    labels = batch["labels"]
    mask = (labels >= 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32),
        jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    ce = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1)

    if cfg.mtp:
        # DeepSeek-V3 MTP depth 1: predict t+2 through one extra block.
        emb_next = jnp.take(params["embed"], batch["tokens"], axis=0)
        emb_next = jnp.roll(emb_next, -1, axis=1)
        mp = params["mtp"]
        hcat = jnp.concatenate(
            [rms_norm(h, mp["norm1"], cfg.norm_eps),
             rms_norm(emb_next, mp["norm2"], cfg.norm_eps)], axis=-1)
        hm = jnp.einsum("bsd,dk->bsk", hcat, mp["proj"])
        hm, _ = _block_forward(cfg, mp["block"], hm, pos,
                               "moe" if cfg.is_moe else "dense")
        lm = jnp.einsum("bsd,dv->bsv",
                        rms_norm(hm, params["final_norm"], cfg.norm_eps),
                        params["lm_head"])
        lbl2 = jnp.roll(labels, -1, axis=1)
        mask2 = (mask & jnp.roll(mask, -1, axis=1)).at[:, -1].set(False)
        lse2 = jax.nn.logsumexp(lm.astype(jnp.float32), axis=-1)
        gold2 = jnp.take_along_axis(
            lm.astype(jnp.float32),
            jnp.maximum(lbl2, 0)[..., None], axis=-1)[..., 0]
        ce = ce + 0.1 * jnp.sum((lse2 - gold2) * mask2) \
            / jnp.maximum(mask2.sum(), 1)
    return ce


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> PyTree:
    """Layer-stacked cache pytree matching layer_plan."""
    caches = {}
    for kind, count in layer_plan(cfg):
        if count == 0:
            continue
        if kind == "hybrid_group":
            per = cfg.attn_every
            ssm = dict(
                conv=jnp.zeros((count, per, batch, CONV_W - 1,
                                cfg.d_inner + 2 * cfg.ssm_state), dtype),
                ssm=jnp.zeros((count, per, batch, cfg.ssm_heads,
                               cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            )
            attn = dict(
                k=jnp.zeros((count, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                            dtype),
                v=jnp.zeros((count, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                            dtype),
            )
            caches[kind] = dict(ssm=ssm, attn=attn)
        elif kind == "ssm":
            caches[kind] = dict(
                conv=jnp.zeros((count, batch, CONV_W - 1,
                                cfg.d_inner + 2 * cfg.ssm_state), dtype),
                ssm=jnp.zeros((count, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                               cfg.ssm_state), jnp.float32),
            )
        elif cfg.mla:
            caches[kind] = dict(
                ckv=jnp.zeros((count, batch, max_seq, cfg.kv_lora_rank),
                              dtype),
                kr=jnp.zeros((count, batch, max_seq, cfg.qk_rope_dim), dtype),
            )
        else:
            caches[kind] = dict(
                k=jnp.zeros((count, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                            dtype),
                v=jnp.zeros((count, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                            dtype),
            )
    return caches


def decode_step(cfg: ArchConfig, params, caches, tokens: Array,
                pos: Array) -> Tuple[Array, PyTree]:
    """One token step.  tokens: [B, 1]; pos: scalar int32 (cache fill)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    posv = pos + jnp.zeros((1, 1), jnp.int32)
    pos3 = jnp.broadcast_to(posv[:, None, :], (x.shape[0], 3, 1)) \
        if cfg.mrope else None
    x, new_caches = _run_stacks(cfg, params, x, posv, caches=caches,
                                cache_pos=pos, pos3=pos3, remat="none")
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, new_caches
