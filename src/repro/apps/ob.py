"""Online Bidding (OB) — paper §VI-A, Figure 7.

Item state: [price, quantity].  Request mix 6:1:1 —
  bid   (len 1):  if bid_price >= price and qty >= req: qty -= req else reject
  alter (len 20): overwrite the price of 20 items
  top   (len 20): increase the quantity of 20 items

``bid`` is the user-defined conditional Fun (not associative) -> lockstep
path; it may be rejected ("rejected" notification via success flag).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.blotter import AppSpec, Blotter
from repro.core.types import CORE_FUNS, FunSpec, make_store

from .common import sample_keys

N_KEYS = 10_000
WIDTH = 2      # lanes: [price, quantity]
MAX_OPS = 20
BID, ALTER, TOP = 0, 1, 2


def _f_bid(pre, operand):
    """operand = [bid_price, req_qty]."""
    ok = (operand[0] >= pre[0]) & (pre[1] >= operand[1])
    qty = pre[1] - jnp.where(ok, operand[1], 0.0)
    return jnp.stack([pre[0], qty]), ok


def _f_set_price(pre, operand):
    return jnp.stack([operand[0], pre[1]]), jnp.asarray(True)


def _f_add_qty(pre, operand):
    return jnp.stack([pre[0], pre[1] + operand[1]]), jnp.asarray(True)


F_BID = FunSpec("bid", _f_bid)
F_SET_PRICE = FunSpec(
    "set_price", _f_set_price,
    affine=lambda o: (jnp.asarray([0.0, 1.0]), o * jnp.asarray([1.0, 0.0])))
F_ADD_QTY = FunSpec(
    "add_qty", _f_add_qty,
    affine=lambda o: (jnp.asarray([1.0, 1.0]), o * jnp.asarray([0.0, 1.0])))

OB_FUNS = CORE_FUNS + (F_BID, F_SET_PRICE, F_ADD_QTY)


def make_ob_store(n_keys: int = N_KEYS, rng: np.random.Generator | None = None):
    rng = rng or np.random.default_rng(2)
    init = np.zeros((n_keys + 1, WIDTH), np.float32)
    init[:n_keys, 0] = rng.uniform(10.0, 100.0, n_keys)   # price
    init[:n_keys, 1] = rng.uniform(0.0, 1000.0, n_keys)   # quantity
    return make_store([n_keys], WIDTH, init=jnp.asarray(init))


def gen_events(rng: np.random.Generator, n_events: int, *,
               n_keys: int = N_KEYS, theta: float = 0.6,
               align_mod: int = 0) -> Dict[str, np.ndarray]:
    kind = rng.choice([BID, ALTER, TOP], size=n_events, p=[0.75, 0.125, 0.125])
    return dict(
        kind=kind.astype(np.int32),
        keys=sample_keys(rng, n_events, MAX_OPS, n_keys, theta,
                         align_mod=align_mod),
        prices=rng.uniform(10.0, 100.0, (n_events, MAX_OPS)).astype(np.float32),
        qtys=rng.uniform(1.0, 20.0, (n_events, MAX_OPS)).astype(np.float32),
    )


def pre_process(ev):
    return ev


def state_access(blt: Blotter, eb):
    f_bid = blt.fun_id("bid")
    f_set, f_addq = blt.fun_id("set_price"), blt.fun_id("add_qty")
    kind = eb["kind"]
    is_bid, is_alter = kind == BID, kind == ALTER
    fun = jnp.where(is_bid, f_bid, jnp.where(is_alter, f_set, f_addq))
    for j in range(MAX_OPS):
        operand = jnp.stack([eb["prices"][j], eb["qtys"][j]])
        # bids touch only their first item; alter/top touch all 20
        blt.read_modify(0, eb["keys"][j], operand, fun,
                        valid=jnp.where(is_bid, j == 0, True))


def post_process(eb, res):
    is_bid = eb["kind"] == BID
    return dict(rejected=is_bid & ~res.success[0],
                qty_after=res.post[0, 1])


OB = AppSpec(
    name="ob", funs=OB_FUNS, max_ops=MAX_OPS, width=WIDTH,
    make_store=make_ob_store, gen_events=gen_events,
    pre_process=pre_process, state_access=state_access,
    post_process=post_process, has_gates=False, may_abort=True,
)
