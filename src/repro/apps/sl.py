"""Streaming Ledger (SL) — paper §VI-A, Figure 6.

Deposit: top-up an (account, asset) pair — 2 ADD ops.
Transfer: move balance from a (src account, src asset) pair to a dst pair —
4 ops: two conditional debits (bounded TAKE on the source records) and two
credits *gated* on the corresponding debit's success (the paper's CFun data
dependency; this is the heavy-dependency workload of §VI-C/D).

Tables: accounts + assets, 10k records each.  Non-associative (TAKE) and
gated -> lockstep path with level-wise dependency resolution.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.blotter import AppSpec, Blotter
from repro.core.types import CORE_FUNS, make_store

from .common import sample_keys

N_KEYS = 10_000
WIDTH = 1
MAX_OPS = 4
T_ACCT, T_ASSET = 0, 1


def make_sl_store(n_keys: int = N_KEYS, rng: np.random.Generator | None = None):
    rng = rng or np.random.default_rng(1)
    init = np.zeros((2 * n_keys + 1, WIDTH), np.float32)
    init[: 2 * n_keys, 0] = rng.uniform(50.0, 500.0, 2 * n_keys)
    return make_store([n_keys, n_keys], WIDTH, init=jnp.asarray(init))


def gen_events(rng: np.random.Generator, n_events: int, *,
               n_keys: int = N_KEYS, theta: float = 0.6,
               transfer_ratio: float = 0.5,
               align_mod: int = 0) -> Dict[str, np.ndarray]:
    # [src, dst] distinct within each pair
    acct = sample_keys(rng, n_events, 2, n_keys, theta, align_mod=align_mod)
    asset = sample_keys(rng, n_events, 2, n_keys, theta, align_mod=align_mod)
    return dict(
        src_acct=acct[:, 0], dst_acct=acct[:, 1],
        src_asset=asset[:, 0], dst_asset=asset[:, 1],
        amount=rng.uniform(1.0, 50.0, n_events).astype(np.float32),
        is_transfer=(rng.random(n_events) < transfer_ratio),
    )


def pre_process(ev):
    return ev


def state_access(blt: Blotter, eb):
    f_add, f_take = blt.fun_id("add"), blt.fun_id("take")
    tr = eb["is_transfer"]
    amt = eb["amount"]
    fun01 = jnp.where(tr, f_take, f_add)
    # deposits top up (ADD) the src pair; transfers debit (TAKE) it.
    s0 = blt.read_modify(T_ACCT, eb["src_acct"], amt, fun01)
    s1 = blt.read_modify(T_ASSET, eb["src_asset"], amt, fun01)
    # credits to the dst pair exist only for transfers, gated on the debits.
    blt.read_modify(T_ACCT, eb["dst_acct"], amt, f_add,
                    gate=jnp.where(tr, s0, -1), valid=tr)
    blt.read_modify(T_ASSET, eb["dst_asset"], amt, f_add,
                    gate=jnp.where(tr, s1, -1), valid=tr)


def post_process(eb, res):
    committed = res.success[0] & res.success[1]
    return dict(ok=committed,
                src_balance=res.post[0, 0],
                rejected=eb["is_transfer"] & ~committed)


SL = AppSpec(
    name="sl", funs=CORE_FUNS, max_ops=MAX_OPS, width=WIDTH,
    make_store=make_sl_store, gen_events=gen_events,
    pre_process=pre_process, state_access=state_access,
    post_process=post_process, has_gates=True, may_abort=True,
)
