"""Toll Processing (TP) — Linear Road, paper §II (Figure 2b) and §VI-A.

Operators Road Speed / Vehicle Cnt / Toll Notification are *fused* (paper §V)
into one joint operator; per position report the fused transaction is:

  RMW  SpeedTable[seg]  += [speed, 1]        (running average as (sum, count))
  RMW  CountTable[seg]  |= onehot(vehicle)   (unique count; see note)
  READ SpeedTable[seg]                       (TN reads *updated* status:
  READ CountTable[seg]                        same ts, later slot -> chain
                                              order gives the fresh version)

Hardware adaptation (DESIGN.md §8): the paper's per-segment HashSet of
vehicle ids has no fixed-size TPU representation; we use a W-lane linear
probabilistic counting sketch — vehicle hashed to a lane, lanes combined by
elementwise max (associative!).  Unique-count estimates come from the lane
occupancy.  SpeedTable uses the affine ADD family.  Both are associative ->
segmented-scan fast path, even though the workload has only 100 hot keys.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blotter import AppSpec, Blotter
from repro.core.types import ASSOC_FUNS, make_store

from .common import align_keys, zipf_probs

N_SEGMENTS = 100
WIDTH = 32          # LPC sketch lanes (also holds [sum, count] for speed)
MAX_OPS = 4
T_SPEED, T_CNT = 0, 1


def make_tp_store(n_segments: int = N_SEGMENTS, **_):
    return make_store([n_segments, n_segments], WIDTH,
                      is_max=[False, True])


def gen_events(rng: np.random.Generator, n_events: int, *,
               n_segments: int = N_SEGMENTS, theta: float = 0.2,
               n_vehicles: int = 5_000,
               align_mod: int = 0) -> Dict[str, np.ndarray]:
    p = zipf_probs(n_segments, theta)
    seg = rng.choice(n_segments, size=n_events, p=p).astype(np.int32)
    if align_mod > 1:
        seg = align_keys(seg, n_segments, align_mod)
    return dict(
        segment=seg,
        vehicle=rng.integers(0, n_vehicles, n_events).astype(np.int32),
        speed=rng.uniform(20.0, 120.0, n_events).astype(np.float32),
    )


def pre_process(ev):
    lane = ev["vehicle"] % WIDTH
    return dict(ev, lane=lane)


def state_access(blt: Blotter, eb):
    seg = eb["segment"]
    # Road Speed: running average of traffic speed
    speed_op = jnp.zeros((WIDTH,), jnp.float32)
    speed_op = speed_op.at[0].set(eb["speed"]).at[1].set(1.0)
    blt.read_modify(T_SPEED, seg, speed_op, "add")
    # Vehicle Cnt: LPC sketch update
    sketch = jnp.zeros((WIDTH,), jnp.float32).at[eb["lane"]].set(1.0)
    blt.read_modify(T_CNT, seg, sketch, "max")
    # Toll Notification: read the *updated* congestion status
    s = blt.read(T_SPEED, seg)
    c = blt.read(T_CNT, seg)
    return s, c


def post_process(eb, res):
    speed_sum, cnt = res.pre[2, 0], res.pre[2, 1]
    avg_speed = speed_sum / jnp.maximum(cnt, 1.0)
    occupied = jnp.sum(res.pre[3] > 0.0)
    # LPC estimate of unique vehicles from lane occupancy
    frac = jnp.clip(occupied / WIDTH, 0.0, 1.0 - 1e-3)
    uniq = -WIDTH * jnp.log1p(-frac)
    congested = (avg_speed < 40.0) & (uniq > 5.0)
    toll = jnp.where(congested, 2.0 * (uniq - 5.0) ** 2, 0.0)
    return dict(toll=toll, avg_speed=avg_speed, uniq=uniq)


TP = AppSpec(
    name="tp", funs=ASSOC_FUNS, max_ops=MAX_OPS, width=WIDTH,
    make_store=make_tp_store, gen_events=gen_events,
    pre_process=pre_process, state_access=state_access,
    post_process=post_process, has_gates=False, may_abort=False,
)
