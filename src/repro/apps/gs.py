"""Grep and Sum (GS) — paper §VI-A, Figure 5.

Grep issues one state transaction of 10 accesses per event: a read event
READs 10 records and forwards the values to Sum (fused here, per §V operator
fusion); a write event WRITEs 10 records.  Table: 10k records.  Associative
(READ/PUT) -> eligible for the segmented-scan fast path.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.blotter import AppSpec, Blotter
from repro.core.types import ASSOC_FUNS, OpKind, make_store

from .common import sample_keys, sample_multipartition_keys

TXN_LEN = 10
N_KEYS = 10_000
WIDTH = 1


def make_gs_store(n_keys: int = N_KEYS, rng: np.random.Generator | None = None):
    rng = rng or np.random.default_rng(0)
    init = np.zeros((n_keys + 1, WIDTH), np.float32)
    init[:n_keys, 0] = rng.uniform(1.0, 100.0, n_keys)
    return make_store([n_keys], WIDTH, init=jnp.asarray(init))


def gen_events(rng: np.random.Generator, n_events: int, *,
               n_keys: int = N_KEYS, theta: float = 0.6,
               read_ratio: float = 0.5, n_partitions: int = 0,
               mp_ratio: float = 0.0, mp_len: int = 4,
               align_mod: int = 0) -> Dict[str, np.ndarray]:
    if n_partitions:
        keys = sample_multipartition_keys(rng, n_events, TXN_LEN, n_keys,
                                          theta, n_partitions, mp_ratio, mp_len)
    else:
        keys = sample_keys(rng, n_events, TXN_LEN, n_keys, theta,
                           align_mod=align_mod)
    return dict(
        keys=keys,
        is_read=(rng.random(n_events) < read_ratio),
        values=rng.uniform(1.0, 100.0, (n_events, TXN_LEN)).astype(np.float32),
    )


def pre_process(ev):
    return ev  # Parser already produced structured fields


def state_access(blt: Blotter, eb):
    f_read, f_put = blt.fun_id("read"), blt.fun_id("put")
    fun = jnp.where(eb["is_read"], f_read, f_put)
    kind = jnp.where(eb["is_read"], int(OpKind.READ), int(OpKind.WRITE))
    for j in range(TXN_LEN):
        blt.read_modify(0, eb["keys"][j], eb["values"][j], fun)
        blt.rows[-1]["kind"] = jnp.asarray(kind, jnp.int32)


def post_process(eb, res):
    # Sum operator: sum of returned values for read events; else pass-through.
    total = jnp.sum(res.pre[:, 0]) * eb["is_read"]
    return dict(sum=total, ok=jnp.all(res.success))


GS = AppSpec(
    name="gs", funs=ASSOC_FUNS, max_ops=TXN_LEN, width=WIDTH,
    make_store=make_gs_store, gen_events=gen_events,
    pre_process=pre_process, state_access=state_access,
    post_process=post_process, has_gates=False, may_abort=False,
)
