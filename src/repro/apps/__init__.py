"""Benchmark applications (paper §VI-A): GS, SL, OB, TP."""
from .gs import GS
from .ob import OB
from .sl import SL
from .tp import TP

ALL_APPS = {a.name: a for a in (GS, SL, OB, TP)}
