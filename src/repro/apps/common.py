"""Workload generators shared by the benchmark applications (paper §VI-B).

Host-side numpy generators (the Parser operator): Zipf-skewed key choice,
multi-partition transaction mixes, deterministic seeding.  Keys within one
transaction are sampled *distinct* (the paper's record lists; also required
so a transaction never touches the same state twice, matching all four
applications' semantics).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def zipf_probs(n_keys: int, theta: float) -> np.ndarray:
    """P(k) ∝ 1/(k+1)^theta — the standard Zipfian access distribution."""
    w = 1.0 / np.power(np.arange(1, n_keys + 1, dtype=np.float64), theta)
    return w / w.sum()


def sample_keys(rng: np.random.Generator, n_events: int, ops_per_txn: int,
                n_keys: int, theta: float) -> np.ndarray:
    """[n_events, ops_per_txn] Zipf-skewed keys, distinct within a txn."""
    p = zipf_probs(n_keys, theta)
    if ops_per_txn == 1:
        return rng.choice(n_keys, size=(n_events, 1), p=p).astype(np.int32)
    out = np.empty((n_events, ops_per_txn), np.int32)
    for i in range(n_events):
        out[i] = rng.choice(n_keys, size=ops_per_txn, replace=False, p=p)
    return out


def sample_multipartition_keys(
        rng: np.random.Generator, n_events: int, ops_per_txn: int,
        n_keys: int, theta: float, n_partitions: int,
        mp_ratio: float, mp_len: int) -> np.ndarray:
    """Keys honouring the paper's multi-partition mix: ``mp_ratio`` of the
    transactions touch exactly ``mp_len`` distinct partitions (hash = key %
    n_partitions); the rest stay within a single partition."""
    p = zipf_probs(n_keys, theta)
    keys = np.empty((n_events, ops_per_txn), np.int32)
    is_mp = rng.random(n_events) < mp_ratio
    key_part = np.arange(n_keys) % n_partitions
    part_pools = [np.flatnonzero(key_part == q) for q in range(n_partitions)]
    part_probs = [p[pool] / p[pool].sum() for pool in part_pools]
    for i in range(n_events):
        span = mp_len if is_mp[i] else 1
        span = min(span, n_partitions, ops_per_txn)
        parts = rng.choice(n_partitions, size=span, replace=False)
        ks: list = []
        for j in range(ops_per_txn):
            q = parts[j % span]
            pool, pp = part_pools[q], part_probs[q]
            while True:
                k = rng.choice(pool, p=pp)
                if k not in ks:
                    break
            ks.append(k)
        keys[i] = ks
    return keys
