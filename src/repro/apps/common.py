"""Workload generators shared by the benchmark applications (paper §VI-B).

Host-side numpy generators (the Parser operator): Zipf-skewed key choice,
multi-partition transaction mixes, deterministic seeding.  Keys within one
transaction are sampled *distinct* (the paper's record lists; also required
so a transaction never touches the same state twice, matching all four
applications' semantics).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def zipf_probs(n_keys: int, theta: float) -> np.ndarray:
    """P(k) ∝ 1/(k+1)^theta — the standard Zipfian access distribution."""
    w = 1.0 / np.power(np.arange(1, n_keys + 1, dtype=np.float64), theta)
    return w / w.sum()


def align_keys(keys: np.ndarray, n_keys: int, align_mod: int) -> np.ndarray:
    """Bijectively remap keys so the Zipf-hot head lands on ONE residue
    class mod ``align_mod`` (k -> align_mod*(k % K) + k//K, K = n_keys /
    align_mod): the hottest keys map to 0, align_mod, 2*align_mod, ...

    The round-robin ownership striping (``owner = uid % n_shards``)
    neutralises plain Zipf skew by construction; this adversarial
    permutation re-concentrates it on one shard — the skew-storm
    workload that elastic resharding exists to absorb.  Distinctness
    within a transaction is preserved (the map is a bijection).
    """
    if align_mod <= 1:
        return keys
    assert n_keys % align_mod == 0, (n_keys, align_mod)
    k_per = n_keys // align_mod
    return (align_mod * (keys % k_per) + keys // k_per).astype(keys.dtype)


def sample_keys(rng: np.random.Generator, n_events: int, ops_per_txn: int,
                n_keys: int, theta: float,
                align_mod: int = 0) -> np.ndarray:
    """[n_events, ops_per_txn] Zipf-skewed keys, distinct within a txn.

    ``align_mod`` > 1 post-permutes through :func:`align_keys` so the hot
    head collides on one residue class (skew-storm workloads)."""
    p = zipf_probs(n_keys, theta)
    if ops_per_txn == 1:
        out = rng.choice(n_keys, size=(n_events, 1), p=p).astype(np.int32)
        return align_keys(out, n_keys, align_mod)
    out = np.empty((n_events, ops_per_txn), np.int32)
    for i in range(n_events):
        out[i] = rng.choice(n_keys, size=ops_per_txn, replace=False, p=p)
    return align_keys(out, n_keys, align_mod)


def sample_multipartition_keys(
        rng: np.random.Generator, n_events: int, ops_per_txn: int,
        n_keys: int, theta: float, n_partitions: int,
        mp_ratio: float, mp_len: int) -> np.ndarray:
    """Keys honouring the paper's multi-partition mix: ``mp_ratio`` of the
    transactions touch exactly ``mp_len`` distinct partitions (hash = key %
    n_partitions); the rest stay within a single partition."""
    p = zipf_probs(n_keys, theta)
    keys = np.empty((n_events, ops_per_txn), np.int32)
    is_mp = rng.random(n_events) < mp_ratio
    key_part = np.arange(n_keys) % n_partitions
    part_pools = [np.flatnonzero(key_part == q) for q in range(n_partitions)]
    part_probs = [p[pool] / p[pool].sum() for pool in part_pools]
    for i in range(n_events):
        span = mp_len if is_mp[i] else 1
        span = min(span, n_partitions, ops_per_txn)
        parts = rng.choice(n_partitions, size=span, replace=False)
        ks: list = []
        for j in range(ops_per_txn):
            q = parts[j % span]
            pool, pp = part_pools[q], part_probs[q]
            while True:
                k = rng.choice(pool, p=pp)
                if k not in ks:
                    break
            ks.append(k)
        keys[i] = ks
    return keys
