"""Dual-mode scheduling (paper §IV-B, D1).

One engine *step* processes exactly one punctuation interval:

  compute mode      vmapped PRE_PROCESS + op registration into blotters
  (TXN_START)       punctuation boundary — barrier analogue is the data
                    dependence between phases inside one jitted function
  state-access mode restructure + evaluate the postponed transaction batch
  compute mode      vmapped POST_PROCESS over stored events + access results

The punctuation interval is the leading batch axis; the progress controller
assigns monotonically increasing timestamps (the paper's fetch&add counter
becomes ``ts_base + arange``: SPMD-deterministic and contention-free).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blotter import AppSpec, build_opbatch
from .engines import EngineStats, evaluate
from .types import OpResults, StateStore


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    scheme: str = "tstream"
    n_partitions: int = 16
    max_dep_levels: int = 3
    use_pallas: bool = False
    abort_repass: bool = False   # re-run with aborted txns masked (§IV-C2)


class DualModeEngine:
    """The TStream engine bound to one application."""

    def __init__(self, app: AppSpec, store: StateStore,
                 cfg: EngineConfig = EngineConfig()):
        self.app = app
        self.cfg = cfg
        self.init_store = store
        self._step = jax.jit(partial(_step_impl, app=app, cfg=cfg))

    def step(self, values: jnp.ndarray, events: Dict[str, jnp.ndarray],
             ts_base) -> Tuple[Dict, jnp.ndarray, EngineStats]:
        """Process one punctuation interval. Returns (outputs, values', stats)."""
        store = dataclasses.replace(self.init_store, values=values)
        return self._step(store, events, jnp.asarray(ts_base, jnp.int32))

    def run_stream(self, values, event_stream, punct_interval: int):
        """Drive a host-side event stream punctuation by punctuation."""
        outs = []
        ts = 0
        for batch in _batches(event_stream, punct_interval):
            out, values, stats = self.step(values, batch, ts)
            ts += punct_interval
            outs.append(out)
        return outs, values


def _batches(stream: Dict[str, np.ndarray], interval: int):
    n = len(next(iter(stream.values())))
    for i in range(0, n - n % interval, interval):
        yield {k: jnp.asarray(v[i : i + interval]) for k, v in stream.items()}


def _step_impl(store: StateStore, events, ts_base, *, app: AppSpec,
               cfg: EngineConfig):
    # -- compute mode: pre-process + postpone state access (D1) ------------
    ops, ebs = build_opbatch(app, store, events, ts_base)

    # -- state access mode: dynamic restructuring execution (D2) -----------
    res, values, stats = evaluate(
        store, ops, app.funs, cfg.scheme,
        associative_only=app.associative_only, has_gates=app.has_gates,
        n_partitions=cfg.n_partitions, max_dep_levels=cfg.max_dep_levels,
        use_pallas=cfg.use_pallas)

    if cfg.abort_repass and app.may_abort:
        # Abort handling without rollback: a transaction whose ops failed is
        # masked out and the batch is re-evaluated from the pre-batch values.
        # (Addresses the paper's §IV-F multi-write rollback limitation.)
        some = jax.tree_util.tree_leaves(events)[0]
        batch = some.shape[0]
        succ = res["success"].reshape(batch, app.max_ops)
        valid = ops.valid.reshape(batch, app.max_ops)
        txn_ok = jnp.all(succ | ~valid, axis=1)
        keep = jnp.repeat(txn_ok, app.max_ops)
        ops2 = dataclasses.replace(ops, valid=ops.valid & keep)
        res, values, stats = evaluate(
            store, ops2, app.funs, cfg.scheme,
            associative_only=app.associative_only, has_gates=app.has_gates,
            n_partitions=cfg.n_partitions, max_dep_levels=cfg.max_dep_levels,
            use_pallas=cfg.use_pallas)

    # -- compute mode resumes: post-process stored events -------------------
    some = jax.tree_util.tree_leaves(events)[0]
    batch = some.shape[0]
    shaped = OpResults(
        pre=res["pre"].reshape(batch, app.max_ops, app.width),
        post=res["post"].reshape(batch, app.max_ops, app.width),
        success=res["success"].reshape(batch, app.max_ops),
    )
    out = jax.vmap(app.post_process)(ebs, shaped)
    return out, values, stats
