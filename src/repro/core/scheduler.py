"""Dual-mode scheduling (paper §IV-B, D1).

One engine *step* processes exactly one punctuation interval:

  compute mode      vmapped PRE_PROCESS + op registration into blotters
  (TXN_START)       punctuation boundary — barrier analogue is the data
                    dependence between phases inside one jitted function
  state-access mode restructure + evaluate the postponed transaction batch
  compute mode      vmapped POST_PROCESS over stored events + access results

The punctuation interval is the leading batch axis; the progress controller
assigns monotonically increasing timestamps (the paper's fetch&add counter
becomes ``ts_base + arange``: SPMD-deterministic and contention-free).

Two drivers share the per-interval logic (DESIGN.md §2.4):

* ``run_stream(fused=False)`` — the host-side loop: one jit dispatch, one
  store rebuild and one host<->device round-trip *per interval*.  Kept as
  the reference / debugging path.
* ``run_stream(fused=True)``  — the device-resident path: the stream is
  reshaped to ``[n_intervals, interval, ...]`` and the whole run executes
  as a single ``jax.lax.scan`` inside one jitted call with the state
  buffer donated.  Compute mode (pre-process + op registration) is
  intrinsically interval-parallel, so it is vmapped over *all* intervals
  up front; only state-access mode is sequential across punctuations.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blotter import AppSpec, build_opbatch
from .engines import (CHAIN_SCHEMES, EngineStats, evaluate,
                      simple_affine_luts, tstream_scan_coefs_stream,
                      tstream_scan_execute, tstream_scan_plan)
from .restructure import megakernel_engaged, restructure, restructure_stream
from .types import OpResults, StateStore


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    scheme: str = "tstream"
    n_partitions: int = 16
    max_dep_levels: int = 3
    use_pallas: bool = False
    abort_repass: bool = False   # re-run with aborted txns masked (§IV-C2)
    # sharded streaming: resolve uid -> owner through the hash-probe
    # kernel instead of the direct-addressed gather (DESIGN.md §2.5)
    use_hash_probe_route: bool = False
    # restructure backbone: "auto" resolves the partition -> packed-sort ->
    # lexsort -> megakernel ladder (DESIGN.md §2.1/§2.8); force a rung for
    # parity tests/benches ("megakernel" forces the fused chain-eval rung)
    restructure_method: str = "auto"
    # force kernel block parameters in the fused drivers' dispatches,
    # overriding the autotune cache: a tuple of (kernel, value) pairs,
    # e.g. (("segscan", 128), ("radix_partition", 512)).  Empty () defers
    # to kernels/autotune.  (Tuple-of-pairs, not dict: EngineConfig must
    # stay hashable for jit closure.)
    kernel_block_params: tuple = ()

    def block_param(self, kernel: str):
        return dict(self.kernel_block_params).get(kernel)


class DualModeEngine:
    """The TStream engine bound to one application.

    With ``mesh``/``layout`` the engine becomes device-parallel: the
    ownership permutation and routing tables are built once here, and
    ``run_stream`` dispatches the whole stream as one sharded fused
    program (``core/sharded_stream``).
    """

    def __init__(self, app: AppSpec, store: StateStore,
                 cfg: EngineConfig = EngineConfig(), *,
                 mesh=None, layout: str = "shared_nothing",
                 exchange_slack: float = 2.0):
        self.app = app
        self.cfg = cfg
        self.init_store = store
        self._step = jax.jit(partial(_step_impl, app=app, cfg=cfg))
        self._fused = jax.jit(
            partial(_fused_impl, app=app, cfg=cfg, store=store),
            donate_argnums=0)
        # plan variants (adaptive control plane, DESIGN.md §2.9): extra
        # jitted builds of the SAME fused program with scheme/rung
        # overrides, selectable per chunk via run_stream_chunk(variant=)
        self._variants: Dict[Tuple[str, str], object] = {}
        # THE output program: all drivers post-process through this one
        # jitted function on identical shapes (see _post_stream)
        self._post = jax.jit(partial(_post_stream, app=app))
        self._sharded = None
        if mesh is not None:
            from .sharded_stream import ShardedStream
            self._sharded = ShardedStream(app, store, cfg, mesh, layout,
                                          exchange_slack=exchange_slack)

    def step(self, values: jnp.ndarray, events: Dict[str, jnp.ndarray],
             ts_base) -> Tuple[Dict, jnp.ndarray, EngineStats]:
        """Process one punctuation interval. Returns (outputs, values', stats)."""
        store = dataclasses.replace(self.init_store, values=values)
        res, ebs, values, stats = self._step(store, events,
                                             jnp.asarray(ts_base, jnp.int32))
        lift = jax.tree_util.tree_map(lambda x: x[None], (res, ebs))
        outs = self._post(*lift)
        return jax.tree_util.tree_map(lambda x: x[0], outs), values, stats

    def run_stream(self, values, event_stream, punct_interval: int,
                   fused: bool = True):
        """Drive an event stream punctuation by punctuation.

        ``fused=True`` (default) runs every interval inside one jitted
        ``lax.scan`` with the state buffer donated — no per-interval host
        round-trips.  ``fused=False`` is the host-side per-interval loop;
        both produce identical outputs and final state.

        Engines built with a ``mesh`` run the sharded fused driver
        (fused-only); exchange statistics land in
        ``self.last_exchange_stats`` and overflow drops are logged.
        """
        if self._sharded is not None:
            assert fused, "sharded run_stream has no unfused host loop"
            outs, values = self._sharded.run_stream(values, event_stream,
                                                    punct_interval)
            self.last_exchange_stats = self._sharded.last_stats
            return outs, values
        if not fused:
            res_l, ebs_l = [], []
            ts = 0
            for batch in _batches(event_stream, punct_interval):
                store = dataclasses.replace(self.init_store, values=values)
                res, ebs, values, stats = self._step(store, batch,
                                                     jnp.int32(ts))
                ts += punct_interval
                res_l.append(res)
                ebs_l.append(ebs)
            if not res_l:
                return [], values
            stack = lambda *xs: jnp.stack(xs)
            res_all = jax.tree_util.tree_map(stack, *res_l)
            ebs_all = jax.tree_util.tree_map(stack, *ebs_l)
            return self._outs(res_all, ebs_all, len(res_l)), values

        n = len(next(iter(event_stream.values())))
        n_intervals = n // punct_interval
        if n_intervals == 0:
            return [], values
        batched = {}
        for k, v in event_stream.items():
            v = np.asarray(v)[: n_intervals * punct_interval]
            batched[k] = jnp.asarray(
                v.reshape((n_intervals, punct_interval) + v.shape[1:]))
        # the jitted call donates its values argument (in-place carry on
        # device); hand it a private copy so the caller's buffer survives
        res_all, ebs_all, values, _ = self._fused(
            jnp.array(values, copy=True), batched, jnp.int32(0))
        return self._outs(res_all, ebs_all, n_intervals), values

    def _outs(self, res_all, ebs_all, n_intervals: int):
        """Shared output program + one bulk D2H, split per interval."""
        outs = jax.device_get(self._post(res_all, ebs_all))
        return [jax.tree_util.tree_map(lambda x, i=i: x[i], outs)
                for i in range(n_intervals)]

    # -- chunked service API (runtime/service.py; DESIGN.md §2.6/§2.9) -----
    def ensure_variant(self, scheme: str | None = None,
                       restructure_method: str | None = None):
        """Pre-build a jitted plan variant with scheme/rung overridden.

        Returns the variant key to pass to :meth:`run_stream_chunk`, or
        ``None`` when the requested plan IS the construction plan (the
        base ``_fused`` program).  Building is idempotent and lazy —
        compilation itself still happens at the variant's first dispatch
        per chunk shape.  Single-device only: the sharded driver's
        adaptive lattice is {exchange slack, chunk size}, both handled
        elsewhere (``ShardedStream.set_exchange_slack`` / the service's
        chunking loop).
        """
        sch = scheme or self.cfg.scheme
        rung = restructure_method or self.cfg.restructure_method
        if (sch, rung) == (self.cfg.scheme, self.cfg.restructure_method):
            return None
        assert self._sharded is None, \
            "sharded driver has no scheme/rung plan variants"
        key = (sch, rung)
        if key not in self._variants:
            cfg = dataclasses.replace(self.cfg, scheme=sch,
                                      restructure_method=rung)
            self._variants[key] = jax.jit(
                partial(_fused_impl, app=self.app, cfg=cfg,
                        store=self.init_store),
                donate_argnums=0)
        return key

    def run_stream_chunk(self, values, batched, ts0: int, variant=None):
        """One device-resident chunk of a continuous run.

        ``batched`` leaves are ``[K, interval, ...]`` **device** arrays and
        ``values`` is DONATED: the caller owns the buffer and threads the
        returned carry into the next chunk, so K-chunked execution scans
        the same per-interval schedule as one monolithic ``run_stream``
        over the concatenated events (bit-identity pinned in
        tests/test_service.py).  ``ts0`` is the global timestamp base of
        the chunk's first interval (= global interval index × interval).
        ``variant`` selects a pre-built plan variant (``ensure_variant``);
        ``None`` runs the construction plan.

        Returns ``(res_all, ebs_all, values', stats)`` as *unmaterialized*
        device arrays — nothing blocks, so the caller can stage and
        dispatch chunk *i+1* while chunk *i* still runs.  ``stats`` is
        ``dict(engine=EngineStats)`` ([K]-stacked scan leaves) on the
        single-device driver and ``dict(exchange=...)`` (dropped/shipped/
        max_fill per interval + capacity) on the sharded one.  Materialize
        per-interval outputs later via :meth:`post_outputs`.
        """
        if self._sharded is not None:
            assert variant is None, \
                "sharded driver has no scheme/rung plan variants"
            res_all, ebs_all, values, xst = self._sharded.run_chunk(
                values, batched, ts0)
            return res_all, ebs_all, values, dict(exchange=xst)
        fn = self._fused if variant is None else self._variants[variant]
        res_all, ebs_all, values, est = fn(values, batched, jnp.int32(ts0))
        return res_all, ebs_all, values, dict(engine=est)

    def post_outputs(self, res_all, ebs_all, n_intervals: int):
        """Materialize a chunk's per-interval outputs (blocks on D2H)."""
        return self._outs(res_all, ebs_all, n_intervals)

    def chunk_lowered_text(self, values, batched, variant=None) -> str:
        """Compiled (post-SPMD) HLO text for the chunk program that runs
        these carry/batch shapes — the telemetry plane's opt-in cost
        attribution hook (DESIGN.md §2.11).  Only shapes/dtypes are read
        from ``values``/``batched``, never data, so it is safe to call
        right before the donating dispatch.  This is a real AOT
        lower+compile per shape (the jit call cache is separate), which
        is why attribution defaults off."""
        spec = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype),
            (values, batched))
        ts = jax.ShapeDtypeStruct((), jnp.int32)
        if self._sharded is not None:
            fn = self._sharded._impl
        else:
            fn = self._fused if variant is None else self._variants[variant]
        return fn.lower(spec[0], spec[1], ts).compile().as_text()

    # -- elastic resharding / carry API (DESIGN.md §2.10) -----------------
    # The service's chunk loop threads an OPAQUE carry: canonical [S+1, W]
    # values on the single-device driver, the resident ownership-block
    # layout on the sharded one.  Snapshots and final stats always go
    # through carry_out so checkpoints stay canonical (restorable onto
    # any ownership/layout).
    def carry_in(self, values):
        """Canonical [S+1, W] values -> the driver's resident carry."""
        if self._sharded is not None:
            return self._sharded.carry_in(values)
        return values

    def carry_out(self, carry):
        """Resident carry -> canonical [S+1, W] values (no donation)."""
        if self._sharded is not None:
            return self._sharded.carry_out(carry)
        return carry

    @property
    def owners(self):
        """Current ownership overrides (() = pure striping)."""
        return self._sharded.owners if self._sharded is not None else ()

    @property
    def reshardable(self) -> bool:
        return self._sharded is not None and self._sharded.reshardable

    def rebind_ownership(self, overrides) -> None:
        """Rebind the sharded plan to ``overrides`` WITHOUT moving data —
        for restores onto a migrated layout (the snapshot's canonical
        values re-enter through ``carry_in`` under the new binding).
        Identity on the single-device driver (ownership is a no-op
        there, so replayed ``reshard`` decisions stay harmless)."""
        if self._sharded is not None and overrides != self._sharded.owners:
            self._sharded.set_ownership(overrides)

    def apply_resharding(self, carry, overrides):
        """Live migration of the resident carry onto ``overrides``
        (sharded driver; see ``ShardedStream.reshard``).  Returns
        ``(carry, moved_rows)``; identity on single-device."""
        if self._sharded is None:
            return carry, 0
        return self._sharded.reshard(carry, overrides)


def _batches(stream: Dict[str, np.ndarray], interval: int):
    n = len(next(iter(stream.values())))
    for i in range(0, n - n % interval, interval):
        yield {k: jnp.asarray(v[i : i + interval]) for k, v in stream.items()}


def _eval_interval(store: StateStore, ops, *, app: AppSpec,
                   cfg: EngineConfig, prestructured=None):
    """State-access mode for one interval: restructure exactly once,
    evaluate, optionally re-pass with aborted txns masked (reusing the
    same sort).  Returns materialized per-op results; post-processing
    happens in the shared output program (``_post_stream``)."""
    pres = prestructured
    if pres is None and cfg.scheme in CHAIN_SCHEMES:
        # the segmented-scan path reads only 4 sorted columns — skip the rest
        light = (cfg.scheme in ("tstream", "tstream_scan")
                 and app.associative_only)
        pres = restructure(ops, store.pad_uid, rowmajor_ts=True, light=light,
                           method=cfg.restructure_method,
                           use_pallas=cfg.use_pallas)
    res, values, stats = evaluate(
        store, ops, app.funs, cfg.scheme,
        associative_only=app.associative_only, has_gates=app.has_gates,
        n_partitions=cfg.n_partitions, max_dep_levels=cfg.max_dep_levels,
        use_pallas=cfg.use_pallas, prestructured=pres)

    batch = ops.n_ops // app.max_ops
    if cfg.abort_repass and app.may_abort:
        # Abort handling without rollback: a transaction whose ops failed is
        # masked out and the batch is re-evaluated from the pre-batch values.
        # (Addresses the paper's §IV-F multi-write rollback limitation.)
        # Chain geometry only depends on uids, so the repass tightens the
        # ``valid`` mask in both layouts instead of re-sorting.
        succ = res["success"].reshape(batch, app.max_ops)
        valid = ops.valid.reshape(batch, app.max_ops)
        txn_ok = jnp.all(succ | ~valid, axis=1)
        keep = jnp.repeat(txn_ok, app.max_ops)
        ops2 = dataclasses.replace(ops, valid=ops.valid & keep)
        pres2 = None
        if pres is not None:
            sops, ch = pres
            pres2 = (dataclasses.replace(sops,
                                         valid=sops.valid & ch.take(keep)),
                     ch)
        res, values, stats = evaluate(
            store, ops2, app.funs, cfg.scheme,
            associative_only=app.associative_only, has_gates=app.has_gates,
            n_partitions=cfg.n_partitions, max_dep_levels=cfg.max_dep_levels,
            use_pallas=cfg.use_pallas, prestructured=pres2)

    return res, values, stats


def _post_stream(res_all, ebs_all, *, app: AppSpec):
    """Post-process a whole stream's stacked per-op results.

    This is THE output program: every driver (host loop, fused scan,
    sharded fused) evaluates to *materialized* per-op results and feeds
    them through this one jitted function on identical ``[n_intervals,
    N, ...]`` shapes.  Keeping the app-level reductions in a single
    compilation context is what makes the drivers' outputs bit-identical:
    XLA CPU lowers a reduction fused into a producer loop with a
    different float association than a standalone reduction (~1-ulp
    drift), so post-processing must never compile inside one driver's
    evaluation fusion but not another's.
    """
    return jax.vmap(lambda r, e: _post_interval(r, e, app=app))(res_all,
                                                                ebs_all)


def _post_interval(res, ebs, *, app: AppSpec):
    """Compute mode resumes: post-process one interval's stored events.

    (Results may carry kernel-padded lanes in the fused Pallas path —
    sliced here.)  Drivers do not call this directly; outputs go through
    ``_post_stream`` so every driver shares one compilation context.
    """
    batch = res["success"].shape[0] // app.max_ops
    shaped = OpResults(
        pre=res["pre"].reshape(batch, app.max_ops, -1)[..., : app.width],
        post=res["post"].reshape(batch, app.max_ops, -1)[..., : app.width],
        success=res["success"].reshape(batch, app.max_ops),
    )
    return jax.vmap(app.post_process)(ebs, shaped)


def _step_impl(store: StateStore, events, ts_base, *, app: AppSpec,
               cfg: EngineConfig):
    # -- compute mode: pre-process + postpone state access (D1) ------------
    ops, ebs = build_opbatch(app, store, events, ts_base)
    # -- state access mode: dynamic restructuring execution (D2) -----------
    res, values, stats = _eval_interval(store, ops, app=app, cfg=cfg)
    return res, ebs, values, stats


def _fused_impl(values, events_b, ts0, *, app: AppSpec, cfg: EngineConfig,
                store: StateStore):
    """Whole-stream driver: one jitted call, ``lax.scan`` over intervals.

    ``events_b`` leaves are [n_intervals, interval, ...]; ``values`` is the
    donated state buffer.  Everything values-independent — op registration,
    the restructure sort, and (on the associative path) the coefficient
    scans and commit gather maps — is hoisted out of the sequential scan
    and batched over all intervals; the scan body carries only the
    values-dependent evaluation.
    """
    some = jax.tree_util.tree_leaves(events_b)[0]
    n_intervals, interval = some.shape[0], some.shape[1]
    store = dataclasses.replace(store, values=values)

    # compute mode for ALL intervals at once (interval-parallel)
    ts_bases = ts0 + jnp.arange(n_intervals, dtype=jnp.int32) * interval
    ops_all, ebs_all = jax.vmap(
        lambda ev, tb: build_opbatch(app, store, ev, tb))(events_b, ts_bases)

    assoc_fast = (cfg.scheme in ("tstream", "tstream_scan")
                  and app.associative_only
                  and not (cfg.abort_repass and app.may_abort))

    # Pallas fast path: lane-pad operands & state to the kernel width ONCE
    # per stream, so per-interval kernel dispatch does no lane padding.
    padded = False
    if cfg.use_pallas and assoc_fast:
        from repro.kernels.segscan import kernel as K
        if app.width < K.LANES:
            lane_pad = K.LANES - app.width
            ops_all = dataclasses.replace(
                ops_all, operand=jnp.pad(
                    ops_all.operand, ((0, 0), (0, 0), (0, lane_pad))))
            store = dataclasses.replace(
                store, values=jnp.pad(store.values, ((0, 0), (0, lane_pad))))
            padded = True

    if assoc_fast:
        res_all, values, stats = _fused_assoc(store, ops_all, app=app,
                                              cfg=cfg)
        if padded:
            values = values[:, : app.width]
        return res_all, ebs_all, values, stats

    # generic path: hoist the restructure pass for chain schemes; the scan
    # body evaluates one interval from its prestructured batch
    pres_all = None
    if cfg.scheme in CHAIN_SCHEMES:
        pres_all = restructure_stream(
            ops_all, store.pad_uid, rowmajor_ts=True,
            method=cfg.restructure_method, use_pallas=cfg.use_pallas,
            block_rows=cfg.block_param("radix_partition"))

    def body(values, xs):
        ops, pres = xs
        st = dataclasses.replace(store, values=values)
        res, values, stats = _eval_interval(st, ops, app=app, cfg=cfg,
                                            prestructured=pres)
        return values, (res, stats)

    values, (res_all, stats) = jax.lax.scan(body, store.values,
                                            (ops_all, pres_all))
    return res_all, ebs_all, values, stats


def _fused_assoc(store: StateStore, ops_all, *, app: AppSpec,
                 cfg: EngineConfig):
    """Associative fast path: the scan body is O(N) gathers + elementwise.

    The one-pass restructure plan (partition ranks + histograms, ONE
    kernel dispatch under ``use_pallas``), coefficient scans and commit
    gather maps for ALL intervals run batched before the scan; results
    return to flat layout inside the body and stack as scan outputs
    (post-processing happens in the shared output program,
    ``_post_stream``).
    """
    luts = simple_affine_luts(app.funs)
    if megakernel_engaged(ops_all.uid.shape[-1], store.values.shape[0],
                          method=cfg.restructure_method,
                          has_max=any(store.table_is_max),
                          funs_simple=luts is not None):
        return _fused_assoc_mega(store, ops_all, luts=luts, cfg=cfg)

    pres_all = restructure_stream(
        ops_all, store.pad_uid, rowmajor_ts=True, light=True,
        method=cfg.restructure_method, use_pallas=cfg.use_pallas,
        block_rows=cfg.block_param("radix_partition"))
    plan_all = jax.vmap(
        lambda o, p: tstream_scan_plan(store, o, app.funs,
                                       prestructured=p))(ops_all, pres_all)
    plan_all = tstream_scan_coefs_stream(plan_all, use_pallas=cfg.use_pallas,
                                         block_rows=cfg.block_param("segscan"))

    def body(values, plan):
        res, new_values, stats = tstream_scan_execute(
            values, plan, store.pad_uid)
        return new_values, (res, stats)

    values, (res_all, stats) = jax.lax.scan(body, store.values, plan_all)
    return res_all, values, stats


def _fused_assoc_mega(store: StateStore, ops_all, *, luts,
                      cfg: EngineConfig):
    """Megakernel rung of the associative fast path (DESIGN.md §2.8).

    The hoisted plan shrinks to the partition permutation + histograms
    (``geometry=False`` — no per-row seg_id/pos/seg_end, no materialized
    [N, W] coefficient arrays); the scan body evaluates each interval's
    chains through ONE fused partition→segscan→commit dispatch
    (``kernels/megakernel``), bit-identical to the staged rungs.
    """
    from repro.kernels.megakernel import fused_chain_eval

    a_lut, b_lut = luts
    sops_all, ch_all = restructure_stream(
        ops_all, store.pad_uid, rowmajor_ts=True, light=True,
        method="partition", use_pallas=cfg.use_pallas, geometry=False,
        block_rows=cfg.block_param("radix_partition"))

    def body(values, xs):
        sops, ch = xs
        res, new_values, stats = fused_chain_eval(
            values, sops, ch, store.pad_uid, a_lut=a_lut, b_lut=b_lut,
            use_pallas=cfg.use_pallas)
        res = {k: ch.untake(v) for k, v in res.items()}
        return new_values, (res, stats)

    values, (res_all, stats) = jax.lax.scan(body, store.values,
                                            (sops_all, ch_all))
    return res_all, values, stats
