"""TStream core: transactional concurrent state access for stream processing.

The paper's primary contribution — dual-mode scheduling (D1) and dynamic
restructuring execution (D2) — implemented as data-parallel JAX.
"""
from .blotter import AppSpec, Blotter, build_opbatch
from .engines import SCHEMES, EngineStats, evaluate
from .intervals import (IntervalAssembler, IntervalInfo, ReplaySource,
                        WatermarkPolicy)
from .ownership import LAYOUTS, Ownership, build_ownership, make_local_store
from .restructure import Chains, restructure
from .scheduler import DualModeEngine, EngineConfig
from .types import (CORE_FUNS, F_ADD, F_MAX, F_NOP, F_PUT, F_READ, F_TAKE,
                    FunSpec, OpBatch, OpKind, OpResults, StateStore,
                    make_store)
