"""Dynamic restructuring (paper §IV-C1): transactions -> operation chains.

The paper decomposes each postponed transaction into per-state operations and
inserts them into timestamp-sorted per-state lists (operation chains) via a
concurrent skip list.  The TPU-native equivalent is a stable lexicographic
sort by (state uid, ts, slot): after sorting, each chain is a contiguous
segment, already timestamp-ordered.  Sorting is deterministic, O(N log N),
and — unlike a concurrent data structure — meaningful in SPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .types import OpBatch


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Chains:
    """Operation chains over a sorted view of an OpBatch.

    ``order``     : sorted index -> original flat op index (gather map)
    ``inv``       : original flat op index -> sorted index (inverse of
                    ``order``; lets results return to (txn, slot) layout by
                    *gather* instead of the much slower CPU/TPU scatter)
    ``seg_start`` : bool[N], True at the first op of each chain
    ``seg_id``    : chain id of each sorted op (== cumsum(seg_start)-1)
    ``pos``       : position of the op inside its chain (ts order)
    ``seg_end``   : True at the last op of each chain
    ``n_chains``  : traced scalar, number of distinct chains
    ``max_len``   : traced scalar, longest chain (lockstep round count)
    """

    order: jnp.ndarray
    inv: jnp.ndarray
    seg_start: jnp.ndarray
    seg_id: jnp.ndarray
    pos: jnp.ndarray
    seg_end: jnp.ndarray
    n_chains: jnp.ndarray
    max_len: jnp.ndarray

    def take(self, x: jnp.ndarray) -> jnp.ndarray:
        """Gather a flat (pre-sort) per-op array into sorted chain order."""
        return jnp.take(x, self.order, axis=0)

    def untake(self, x_sorted: jnp.ndarray) -> jnp.ndarray:
        """Gather a sorted per-op array back into flat (pre-sort) layout."""
        return jnp.take(x_sorted, self.inv, axis=0)


def packed_sort_fits(n_rows: int, max_major: int) -> bool:
    """Whether (major, row-index) packs into one uint32 sort key."""
    idx_bits = max(n_rows - 1, 1).bit_length()
    major_bits = max(int(max_major), 1).bit_length()
    return idx_bits + major_bits <= 32


def packed_stable_sort(major: jnp.ndarray, max_major: int):
    """Stable sort of rows by an integer major key via ONE single-operand
    sort of ``major << idx_bits | index`` packed uint32 keys (~6x faster
    than a multi-key lexsort on CPU XLA; DESIGN.md §2.1).

    ``major`` must lie in [0, max_major] and
    ``packed_sort_fits(n, max_major)`` must hold.  Returns
    ``(order, major_sorted, pos)`` with ``order`` the sorted->original
    gather map and ``pos`` the inverse permutation (original row ->
    sorted position, via vectorized binary search instead of a scatter).

    Shared by chain restructuring (major = state uid) and the owner-routed
    exchange (major = destination shard).
    """
    n = major.shape[0]
    idx_bits = max(n - 1, 1).bit_length()
    idx = jnp.arange(n, dtype=jnp.int32)
    shift = jnp.uint32(1 << idx_bits)
    packed = major.astype(jnp.uint32) * shift + idx.astype(jnp.uint32)
    keys = jnp.sort(packed)
    order = (keys & jnp.uint32((1 << idx_bits) - 1)).astype(jnp.int32)
    major_s = (keys // shift).astype(jnp.int32)
    # keys are unique, so each row's sorted position == binary search
    pos = jnp.searchsorted(keys, packed,
                           method="scan_unrolled").astype(jnp.int32)
    return order, major_s, pos


def restructure(ops: OpBatch, pad_uid: int, *,
                rowmajor_ts: bool = False,
                light: bool = False) -> Tuple[OpBatch, Chains]:
    """Sort the op batch into operation chains.

    Invalid (padding) ops are routed to the padding chain (uid = pad_uid) and
    sort to the end; chain order within a state follows (ts, slot) so that a
    transaction's intra-state ops keep their registration order.

    ``rowmajor_ts``: caller's promise that flat row order already equals
    (ts, slot) lexicographic order — true for every batch built by
    ``build_opbatch`` (ts = ts_base + txn, rows laid out (txn, slot)).
    Then the 3-key lexsort collapses to a *single-operand* sort of
    ``uid << idx_bits | index`` packed keys — ~6x faster on CPU XLA and
    identical output (the packed low bits are exactly the stable
    tie-break), and the inverse permutation comes from a vectorized binary
    search instead of a scatter.  Falls back to the generic lexsort when
    the packed key would not fit 32 bits.

    ``light``: gather only the columns the segmented-scan path reads
    (uid, fun, operand, valid); ts/txn/slot/kind/gate are ``None`` in the
    returned sorted batch.  Lockstep/mvlk callers need the full view.
    """
    uid = jnp.where(ops.valid, ops.uid, pad_uid)
    n = uid.shape[0]
    packed_ok = rowmajor_ts and packed_sort_fits(n, pad_uid)

    idx = jnp.arange(n, dtype=jnp.int32)
    if packed_ok:
        order, uid_s, inv = packed_stable_sort(uid, pad_uid)
    else:
        order = jnp.lexsort((ops.slot, ops.ts, uid))  # uid major, ts, slot
        uid_s = jnp.take(uid, order)
        inv = jnp.zeros((n,), jnp.int32).at[order].set(idx)

    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), uid_s[1:] != uid_s[:-1]])
    seg_id = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    start_idx = jax.lax.cummax(jnp.where(seg_start, idx, 0))
    pos = idx - start_idx
    seg_end = jnp.concatenate(
        [uid_s[1:] != uid_s[:-1], jnp.ones((1,), bool)])

    sorted_ops = OpBatch(
        uid=uid_s,
        ts=None if light else jnp.take(ops.ts, order),
        txn=None if light else jnp.take(ops.txn, order),
        slot=None if light else jnp.take(ops.slot, order),
        kind=None if light else jnp.take(ops.kind, order),
        fun=jnp.take(ops.fun, order),
        gate=None if light else jnp.take(ops.gate, order),
        operand=jnp.take(ops.operand, order, axis=0),
        valid=jnp.take(ops.valid, order),
    )
    chains = Chains(
        order=order,
        inv=inv,
        seg_start=seg_start,
        seg_id=seg_id,
        pos=pos,
        seg_end=seg_end,
        n_chains=seg_id[-1] + 1,
        max_len=jnp.max(pos) + 1,
    )
    return sorted_ops, chains


def commit_index(uid_sorted: jnp.ndarray, n_slots_incl_pad: int):
    """Per-state commit gather map from the sorted uid column.

    Returns ``(pos, ok)`` with ``pos[u]`` = sorted index of the *last* op
    of chain ``u`` and ``ok[u]`` = chain ``u`` has ops in this batch.  The
    state update then becomes a [S+1] gather + select instead of an [N]
    scatter (CPU/TPU scatters serialize; binary search vectorizes).
    """
    slots = jnp.arange(n_slots_incl_pad, dtype=uid_sorted.dtype)
    pos = jnp.searchsorted(uid_sorted, slots, side="right",
                           method="scan_unrolled") - 1
    ok = (pos >= 0) & (jnp.take(uid_sorted, jnp.maximum(pos, 0)) == slots)
    return jnp.maximum(pos, 0), ok


def segmented_scan_affine(a: jnp.ndarray, b: jnp.ndarray,
                          seg_start: jnp.ndarray,
                          exclusive: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Segmented scan of affine maps f(v) = a*v + b under composition.

    Composition (applied left-to-right): (a2,b2)∘(a1,b1) = (a2*a1, a2*b1+b2).
    Returns per-op (A, B) such that the state seen by op i within its chain is
    A_i * v0 + B_i (exclusive) — the paper's multiversion value at ts_i.

    Implemented as an explicit log-step Hillis–Steele sweep with
    segment-flag blocking (the same scheme the Pallas kernel uses inside a
    block).  Unlike ``lax.associative_scan`` — whose combine tree depends
    on an element's *global* array offset — the association here is fixed
    by each op's position **within its segment**, so a chain produces
    bit-identical results wherever it sits in the array.  The sharded
    fused driver relies on this: the same chain lands at different offsets
    on different devices and must still match the single-device schedule
    bit for bit (DESIGN.md §2.5).
    """
    n = a.shape[0]
    f = seg_start
    a_inc, b_inc = a, b
    d = 1
    while d < n:
        ap = jnp.concatenate([jnp.ones_like(a_inc[:d]), a_inc[:-d]], axis=0)
        bp = jnp.concatenate([jnp.zeros_like(b_inc[:d]), b_inc[:-d]], axis=0)
        fp = jnp.concatenate([jnp.ones((d,), bool), f[:-d]])
        blocked = f[:, None]
        a_inc, b_inc = (jnp.where(blocked, a_inc, a_inc * ap),
                        jnp.where(blocked, b_inc, a_inc * bp + b_inc))
        f = f | fp
        d *= 2
    if not exclusive:
        return a_inc, b_inc
    # shift right within segments: identity at segment starts.
    ident_a = jnp.ones_like(a[:1])
    ident_b = jnp.zeros_like(b[:1])
    a_exc = jnp.concatenate([ident_a, a_inc[:-1]], axis=0)
    b_exc = jnp.concatenate([ident_b, b_inc[:-1]], axis=0)
    a_exc = jnp.where(seg_start[:, None], jnp.ones_like(a_exc), a_exc)
    b_exc = jnp.where(seg_start[:, None], jnp.zeros_like(b_exc), b_exc)
    return a_exc, b_exc


def segmented_scan_max(m: jnp.ndarray, seg_start: jnp.ndarray,
                       exclusive: bool = True) -> jnp.ndarray:
    """Segmented running max (for max-type tables, e.g. LPC sketches).

    Same segment-relative Hillis–Steele sweep as the affine scan (max is
    order-insensitive, but the uniform structure keeps the two paths'
    round counts identical).
    """
    neg = jnp.full_like(m, -jnp.inf)
    n = m.shape[0]
    f = seg_start
    m_inc = m
    d = 1
    while d < n:
        mp = jnp.concatenate([neg[:d], m_inc[:-d]], axis=0)
        fp = jnp.concatenate([jnp.ones((d,), bool), f[:-d]])
        m_inc = jnp.where(f[:, None], m_inc, jnp.maximum(m_inc, mp))
        f = f | fp
        d *= 2
    if not exclusive:
        return m_inc
    m_exc = jnp.concatenate([neg[:1], m_inc[:-1]], axis=0)
    return jnp.where(seg_start[:, None], neg, m_exc)
