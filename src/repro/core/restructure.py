"""Dynamic restructuring (paper §IV-C1): transactions -> operation chains.

The paper decomposes each postponed transaction into per-state operations
and inserts them into timestamp-sorted per-state lists (operation chains)
via a concurrent skip list.  The accelerator-native equivalent is a stable
grouping by (state uid, ts, slot): after grouping, each chain is a
contiguous, timestamp-ordered segment.

Because the major key is a **bounded integer** (uid < n_slots), the
grouping does not need a comparison sort: the default backbone is a
one-pass **radix/counting partition** (``kernels/radix_partition``) —
histogram + exclusive prefix + stable within-bucket rank, O(N + K) — that
yields the chain order, its inverse (by direct offset arithmetic instead
of binary search), the segment flags and the per-state commit gather map
from the *same* per-bucket histograms.  The fallback ladder when the
partition's bucket bounds don't hold is the packed single-operand sort
(uint32, then uint64 under x64), then the generic 3-key lexsort
(DESIGN.md §2.1).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from .types import OpBatch

log = logging.getLogger(__name__)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Chains:
    """Operation chains over a sorted view of an OpBatch.

    ``order``     : sorted index -> original flat op index (gather map)
    ``inv``       : original flat op index -> sorted index (inverse of
                    ``order``; lets results return to (txn, slot) layout by
                    *gather* instead of the much slower CPU/TPU scatter)
    ``seg_start`` : bool[N], True at the first op of each chain
    ``seg_id``    : chain id of each sorted op (== cumsum(seg_start)-1)
    ``pos``       : position of the op inside its chain (ts order)
    ``seg_end``   : True at the last op of each chain
    ``n_chains``  : traced scalar, number of distinct chains
    ``max_len``   : traced scalar, longest chain (lockstep round count)
    ``counts``    : i32[n_buckets] per-uid histogram — populated by the
                    partition path (None on the sort paths); feeds the
                    commit gather map and exchange capacities for free
    ``starts``    : i32[n_buckets] exclusive prefix of ``counts``
    """

    order: jnp.ndarray
    inv: jnp.ndarray
    seg_start: jnp.ndarray
    seg_id: jnp.ndarray
    pos: jnp.ndarray
    seg_end: jnp.ndarray
    n_chains: jnp.ndarray
    max_len: jnp.ndarray
    counts: Optional[jnp.ndarray] = None
    starts: Optional[jnp.ndarray] = None

    def take(self, x: jnp.ndarray) -> jnp.ndarray:
        """Gather a flat (pre-sort) per-op array into sorted chain order."""
        return jnp.take(x, self.order, axis=0)

    def untake(self, x_sorted: jnp.ndarray) -> jnp.ndarray:
        """Gather a sorted per-op array back into flat (pre-sort) layout."""
        return jnp.take(x_sorted, self.inv, axis=0)


# ---------------------------------------------------------------------------
# Path selection: partition -> packed sort (u32/u64) -> lexsort
# ---------------------------------------------------------------------------
RESTRUCTURE_METHODS = ("auto", "partition", "packed", "lexsort",
                       "megakernel")

# Counting-partition auto bounds — the measured crossover for the CURRENT
# device kind, resolved from ``kernels/autotune.LADDER_BOUNDS``.  On this
# repo's CPU hosts the row is the measured BENCH_restructure.json
# crossover (1.3-1.8x for the owner-routing shape at >=655k rows;
# wall-clock parity within host noise (0.9-1.1x) for a 9-bucket store at
# 512k, trending with N — engaged there because the commit map comes free
# and the structural cost is O(N + K) vs O(N log N); loses for large
# sparse stores), so "auto" only engages the partition inside that
# regime.  On accelerators the jnp.sort baseline is a bitonic network,
# which moves the crossover far right — the autotune table carries
# per-device rows instead of this one CPU measurement.  Forcing
# ``method="partition"`` bypasses the bound (parity tests, deployments).
PARTITION_MAX_BUCKETS, PARTITION_MIN_ROWS = autotune.ladder_bounds("cpu")


def partition_fits(n_rows: int, n_buckets: int) -> bool:
    """Whether "auto" picks the one-pass counting partition backbone
    (device-derived bounds; see ``kernels/autotune.LADDER_BOUNDS``)."""
    max_buckets, min_rows = autotune.ladder_bounds()
    return n_buckets <= max_buckets and int(n_rows) >= min_rows


def megakernel_engaged(n_rows: int, n_slots_incl_pad: int, *,
                       method: str, has_max: bool,
                       funs_simple: bool) -> bool:
    """Whether the fused drivers evaluate chains through the fused
    partition→segscan→commit megakernel (``kernels/megakernel``).

    Structural eligibility first — the fused pipeline only expresses
    simple-affine tables (``FunSpec.affine_simple``; its one-hot
    gather/scatter is exact only for finite values, which ±inf max
    neutrals break) — then either an explicit ``method="megakernel"``
    force or, under "auto", the measured per-device win band
    (``kernels/autotune.MEGA_BOUNDS``).  Ineligible forces fall back to
    the staged path (bit-identical by construction), logged once.
    """
    eligible = (not has_max) and funs_simple
    if method == "megakernel":
        if not eligible:
            _warn_mega_fallback(has_max, funs_simple)
        return eligible
    if method != "auto" or not eligible:
        return False
    band = autotune.mega_bounds()
    min_rows = band.get("min_rows")
    return (min_rows is not None and int(n_rows) >= int(min_rows)
            and n_slots_incl_pad <= int(band.get("max_buckets", 0)))


_MEGA_FALLBACK_WARNED = set()


def _warn_mega_fallback(has_max: bool, funs_simple: bool) -> None:
    key = (has_max, funs_simple)
    if key in _MEGA_FALLBACK_WARNED:
        return
    _MEGA_FALLBACK_WARNED.add(key)
    why = []
    if has_max:
        why.append("store has max-type tables (-inf neutrals break the "
                   "kernel's one-hot gather exactness)")
    if not funs_simple:
        why.append("app registers non-simple affine functions")
    log.warning("restructure: method='megakernel' forced but %s — using the "
                "staged partition path (bit-identical)", "; ".join(why))


def packed_sort_fits(n_rows: int, max_major: int, bits: int = 32) -> bool:
    """Whether (major, row-index) packs into one ``bits``-wide sort key."""
    idx_bits = max(n_rows - 1, 1).bit_length()
    major_bits = max(int(max_major), 1).bit_length()
    return idx_bits + major_bits <= bits


def _x64_enabled() -> bool:
    return bool(jax.config.jax_enable_x64)


def restructure_path(n: int, pad_uid: int, *, rowmajor_ts: bool,
                     method: str = "auto") -> str:
    """Resolve the restructure backbone for an (n, pad_uid) batch.

    The ladder (DESIGN.md §2.1): counting partition when its bucket
    bounds hold; else the packed single-operand sort (uint32, or uint64
    when x64 is enabled); else the generic 3-key lexsort.  Every
    resolution is logged; the silent-slow case (packed key needs > 32
    bits but x64 is off) warns with the fix.
    """
    if method not in RESTRUCTURE_METHODS:
        raise ValueError(f"method={method!r}; choose from "
                         f"{RESTRUCTURE_METHODS}")
    if method in ("partition", "packed", "megakernel") and not rowmajor_ts:
        raise ValueError(
            f"method={method!r} needs rowmajor_ts=True: all replace the "
            "(ts, slot) tie-break with the flat row index, which is only "
            "equivalent when rows are already in (ts, slot) order")
    if method != "auto":
        # "megakernel" shares the partition's geometry (same histogram
        # backbone); whether chain EVALUATION goes through the fused
        # kernel is the drivers' megakernel_engaged() decision
        path = "partition" if method == "megakernel" else method
    elif not rowmajor_ts:
        path = "lexsort"
    elif partition_fits(n, pad_uid + 1):
        path = "partition"
    elif packed_sort_fits(n, pad_uid, bits=32):
        path = "packed"
    elif packed_sort_fits(n, pad_uid, bits=64) and _x64_enabled():
        path = "packed"
    else:
        if packed_sort_fits(n, pad_uid, bits=64):
            log.warning(
                "restructure: packed key for n=%d, max_major=%d needs more "
                "than 32 bits and jax_enable_x64 is off — falling back to "
                "the slow 3-key lexsort.  Enable x64 (JAX_ENABLE_X64=1 or "
                "jax.config.update('jax_enable_x64', True)) for the "
                "packed-uint64 sort path.", n, pad_uid)
        else:
            log.warning(
                "restructure: packed key for n=%d, max_major=%d exceeds 64 "
                "bits — falling back to the 3-key lexsort.", n, pad_uid)
        path = "lexsort"
    log.debug("restructure: path=%s (n=%d, n_buckets=%d, rowmajor_ts=%s)",
              path, n, pad_uid + 1, rowmajor_ts)
    return path


# ---------------------------------------------------------------------------
# Backbones
# ---------------------------------------------------------------------------
def packed_stable_sort(major: jnp.ndarray, max_major: int):
    """Stable sort of rows by an integer major key via ONE single-operand
    sort of ``major << idx_bits | index`` packed keys (~6x faster than a
    multi-key lexsort on CPU XLA; DESIGN.md §2.1).  Keys pack into uint32
    when they fit, else uint64 (requires ``jax_enable_x64``).

    ``major`` must lie in [0, max_major].  Returns
    ``(order, major_sorted, pos)`` with ``order`` the sorted->original
    gather map and ``pos`` the inverse permutation (original row ->
    sorted position, via vectorized binary search instead of a scatter).

    Shared by chain restructuring (major = state uid) and the owner-routed
    exchange (major = destination shard).
    """
    n = major.shape[0]
    idx_bits = max(n - 1, 1).bit_length()
    if packed_sort_fits(n, max_major, bits=32):
        dt = jnp.uint32
    elif packed_sort_fits(n, max_major, bits=64):
        if not _x64_enabled():
            raise ValueError(
                f"packed_stable_sort: key for n={n}, max_major={max_major} "
                "needs a uint64 pack but jax_enable_x64 is off — enable x64 "
                "(JAX_ENABLE_X64=1) or use the lexsort path")
        dt = jnp.uint64
    else:
        raise ValueError(
            f"packed_stable_sort: (major, index) for n={n}, "
            f"max_major={max_major} exceeds 64 bits — use the lexsort path")
    idx = jnp.arange(n, dtype=jnp.int32)
    shift = dt(1 << idx_bits)
    packed = major.astype(dt) * shift + idx.astype(dt)
    keys = jnp.sort(packed)
    order = (keys & dt((1 << idx_bits) - 1)).astype(jnp.int32)
    major_s = (keys // shift).astype(jnp.int32)
    # keys are unique, so each row's sorted position == binary search
    pos = jnp.searchsorted(keys, packed,
                           method="scan_unrolled").astype(jnp.int32)
    return order, major_s, pos


def partition_permutation(major: jnp.ndarray, rank: jnp.ndarray,
                          counts: jnp.ndarray):
    """(starts, pos, order) of the stable partition from its one-pass
    (rank, counts): exclusive bucket offsets, each row's sorted position
    by direct arithmetic, and the inverted permutation.  The ONE place
    this assembly lives — shared by the chain geometry below and the
    exchange bucketing (``ownership.bucket_by_owner``)."""
    n = major.shape[0]
    starts = (jnp.cumsum(counts) - counts).astype(jnp.int32)     # exclusive
    pos = jnp.take(starts, major) + rank                         # direct
    order = jnp.zeros((n,), jnp.int32).at[pos].set(
        jnp.arange(n, dtype=jnp.int32))
    return starts, pos, order


def _partition_chains(major: jnp.ndarray, n_buckets: int, *,
                      use_pallas: bool = False,
                      rank_counts=None, geometry: bool = True,
                      block_rows: Optional[int] = None):
    """Stable counting partition of one batch: the full chain geometry
    from ONE pass over the keys (rank + histogram), no sort, no binary
    search, no flag-compare pass.

    Returns ``(order, major_sorted, Chains)``; ``rank_counts`` lets the
    stream driver inject a batched kernel result.  ``geometry=False``
    skips the per-row seg_id/pos/seg_end scatters that only the staged
    segscan path reads — the fused megakernel rebuilds the flags it needs
    in VMEM, so its plan carries just order/inv/seg_start + histograms.
    """
    from repro.kernels.radix_partition.ops import radix_partition_rank

    n = major.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    if rank_counts is None:
        rank, counts = radix_partition_rank(major, n_buckets,
                                            use_pallas=use_pallas,
                                            block_rows=block_rows)
    else:
        rank, counts = rank_counts
    starts, inv, order = partition_permutation(major, rank, counts)
    major_s = jnp.take(major, order)
    nz = counts > 0
    # segment geometry straight from the histogram (empty buckets -> drop)
    seg_start = jnp.zeros((n,), bool).at[
        jnp.where(nz, starts, n)].set(True, mode="drop")
    if geometry:
        seg_end = jnp.zeros((n,), bool).at[
            jnp.where(nz, starts + counts - 1, n)].set(True, mode="drop")
        seg_id = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
        pos = idx - jnp.take(starts, major_s)
    else:
        seg_end = seg_id = pos = None
    chains = Chains(
        order=order, inv=inv, seg_start=seg_start, seg_id=seg_id, pos=pos,
        seg_end=seg_end, n_chains=jnp.sum(nz.astype(jnp.int32)),
        max_len=jnp.max(counts), counts=counts, starts=starts)
    return order, major_s, chains


def _sorted_chains(uid_s: jnp.ndarray, order: jnp.ndarray,
                   inv: jnp.ndarray) -> Chains:
    """Chain geometry from a sorted uid column (the sort backbones)."""
    n = uid_s.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), uid_s[1:] != uid_s[:-1]])
    seg_id = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    start_idx = jax.lax.cummax(jnp.where(seg_start, idx, 0))
    pos = idx - start_idx
    seg_end = jnp.concatenate(
        [uid_s[1:] != uid_s[:-1], jnp.ones((1,), bool)])
    return Chains(order=order, inv=inv, seg_start=seg_start, seg_id=seg_id,
                  pos=pos, seg_end=seg_end, n_chains=seg_id[-1] + 1,
                  max_len=jnp.max(pos) + 1)


def _sorted_view(ops: OpBatch, uid_s: jnp.ndarray, order: jnp.ndarray,
                 light: bool) -> OpBatch:
    return OpBatch(
        uid=uid_s,
        ts=None if light else jnp.take(ops.ts, order),
        txn=None if light else jnp.take(ops.txn, order),
        slot=None if light else jnp.take(ops.slot, order),
        kind=None if light else jnp.take(ops.kind, order),
        fun=jnp.take(ops.fun, order),
        gate=None if light else jnp.take(ops.gate, order),
        operand=jnp.take(ops.operand, order, axis=0),
        valid=jnp.take(ops.valid, order),
    )


def restructure(ops: OpBatch, pad_uid: int, *,
                rowmajor_ts: bool = False,
                light: bool = False,
                method: str = "auto",
                use_pallas: bool = False,
                geometry: bool = True) -> Tuple[OpBatch, Chains]:
    """Group the op batch into operation chains.

    Invalid (padding) ops are routed to the padding chain (uid = pad_uid)
    and group to the end; chain order within a state follows (ts, slot) so
    that a transaction's intra-state ops keep their registration order.

    ``rowmajor_ts``: caller's promise that flat row order already equals
    (ts, slot) lexicographic order — true for every batch built by
    ``build_opbatch`` (ts = ts_base + txn, rows laid out (txn, slot)).
    Then the stable tie-break is the flat row index, and the backbone is
    chosen by ``restructure_path``: the one-pass counting partition
    (O(N + K), with the commit histograms as a by-product), else the
    packed single-operand sort, else the generic lexsort.  All backbones
    produce bit-identical output.

    ``light``: gather only the columns the segmented-scan path reads
    (uid, fun, operand, valid); ts/txn/slot/kind/gate are ``None`` in the
    returned sorted batch.  Lockstep/mvlk callers need the full view.

    ``method``: force a backbone ("partition" / "packed" / "lexsort");
    "auto" resolves the ladder.  ``use_pallas`` lets the partition path
    use the Pallas kernel when its bucket bound holds.  ``geometry=False``
    (partition path only) builds the megakernel's light plan — see
    ``_partition_chains``.
    """
    uid = jnp.where(ops.valid, ops.uid, pad_uid)
    n = uid.shape[0]
    path = restructure_path(n, pad_uid, rowmajor_ts=rowmajor_ts,
                            method=method)

    if path == "partition":
        order, uid_s, chains = _partition_chains(uid, pad_uid + 1,
                                                 use_pallas=use_pallas,
                                                 geometry=geometry)
    elif path == "packed":
        order, uid_s, inv = packed_stable_sort(uid, pad_uid)
        chains = _sorted_chains(uid_s, order, inv)
    else:
        idx = jnp.arange(n, dtype=jnp.int32)
        order = jnp.lexsort((ops.slot, ops.ts, uid))  # uid major, ts, slot
        uid_s = jnp.take(uid, order)
        inv = jnp.zeros((n,), jnp.int32).at[order].set(idx)
        chains = _sorted_chains(uid_s, order, inv)

    return _sorted_view(ops, uid_s, order, light), chains


def restructure_stream(ops_all: OpBatch, pad_uid: int, *,
                       rowmajor_ts: bool = False,
                       light: bool = False,
                       method: str = "auto",
                       use_pallas: bool = False,
                       geometry: bool = True,
                       block_rows: Optional[int] = None):
    """Batched restructure over stacked ``[n_intervals, N]`` op batches.

    On the partition path the within-bucket ranks and histograms for ALL
    intervals come from ONE (kernel) dispatch — the fused drivers' hoisted
    one-pass plan; only the cheap geometry assembly is vmapped.  Other
    paths vmap the per-batch restructure unchanged.  ``geometry=False``
    (partition path only) builds the megakernel's light plan — see
    ``_partition_chains``.
    """
    n = ops_all.uid.shape[-1]
    path = restructure_path(n, pad_uid, rowmajor_ts=rowmajor_ts,
                            method=method)
    if path != "partition":
        return jax.vmap(lambda o: restructure(
            o, pad_uid, rowmajor_ts=rowmajor_ts, light=light,
            method=path))(ops_all)

    from repro.kernels.radix_partition.ops import radix_partition_rank
    uid = jnp.where(ops_all.valid, ops_all.uid, pad_uid)   # [n_i, N]
    rank, counts = radix_partition_rank(uid, pad_uid + 1,
                                        use_pallas=use_pallas,
                                        block_rows=block_rows)

    def assemble(o, u, r, c):
        order, uid_s, chains = _partition_chains(u, pad_uid + 1,
                                                 rank_counts=(r, c),
                                                 geometry=geometry)
        return _sorted_view(o, uid_s, order, light), chains

    return jax.vmap(assemble)(ops_all, uid, rank, counts)


def commit_index(uid_sorted: jnp.ndarray, n_slots_incl_pad: int):
    """Per-state commit gather map from the sorted uid column.

    Returns ``(pos, ok)`` with ``pos[u]`` = sorted index of the *last* op
    of chain ``u`` and ``ok[u]`` = chain ``u`` has ops in this batch.  The
    state update then becomes a [S+1] gather + select instead of an [N]
    scatter (CPU/TPU scatters serialize; binary search vectorizes).

    The partition path does not need this: its histogram gives the same
    map directly (``commit_from_histogram``).
    """
    slots = jnp.arange(n_slots_incl_pad, dtype=uid_sorted.dtype)
    pos = jnp.searchsorted(uid_sorted, slots, side="right",
                           method="scan_unrolled") - 1
    ok = (pos >= 0) & (jnp.take(uid_sorted, jnp.maximum(pos, 0)) == slots)
    return jnp.maximum(pos, 0), ok


def commit_from_histogram(counts: jnp.ndarray, starts: jnp.ndarray):
    """Commit gather map from the partition histogram: the last op of
    chain ``u`` sits at ``starts[u] + counts[u] - 1`` — bit-identical to
    ``commit_index`` (searchsorted-right of u == starts[u] + counts[u])
    with the two binary-search passes gone."""
    pos = jnp.maximum(starts + counts - 1, 0).astype(jnp.int32)
    return pos, counts > 0


def segmented_scan_affine(a: jnp.ndarray, b: jnp.ndarray,
                          seg_start: jnp.ndarray,
                          exclusive: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Segmented scan of affine maps f(v) = a*v + b under composition.

    Composition (applied left-to-right): (a2,b2)∘(a1,b1) = (a2*a1, a2*b1+b2).
    Returns per-op (A, B) such that the state seen by op i within its chain is
    A_i * v0 + B_i (exclusive) — the paper's multiversion value at ts_i.

    Implemented as an explicit log-step Hillis–Steele sweep with
    segment-flag blocking (the same scheme the Pallas kernel uses inside a
    block).  Unlike ``lax.associative_scan`` — whose combine tree depends
    on an element's *global* array offset — the association here is fixed
    by each op's position **within its segment**, so a chain produces
    bit-identical results wherever it sits in the array.  The sharded
    fused driver relies on this: the same chain lands at different offsets
    on different devices and must still match the single-device schedule
    bit for bit (DESIGN.md §2.5).
    """
    n = a.shape[0]
    f = seg_start
    a_inc, b_inc = a, b
    d = 1
    while d < n:
        ap = jnp.concatenate([jnp.ones_like(a_inc[:d]), a_inc[:-d]], axis=0)
        bp = jnp.concatenate([jnp.zeros_like(b_inc[:d]), b_inc[:-d]], axis=0)
        fp = jnp.concatenate([jnp.ones((d,), bool), f[:-d]])
        blocked = f[:, None]
        a_inc, b_inc = (jnp.where(blocked, a_inc, a_inc * ap),
                        jnp.where(blocked, b_inc, a_inc * bp + b_inc))
        f = f | fp
        d *= 2
    if not exclusive:
        return a_inc, b_inc
    # shift right within segments: identity at segment starts.
    ident_a = jnp.ones_like(a[:1])
    ident_b = jnp.zeros_like(b[:1])
    a_exc = jnp.concatenate([ident_a, a_inc[:-1]], axis=0)
    b_exc = jnp.concatenate([ident_b, b_inc[:-1]], axis=0)
    a_exc = jnp.where(seg_start[:, None], jnp.ones_like(a_exc), a_exc)
    b_exc = jnp.where(seg_start[:, None], jnp.zeros_like(b_exc), b_exc)
    return a_exc, b_exc


def segmented_scan_max(m: jnp.ndarray, seg_start: jnp.ndarray,
                       exclusive: bool = True) -> jnp.ndarray:
    """Segmented running max (for max-type tables, e.g. LPC sketches).

    Same segment-relative Hillis–Steele sweep as the affine scan (max is
    order-insensitive, but the uniform structure keeps the two paths'
    round counts identical).
    """
    neg = jnp.full_like(m, -jnp.inf)
    n = m.shape[0]
    f = seg_start
    m_inc = m
    d = 1
    while d < n:
        mp = jnp.concatenate([neg[:d], m_inc[:-d]], axis=0)
        fp = jnp.concatenate([jnp.ones((d,), bool), f[:-d]])
        m_inc = jnp.where(f[:, None], m_inc, jnp.maximum(m_inc, mp))
        f = f | fp
        d *= 2
    if not exclusive:
        return m_inc
    m_exc = jnp.concatenate([neg[:1], m_inc[:-1]], axis=0)
    return jnp.where(seg_start[:, None], neg, m_exc)
