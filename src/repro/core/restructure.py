"""Dynamic restructuring (paper §IV-C1): transactions -> operation chains.

The paper decomposes each postponed transaction into per-state operations and
inserts them into timestamp-sorted per-state lists (operation chains) via a
concurrent skip list.  The TPU-native equivalent is a stable lexicographic
sort by (state uid, ts, slot): after sorting, each chain is a contiguous
segment, already timestamp-ordered.  Sorting is deterministic, O(N log N),
and — unlike a concurrent data structure — meaningful in SPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .types import OpBatch


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Chains:
    """Operation chains over a sorted view of an OpBatch.

    ``order``     : sorted index -> original flat op index (gather map)
    ``inv``       : original flat op index -> sorted index (inverse of
                    ``order``; lets results return to (txn, slot) layout by
                    *gather* instead of the much slower CPU/TPU scatter)
    ``seg_start`` : bool[N], True at the first op of each chain
    ``seg_id``    : chain id of each sorted op (== cumsum(seg_start)-1)
    ``pos``       : position of the op inside its chain (ts order)
    ``seg_end``   : True at the last op of each chain
    ``n_chains``  : traced scalar, number of distinct chains
    ``max_len``   : traced scalar, longest chain (lockstep round count)
    """

    order: jnp.ndarray
    inv: jnp.ndarray
    seg_start: jnp.ndarray
    seg_id: jnp.ndarray
    pos: jnp.ndarray
    seg_end: jnp.ndarray
    n_chains: jnp.ndarray
    max_len: jnp.ndarray

    def take(self, x: jnp.ndarray) -> jnp.ndarray:
        """Gather a flat (pre-sort) per-op array into sorted chain order."""
        return jnp.take(x, self.order, axis=0)

    def untake(self, x_sorted: jnp.ndarray) -> jnp.ndarray:
        """Gather a sorted per-op array back into flat (pre-sort) layout."""
        return jnp.take(x_sorted, self.inv, axis=0)


def restructure(ops: OpBatch, pad_uid: int, *,
                rowmajor_ts: bool = False,
                light: bool = False) -> Tuple[OpBatch, Chains]:
    """Sort the op batch into operation chains.

    Invalid (padding) ops are routed to the padding chain (uid = pad_uid) and
    sort to the end; chain order within a state follows (ts, slot) so that a
    transaction's intra-state ops keep their registration order.

    ``rowmajor_ts``: caller's promise that flat row order already equals
    (ts, slot) lexicographic order — true for every batch built by
    ``build_opbatch`` (ts = ts_base + txn, rows laid out (txn, slot)).
    Then the 3-key lexsort collapses to a *single-operand* sort of
    ``uid << idx_bits | index`` packed keys — ~6x faster on CPU XLA and
    identical output (the packed low bits are exactly the stable
    tie-break), and the inverse permutation comes from a vectorized binary
    search instead of a scatter.  Falls back to the generic lexsort when
    the packed key would not fit 32 bits.

    ``light``: gather only the columns the segmented-scan path reads
    (uid, fun, operand, valid); ts/txn/slot/kind/gate are ``None`` in the
    returned sorted batch.  Lockstep/mvlk callers need the full view.
    """
    uid = jnp.where(ops.valid, ops.uid, pad_uid)
    n = uid.shape[0]
    idx_bits = max(n - 1, 1).bit_length()
    uid_bits = max(int(pad_uid), 1).bit_length()
    packed_ok = rowmajor_ts and (idx_bits + uid_bits) <= 32

    idx = jnp.arange(n, dtype=jnp.int32)
    if packed_ok:
        shift = jnp.uint32(1 << idx_bits)
        keys = jnp.sort(uid.astype(jnp.uint32) * shift
                        + idx.astype(jnp.uint32))
        order = (keys & jnp.uint32((1 << idx_bits) - 1)).astype(jnp.int32)
        uid_s = (keys // shift).astype(jnp.int32)
        # inverse permutation: keys are unique, so position == binary search
        inv = jnp.searchsorted(keys, uid.astype(jnp.uint32) * shift
                               + idx.astype(jnp.uint32),
                               method="scan_unrolled").astype(jnp.int32)
    else:
        order = jnp.lexsort((ops.slot, ops.ts, uid))  # uid major, ts, slot
        uid_s = jnp.take(uid, order)
        inv = jnp.zeros((n,), jnp.int32).at[order].set(idx)

    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), uid_s[1:] != uid_s[:-1]])
    seg_id = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    start_idx = jax.lax.cummax(jnp.where(seg_start, idx, 0))
    pos = idx - start_idx
    seg_end = jnp.concatenate(
        [uid_s[1:] != uid_s[:-1], jnp.ones((1,), bool)])

    sorted_ops = OpBatch(
        uid=uid_s,
        ts=None if light else jnp.take(ops.ts, order),
        txn=None if light else jnp.take(ops.txn, order),
        slot=None if light else jnp.take(ops.slot, order),
        kind=None if light else jnp.take(ops.kind, order),
        fun=jnp.take(ops.fun, order),
        gate=None if light else jnp.take(ops.gate, order),
        operand=jnp.take(ops.operand, order, axis=0),
        valid=jnp.take(ops.valid, order),
    )
    chains = Chains(
        order=order,
        inv=inv,
        seg_start=seg_start,
        seg_id=seg_id,
        pos=pos,
        seg_end=seg_end,
        n_chains=seg_id[-1] + 1,
        max_len=jnp.max(pos) + 1,
    )
    return sorted_ops, chains


def commit_index(uid_sorted: jnp.ndarray, n_slots_incl_pad: int):
    """Per-state commit gather map from the sorted uid column.

    Returns ``(pos, ok)`` with ``pos[u]`` = sorted index of the *last* op
    of chain ``u`` and ``ok[u]`` = chain ``u`` has ops in this batch.  The
    state update then becomes a [S+1] gather + select instead of an [N]
    scatter (CPU/TPU scatters serialize; binary search vectorizes).
    """
    slots = jnp.arange(n_slots_incl_pad, dtype=uid_sorted.dtype)
    pos = jnp.searchsorted(uid_sorted, slots, side="right",
                           method="scan_unrolled") - 1
    ok = (pos >= 0) & (jnp.take(uid_sorted, jnp.maximum(pos, 0)) == slots)
    return jnp.maximum(pos, 0), ok


def segmented_scan_affine(a: jnp.ndarray, b: jnp.ndarray,
                          seg_start: jnp.ndarray,
                          exclusive: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Segmented scan of affine maps f(v) = a*v + b under composition.

    Composition (applied left-to-right): (a2,b2)∘(a1,b1) = (a2*a1, a2*b1+b2).
    Returns per-op (A, B) such that the state seen by op i within its chain is
    A_i * v0 + B_i (exclusive) — the paper's multiversion value at ts_i.

    Pure-jnp reference path; the Pallas kernel in ``repro.kernels.segscan``
    implements the same contract for the TPU target.
    """
    flag = seg_start

    def combine(x, y):
        f1, a1, b1 = x
        f2, a2, b2 = y
        f2e = f2[..., None]
        a = jnp.where(f2e, a2, a2 * a1)
        b = jnp.where(f2e, b2, a2 * b1 + b2)
        return (f1 | f2, a, b)

    _, a_inc, b_inc = jax.lax.associative_scan(combine, (flag, a, b))
    if not exclusive:
        return a_inc, b_inc
    # shift right within segments: identity at segment starts.
    ident_a = jnp.ones_like(a[:1])
    ident_b = jnp.zeros_like(b[:1])
    a_exc = jnp.concatenate([ident_a, a_inc[:-1]], axis=0)
    b_exc = jnp.concatenate([ident_b, b_inc[:-1]], axis=0)
    a_exc = jnp.where(seg_start[:, None], jnp.ones_like(a_exc), a_exc)
    b_exc = jnp.where(seg_start[:, None], jnp.zeros_like(b_exc), b_exc)
    return a_exc, b_exc


def segmented_scan_max(m: jnp.ndarray, seg_start: jnp.ndarray,
                       exclusive: bool = True) -> jnp.ndarray:
    """Segmented running max (for max-type tables, e.g. LPC sketches)."""
    neg = jnp.full_like(m, -jnp.inf)
    flag = seg_start

    def combine(x, y):
        f1, m1 = x
        f2, m2 = y
        return (f1 | f2, jnp.where(f2[..., None], m2, jnp.maximum(m1, m2)))

    _, m_inc = jax.lax.associative_scan(combine, (flag, m))
    if not exclusive:
        return m_inc
    m_exc = jnp.concatenate([neg[:1], m_inc[:-1]], axis=0)
    return jnp.where(seg_start[:, None], neg, m_exc)
