"""Transaction-processing engines: TStream (D2) + re-implemented baselines.

All engines share one contract::

    evaluate(store, ops, funs, ...) -> (OpResults_flat, new_values, stats)

``OpResults_flat`` is in *pre-sort* flat layout ([N] rows aligned with
(txn, slot)), so the scheduler can reshape it straight back into per-event
blotters.  ``stats`` carries structural parallelism counters (rounds, chain
counts) consumed by the benchmark harness's executor model.

The O(N log N) ``restructure`` lexsort runs **exactly once per evaluated
batch**: callers that already hold the sorted view pass it via
``prestructured=(sops, chains)`` and every chain-based scheme (tstream
variants + mvlk) threads it through instead of re-sorting.  A batch whose
``valid`` mask was tightened *after* sorting (the scheduler's abort repass)
is still legal input: chain geometry only depends on uids, and all paths
neutralize invalid mid-chain ops.

Schemes (see DESIGN.md §2 for the multicore->TPU schedule mapping):

* ``tstream``   — D2 dynamic restructuring.  Associative-only apps take the
                  segmented-scan fast path (log-depth chains); otherwise the
                  lockstep path walks all chains in parallel, one op per chain
                  per round (the paper's one-thread-per-chain walk).  Gated
                  ops (cross-chain CFun deps) are scheduled level-wise like
                  the paper's iterative process; unresolved residue (cycles)
                  falls back to the sequential oracle for affected ops.
* ``lock``      — S2PL + lockAhead schedule: conflict-equivalent global ts
                  order, one transaction at a time (depth N).  Doubles as the
                  correctness oracle.
* ``mvlk``      — multiversion locking: writes serialize per state, reads are
                  served from versions in parallel.
* ``pat``       — S-Store partition-level locking: partitions advance their
                  ts-ordered fronts; a multi-partition transaction fires only
                  when it is at the front of *all* its partitions.
* ``nolock``    — no ordering (upper bound, deliberately incorrect).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .restructure import (Chains, commit_from_histogram, commit_index,
                          restructure, segmented_scan_affine,
                          segmented_scan_max)
from .types import FunSpec, OpBatch, OpKind, StateStore

Prestructured = Tuple[OpBatch, Chains]


# ---------------------------------------------------------------------------
# Fun application
# ---------------------------------------------------------------------------
def apply_funs(funs: Tuple[FunSpec, ...], fun_id: jnp.ndarray,
               pre: jnp.ndarray, operand: jnp.ndarray):
    """Vectorized lax.switch over the app's fun family.

    pre, operand: [N, W] -> (post [N, W], success bool[N]).
    """
    branches = [f.apply for f in funs]

    def one(fid, p, o):
        return jax.lax.switch(fid, branches, p, o)

    return jax.vmap(one)(fun_id, pre, operand)


def affine_coeffs(funs: Tuple[FunSpec, ...], fun_id: jnp.ndarray,
                  operand: jnp.ndarray):
    """Per-op (a, b) affine coefficients; identity for non-affine funs.

    When every fun declares a simple affine shape (``affine_simple``:
    a ∈ {0, 1}, b ∈ {0, operand} — true for the whole core family), the
    vmapped 5-branch switch collapses to two tiny LUT gathers + a select,
    with bit-identical outputs.
    """
    simple = [f.affine_simple if f.affine is not None else (1.0, False)
              for f in funs]
    if all(s is not None for s in simple):
        a_lut = jnp.asarray([s[0] for s in simple], operand.dtype)
        b_lut = jnp.asarray([s[1] for s in simple])
        a = jnp.broadcast_to(jnp.take(a_lut, fun_id)[:, None], operand.shape)
        b = jnp.where(jnp.take(b_lut, fun_id)[:, None], operand,
                      jnp.zeros_like(operand))
        return a, b

    branches = [(f.affine if f.affine is not None else (lambda o: (jnp.ones_like(o), jnp.zeros_like(o))))
                for f in funs]

    def one(fid, o):
        return jax.lax.switch(fid, branches, o)

    return jax.vmap(one)(fun_id, operand)


def simple_affine_luts(funs: Tuple[FunSpec, ...]):
    """(a_lut f32[n_funs], b_lut bool[n_funs]) when EVERY fun declares a
    simple affine shape (a ∈ {0, 1}, b ∈ {0, operand}; non-affine funs
    count as identity) — the whole-app precondition for the fused
    megakernel, whose in-VMEM coefficient expansion is these two gathers.
    Returns None when any fun is not simple-affine.
    """
    simple = [f.affine_simple if f.affine is not None else (1.0, False)
              for f in funs]
    if not all(s is not None for s in simple):
        return None
    return (jnp.asarray([s[0] for s in simple], jnp.float32),
            jnp.asarray([s[1] for s in simple]))


def _gate_open(gate: jnp.ndarray, success_flat: jnp.ndarray) -> jnp.ndarray:
    """CFun gating: open when ungated, else the mate op's recorded success."""
    return jnp.where(gate >= 0, jnp.take(success_flat, jnp.maximum(gate, 0)), True)


@dataclasses.dataclass
class EngineStats:
    """Structural parallelism counters for the executor cost model."""
    rounds: jnp.ndarray          # sequential depth of the schedule
    n_chains: jnp.ndarray        # parallel width available
    max_chain: jnp.ndarray       # longest chain
    n_ops: int                   # total decomposed ops (incl. padding)
    scheme: str = ""
    path: str = ""               # "segscan" | "lockstep" | ...


jax.tree_util.register_dataclass(
    EngineStats, data_fields=["rounds", "n_chains", "max_chain"],
    meta_fields=["n_ops", "scheme", "path"])


def _empty_results(n: int, w: int):
    return dict(pre=jnp.zeros((n + 1, w)), post=jnp.zeros((n + 1, w)),
                success=jnp.zeros((n + 1,), bool))


# ---------------------------------------------------------------------------
# TStream fast path: segmented-scan chain evaluation (associative funs only)
#
# Split into three stages so the fused stream driver can hoist everything
# values-independent out of its sequential interval scan (DESIGN.md §2.4):
#
#   plan    = tstream_scan_plan(...)        restructure + coefficients +
#                                           commit gather map (per batch)
#   plan    = tstream_scan_coefs(plan)      exclusive segmented scans
#   results = tstream_scan_execute(values, plan)   the only values-dependent
#                                           part: v0 gather, Fun application,
#                                           commit — O(N) elementwise+gather
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScanPlan:
    """Values-independent plan for one batch on the segmented-scan path.

    ``af``/``bf``/``mx`` hold the per-op affine / max *coefficients* after
    ``tstream_scan_plan`` and the *exclusive segmented scans* of those
    coefficients after ``tstream_scan_coefs``; ``afi``/``bfi``/``mxi`` are
    the *inclusive* scans (None until coefs run).  With every fun on this
    path associative-affine (or max-on-max-table), pre/post are pure
    coefficient applications — no Fun dispatch in the values-dependent
    stage.  ``commit_pos``/``commit_ok`` are the [S+1] per-state commit
    gather map (see ``commit_index``).
    """

    sops: OpBatch
    ch: Chains
    af: jnp.ndarray
    bf: jnp.ndarray
    afi: Optional[jnp.ndarray]
    bfi: Optional[jnp.ndarray]
    mx: Optional[jnp.ndarray]        # None when the store has no max tables
    mxi: Optional[jnp.ndarray]
    is_max_s: Optional[jnp.ndarray]  # (statically elided — saves a scan)
    commit_pos: jnp.ndarray
    commit_ok: jnp.ndarray


def tstream_scan_plan(store: StateStore, ops: OpBatch,
                      funs: Tuple[FunSpec, ...], *,
                      prestructured: Optional[Prestructured] = None,
                      rowmajor_ts: bool = False,
                      restructure_method: str = "auto",
                      use_pallas: bool = False) -> ScanPlan:
    # the scan path evaluates ops purely from (scanned) coefficients: every
    # fun must be associative (affine family or max) — conditional funs
    # like TAKE belong on the lockstep path and would silently mis-evaluate
    # here (identity post, success always True)
    bad = [f.name for f in funs if not f.associative]
    if bad:
        raise ValueError(
            f"tstream_scan requires associative funs; got {bad} — use the "
            "lockstep path (scheme='tstream'/'tstream_lockstep') instead")
    sops, ch = (restructure(ops, store.pad_uid, rowmajor_ts=rowmajor_ts,
                            light=True, method=restructure_method,
                            use_pallas=use_pallas)
                if prestructured is None else prestructured)
    has_max = any(store.table_is_max)

    # affine family coefficients (non-affine, max-table and invalid ops
    # become identity — invalid ops can sit mid-chain when a prestructured
    # batch had its valid mask tightened after sorting)
    a, b = affine_coeffs(funs, sops.fun, sops.operand)
    if has_max:
        is_max_s = jnp.take(store.uid_is_max(), sops.uid)  # [N]
        neutralize = (is_max_s | ~sops.valid)[:, None]
    else:
        is_max_s = None
        neutralize = (~sops.valid)[:, None]
    a = jnp.where(neutralize, jnp.ones_like(a), a)
    b = jnp.where(neutralize, jnp.zeros_like(b), b)

    # max family (ops on non-max tables, READs and invalid ops -> -inf);
    # statically elided when no table is max-typed
    m = None
    if has_max:
        is_max_fun = jnp.asarray([f.is_max for f in funs])[sops.fun]
        m = jnp.where((is_max_s & is_max_fun & sops.valid)[:, None],
                      sops.operand, -jnp.inf)

    # commit map: free from the partition path's histogram; otherwise two
    # binary-search passes over the sorted uid column
    if (ch.counts is not None
            and ch.counts.shape[-1] == store.values.shape[0]):
        commit_pos, commit_ok = commit_from_histogram(ch.counts, ch.starts)
    else:
        commit_pos, commit_ok = commit_index(sops.uid, store.values.shape[0])
    return ScanPlan(sops=sops, ch=ch, af=a, bf=b, afi=None, bfi=None,
                    mx=m, mxi=None, is_max_s=is_max_s,
                    commit_pos=commit_pos, commit_ok=commit_ok)


def tstream_scan_coefs(plan: ScanPlan, *, use_pallas: bool = False,
                       block_rows: Optional[int] = None) -> ScanPlan:
    """Segmented scans of the planned coefficients.

    Exclusive scans give each op's ``pre``; composing the op's own raw
    coefficient on top gives the *inclusive* scans and thereby ``post``
    without any per-op Fun dispatch at execution time.  ``block_rows``
    forces the Pallas kernel's block shape (None -> autotuned).
    """
    if use_pallas:
        from repro.kernels.segscan import ops as segscan_ops
        A, B = segscan_ops.segscan_affine(plan.af, plan.bf,
                                          plan.ch.seg_start, exclusive=True,
                                          block_rows=block_rows)
        M = (segscan_ops.segscan_max(plan.mx, plan.ch.seg_start,
                                     exclusive=True, block_rows=block_rows)
             if plan.mx is not None else None)
    else:
        A, B = segmented_scan_affine(plan.af, plan.bf, plan.ch.seg_start,
                                     exclusive=True)
        M = (segmented_scan_max(plan.mx, plan.ch.seg_start, exclusive=True)
             if plan.mx is not None else None)
    return _compose_inclusive(plan, A, B, M)


def _compose_inclusive(plan: ScanPlan, A, B, M) -> ScanPlan:
    """inclusive = raw ∘ exclusive (the op applied on top of its pre)."""
    Ai = plan.af * A
    Bi = plan.af * B + plan.bf
    Mi = jnp.maximum(M, plan.mx) if M is not None else None
    return dataclasses.replace(plan, af=A, bf=B, afi=Ai, bfi=Bi,
                               mx=M, mxi=Mi)


def tstream_scan_coefs_stream(plan_all: ScanPlan, *,
                              use_pallas: bool = False,
                              block_rows: Optional[int] = None) -> ScanPlan:
    """Coefficient scans for a whole stream of stacked [n_intervals, N]
    plans.  Non-Pallas: vmapped per-interval scans (bit-identical to the
    per-interval driver).  Pallas: ONE kernel dispatch over the flattened
    stream — per-interval seg_start flags isolate the scans.
    ``block_rows`` forces the kernel block shape (None -> autotuned).
    """
    if not use_pallas:
        return jax.vmap(tstream_scan_coefs)(plan_all)
    from repro.kernels.segscan import ops as segscan_ops
    bn, n, w = plan_all.af.shape
    flags = plan_all.ch.seg_start.reshape(bn * n)
    A, B = segscan_ops.segscan_affine(plan_all.af.reshape(bn * n, w),
                                      plan_all.bf.reshape(bn * n, w),
                                      flags, exclusive=True,
                                      block_rows=block_rows)
    A, B = A.reshape(bn, n, w), B.reshape(bn, n, w)
    M = None
    if plan_all.mx is not None:
        M = segscan_ops.segscan_max(plan_all.mx.reshape(bn * n, w), flags,
                                    exclusive=True,
                                    block_rows=block_rows).reshape(bn, n, w)
    return _compose_inclusive(plan_all, A, B, M)


def tstream_scan_execute(values: jnp.ndarray, plan: ScanPlan,
                         pad_uid: int, *, raw: bool = False):
    """Values-dependent stage: O(N) gathers/elementwise + one [S+1] select.

    ``raw=True`` returns results in *sorted* chain layout (the fused driver
    gathers back to flat layout in one batched pass after its scan).
    """
    sops, ch = plan.sops, plan.ch
    n = sops.uid.shape[0]
    v0 = jnp.take(values, sops.uid, axis=0)                # [N, W]
    pre = plan.af * v0 + plan.bf
    post = plan.afi * v0 + plan.bfi
    if plan.mx is not None:
        mmask = plan.is_max_s[:, None]
        pre = jnp.where(mmask, jnp.maximum(v0, plan.mx), pre)
        post = jnp.where(mmask, jnp.maximum(v0, plan.mxi), post)
    # every fun on this path is associative -> unconditionally successful;
    # invalid ops were neutralized to identity, so their post == pre
    success = sops.valid

    # commit: last op of each chain defines the new state value.  The
    # update is a per-state gather + select, not an [N] scatter.
    committed = jnp.take(post, plan.commit_pos, axis=0)         # [S+1, W]
    new_values = jnp.where(plan.commit_ok[:, None], committed, values)
    new_values = new_values.at[pad_uid].set(0.0)

    # invalid (padding) ops record nothing — match the oracle's layout
    vmask = sops.valid
    pre = jnp.where(vmask[:, None], pre, 0.0)
    post = jnp.where(vmask[:, None], post, 0.0)
    success = success & vmask
    res = dict(pre=pre, post=post, success=success)
    if not raw:
        res = {k: ch.untake(v) for k, v in res.items()}
    stats = EngineStats(
        rounds=jnp.ceil(jnp.log2(ch.max_len.astype(jnp.float32) + 1)),
        n_chains=ch.n_chains, max_chain=ch.max_len,
        n_ops=n, scheme="tstream", path="segscan")
    return res, new_values, stats


def eval_tstream_scan(store: StateStore, ops: OpBatch,
                      funs: Tuple[FunSpec, ...], *, use_pallas: bool = False,
                      prestructured: Optional[Prestructured] = None,
                      rowmajor_ts: bool = False,
                      restructure_method: str = "auto"):
    plan = tstream_scan_plan(store, ops, funs, prestructured=prestructured,
                             rowmajor_ts=rowmajor_ts,
                             restructure_method=restructure_method,
                             use_pallas=use_pallas)
    plan = tstream_scan_coefs(plan, use_pallas=use_pallas)
    return tstream_scan_execute(store.values, plan, store.pad_uid)


# ---------------------------------------------------------------------------
# TStream lockstep path: parallel chains, sequential within chain, level-wise
# dependency resolution (paper §IV-C2 Case 2).
# ---------------------------------------------------------------------------
def _chain_levels(sops: OpBatch, ch: Chains, n: int, max_levels: int):
    """Level-wise chain schedule for cross-chain CFun dependencies.

    level(C) = 0 if C has no gated ops, else 1 + max(level(mate chain)).
    Chains whose level does not resolve within ``max_levels`` (dependency
    cycles inside the batch) are flagged for the sequential fallback.
    """
    INF = jnp.int32(10 ** 6)
    # seg id of each op in pre-sort layout, so mate (flat idx) -> chain id
    seg_flat = ch.untake(ch.seg_id)
    gated = (sops.gate >= 0) & sops.valid
    mate_chain = seg_flat[jnp.maximum(sops.gate, 0)]
    chain_has_gate = jax.ops.segment_max(gated.astype(jnp.int32), ch.seg_id,
                                         num_segments=n) > 0
    lvl = jnp.where(chain_has_gate, INF, 0)

    def body(_, lvl):
        pred_lvl = jnp.where(gated, lvl[mate_chain], -1)
        need = jax.ops.segment_max(
            jnp.where(gated, jnp.minimum(pred_lvl + 1, INF), 0),
            ch.seg_id, num_segments=n)
        return jnp.where(chain_has_gate, jnp.minimum(need, INF), 0)

    lvl = jax.lax.fori_loop(0, max_levels, body, lvl)
    unresolved = lvl >= INF
    return lvl, unresolved


def _lockstep_sweep(values, sops: OpBatch, ch: Chains,
                    funs: Tuple[FunSpec, ...], chain_mask, results, n, pad_uid,
                    rounds):
    """Walk masked chains in lockstep: round r applies each chain's r-th op.

    Exactly one op per state per round -> conflict-free scatters, no locks.
    """
    def round_body(r, carry):
        values, res = carry
        active = (ch.pos == r) & jnp.take(chain_mask, ch.seg_id) & sops.valid
        cur = jnp.take(values, sops.uid, axis=0)
        # sops.gate holds the mate's *pre-sort* flat index; success is
        # recorded in pre-sort layout, so this gather is layout-consistent.
        gate_ok_s = _gate_open(sops.gate, res["success"][:-1])
        post, ok = apply_funs(funs, sops.fun, cur, sops.operand)
        post = jnp.where(gate_ok_s[:, None], post, cur)
        ok = ok & gate_ok_s
        scat = jnp.where(active, sops.uid, pad_uid)
        values = values.at[scat].set(jnp.where(active[:, None], post, 0.0))
        values = values.at[pad_uid].set(0.0)
        sink = jnp.where(active, ch.order, n)
        res = dict(
            pre=res["pre"].at[sink].set(cur),
            post=res["post"].at[sink].set(post),
            success=res["success"].at[sink].set(ok),
        )
        return values, res

    return jax.lax.fori_loop(0, rounds, round_body, (values, results))


def eval_tstream_lockstep(store: StateStore, ops: OpBatch,
                          funs: Tuple[FunSpec, ...], *, max_dep_levels: int = 3,
                          has_gates: bool = False,
                          prestructured: Optional[Prestructured] = None):
    sops, ch = (restructure(ops, store.pad_uid) if prestructured is None
                else prestructured)
    n = ops.n_ops
    values = store.values
    results = _empty_results(n, ops.width)

    if not has_gates:
        values, results = _lockstep_sweep(
            values, sops, ch, funs, jnp.ones((n,), bool), results, n,
            store.pad_uid, ch.max_len)
        rounds = ch.max_len
        unresolved_ops = jnp.zeros((n,), bool)
    else:
        lvl, unresolved = _chain_levels(sops, ch, n, max_dep_levels)
        rounds = jnp.int32(0)
        for L in range(max_dep_levels + 1):
            mask = (lvl == L)
            # this level's sweep only needs the longest level-L chain
            in_level = jnp.take(mask, ch.seg_id) & sops.valid
            lvl_rounds = jnp.max(jnp.where(in_level, ch.pos, -1)) + 1
            values, results = _lockstep_sweep(
                values, sops, ch, funs, mask, results, n, store.pad_uid,
                lvl_rounds)
            rounds = rounds + lvl_rounds
        # sequential fallback for ops in unresolved chains (cycles)
        unresolved_ops_sorted = jnp.take(unresolved, ch.seg_id) & sops.valid
        unresolved_ops = ch.untake(unresolved_ops_sorted)
        values, results = _sequential_sweep(values, ops, funs, results,
                                            mask_flat=unresolved_ops,
                                            pad_uid=store.pad_uid)
        rounds = rounds + jnp.sum(unresolved_ops)

    res = {k: v[:n] for k, v in results.items()}
    stats = EngineStats(rounds=rounds, n_chains=ch.n_chains,
                        max_chain=ch.max_len, n_ops=n,
                        scheme="tstream", path="lockstep")
    return res, values, stats


# ---------------------------------------------------------------------------
# Sequential oracle / LOCK schedule
# ---------------------------------------------------------------------------
def _sequential_sweep(values, ops: OpBatch, funs, results, *, mask_flat,
                      pad_uid):
    """Apply ops one at a time in global (ts, slot) order (S2PL schedule)."""
    n = ops.n_ops
    order = jnp.lexsort((ops.slot, ops.ts))  # global timestamp order

    def step(carry, i):
        values, res = carry
        j = order[i]
        run = mask_flat[j] & ops.valid[j]
        uid = jnp.where(run, ops.uid[j], pad_uid)
        cur = values[uid]
        gate = ops.gate[j]
        gate_ok = jnp.where(gate >= 0, res["success"][jnp.maximum(gate, 0)],
                            True)
        post, ok = funs_apply_single(funs, ops.fun[j], cur, ops.operand[j])
        post = jnp.where(gate_ok, post, cur)
        ok = ok & gate_ok
        values = values.at[uid].set(jnp.where(run, post, values[pad_uid]))
        values = values.at[pad_uid].set(0.0)
        sink = jnp.where(run, j, n)
        res = dict(
            pre=res["pre"].at[sink].set(cur),
            post=res["post"].at[sink].set(post),
            success=res["success"].at[sink].set(ok),
        )
        return (values, res), None

    (values, results), _ = jax.lax.scan(step, (values, results),
                                        jnp.arange(n))
    return values, results


def funs_apply_single(funs, fid, pre, operand):
    return jax.lax.switch(fid, [f.apply for f in funs], pre, operand)


def eval_lock(store: StateStore, ops: OpBatch, funs):
    """LOCK baseline == sequential oracle (conflict-equivalent ts order)."""
    n = ops.n_ops
    results = _empty_results(n, ops.width)
    values, results = _sequential_sweep(
        store.values, ops, funs, results,
        mask_flat=jnp.ones((n,), bool), pad_uid=store.pad_uid)
    results = {k: v[:n] for k, v in results.items()}
    stats = EngineStats(rounds=jnp.sum(ops.valid), n_chains=jnp.int32(1),
                        max_chain=jnp.sum(ops.valid), n_ops=n,
                        scheme="lock", path="sequential")
    return results, values, stats


# ---------------------------------------------------------------------------
# MVLK: multiversion — writes serialize per chain, reads resolve in parallel
# ---------------------------------------------------------------------------
def eval_mvlk(store: StateStore, ops: OpBatch, funs,
              *, has_gates: bool = False, max_dep_levels: int = 3,
              prestructured: Optional[Prestructured] = None):
    """Writes run as (lockstep) chains; READs are version lookups.

    Structurally: read ops are identity within chains (their ``pre`` is the
    version with the largest ts' < ts — exactly the paper's lwm-guarded
    multiversion read), so we can reuse the lockstep machinery; the *cost
    model* difference (reads don't occupy chain rounds) is reflected in the
    stats: rounds count only write-chain depth.
    """
    if prestructured is None:
        prestructured = restructure(ops, store.pad_uid)
    sops, ch = prestructured
    is_write = sops.kind != int(OpKind.READ)
    write_pos = _masked_positions(is_write, ch)
    write_depth = jnp.max(jnp.where(is_write, write_pos, -1)) + 1
    res, values, st = eval_tstream_lockstep(
        store, ops, funs, has_gates=has_gates, max_dep_levels=max_dep_levels,
        prestructured=prestructured)
    stats = EngineStats(rounds=write_depth, n_chains=ch.n_chains,
                        max_chain=st.max_chain, n_ops=ops.n_ops,
                        scheme="mvlk", path="mv")
    return res, values, stats


def _masked_positions(mask, ch: Chains):
    """Position of each op among *masked* ops of its chain."""
    inc = jnp.cumsum(mask.astype(jnp.int32))
    seg_base = jax.lax.cummax(jnp.where(ch.seg_start,
                                        inc - mask.astype(jnp.int32), 0))
    return inc - seg_base - mask.astype(jnp.int32)


# ---------------------------------------------------------------------------
# PAT: partition-level locking (S-Store)
# ---------------------------------------------------------------------------
def eval_pat(store: StateStore, ops: OpBatch, funs, *, n_partitions: int = 16):
    """Partitions advance ts-ordered fronts; a transaction fires only when it
    holds the front of *every* partition it touches (S-Store's counter-guarded
    partition-lock acquisition).  A txn's ops within one partition are
    contiguous after the (partition, ts, slot) sort, so readiness reduces to:
    each of the txn's per-partition blocks starts at that partition's front.
    """
    n = ops.n_ops
    part = jnp.where(ops.valid, ops.uid % n_partitions, n_partitions)
    order = jnp.lexsort((ops.slot, ops.ts, part))
    part_s = jnp.take(part, order)
    seg_start = jnp.concatenate([jnp.ones((1,), bool),
                                 part_s[1:] != part_s[:-1]])
    idx = jnp.arange(n, dtype=jnp.int32)
    start_idx = jax.lax.cummax(jnp.where(seg_start, idx, 0))
    pos = idx - start_idx

    sop = jax.tree_util.tree_map(lambda x: jnp.take(x, order, axis=0), ops)
    # (txn, partition) block structure — a txn's ops in one partition are
    # contiguous (same ts) and are executed under one lock acquisition.
    blk_start = seg_start | jnp.concatenate(
        [jnp.ones((1,), bool), sop.txn[1:] != sop.txn[:-1]])
    blk_start_idx = jax.lax.cummax(jnp.where(blk_start, idx, 0))
    blk_front_pos = jnp.take(pos, blk_start_idx)  # pos of block's first op
    blk_id = jnp.cumsum(blk_start.astype(jnp.int32)) - 1
    blk_len = jnp.take(
        jax.ops.segment_sum(jnp.ones((n,), jnp.int32), blk_id,
                            num_segments=n), blk_id)
    # same-uid runs inside a block execute sequentially (slot order)
    uidrun_start = blk_start | jnp.concatenate(
        [jnp.ones((1,), bool), sop.uid[1:] != sop.uid[:-1]])

    txn_total = jax.ops.segment_sum(ops.valid.astype(jnp.int32), ops.txn,
                                    num_segments=n)
    results = _empty_results(n, ops.width)
    values = store.values
    front = jnp.zeros((n_partitions + 1,), jnp.int32)
    part_len = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), part_s,
                                   num_segments=n_partitions + 1)
    fired = jnp.zeros((n,), bool)

    def cond(carry):
        values, res, front, fired, rounds = carry
        return (rounds < n) & jnp.any(~fired & sop.valid)

    def body(carry):
        values, res, front, fired, rounds = carry
        # the op's block holds its partition's lock: the front pointer lies
        # inside the block (a partially executed block keeps the lock).
        fr = jnp.take(front, part_s)
        block_at_front = (fr >= blk_front_pos) & (fr < blk_front_pos + blk_len)
        candidate = (block_at_front | fired) & sop.valid
        txn_cand = jax.ops.segment_sum(candidate.astype(jnp.int32), sop.txn,
                                       num_segments=n)
        ready = (txn_cand >= txn_total) & (txn_total > 0)
        prev_fired = jnp.concatenate([jnp.zeros((1,), bool), fired[:-1]])
        fire = block_at_front & ~fired & sop.valid \
            & jnp.take(ready, sop.txn) & (uidrun_start | prev_fired)
        cur = jnp.take(values, sop.uid, axis=0)
        # intra-txn gates: mates fire in the same round — resolve ungated first
        post0, ok0 = apply_funs(funs, sop.fun, cur, sop.operand)
        sink_now = jnp.where(fire & (sop.gate < 0), order, n)
        succ_now = jnp.zeros((n + 1,), bool).at[sink_now].set(ok0)
        succ_known = succ_now[:-1] | res["success"][:-1]
        gate_ok = jnp.where(sop.gate >= 0,
                            jnp.take(succ_known, jnp.maximum(sop.gate, 0)),
                            True)
        post = jnp.where(gate_ok[:, None], post0, cur)
        ok = ok0 & gate_ok
        scat = jnp.where(fire, sop.uid, store.pad_uid)
        values = values.at[scat].set(jnp.where(fire[:, None], post, 0.0))
        values = values.at[store.pad_uid].set(0.0)
        sink = jnp.where(fire, order, n)
        res = dict(pre=res["pre"].at[sink].set(cur),
                   post=res["post"].at[sink].set(post),
                   success=res["success"].at[sink].set(ok))
        fired = fired | fire
        adv = jax.ops.segment_sum(fire.astype(jnp.int32), part_s,
                                  num_segments=n_partitions + 1)
        front = front + adv
        return values, res, front, fired, rounds + 1

    values, results, front, fired, rounds = jax.lax.while_loop(
        cond, body, (values, results, front, fired, jnp.int32(0)))
    results = {k: v[:n] for k, v in results.items()}
    stats = EngineStats(rounds=rounds, n_chains=jnp.int32(n_partitions),
                        max_chain=jnp.max(part_len[:n_partitions]), n_ops=n,
                        scheme="pat", path="partition")
    return results, values, stats


# ---------------------------------------------------------------------------
# No-Lock upper bound (incorrect by design)
# ---------------------------------------------------------------------------
def eval_nolock(store: StateStore, ops: OpBatch, funs):
    pre = jnp.take(store.values, jnp.where(ops.valid, ops.uid, store.pad_uid),
                   axis=0)
    post, ok = apply_funs(funs, ops.fun, pre, ops.operand)
    scat = jnp.where(ops.valid & (ops.kind != int(OpKind.READ)), ops.uid,
                     store.pad_uid)
    values = store.values.at[scat].set(post)
    values = values.at[store.pad_uid].set(0.0)
    res = dict(pre=jnp.concatenate([pre, pre[:1]]),
               post=jnp.concatenate([post, post[:1]]),
               success=jnp.concatenate([ok, ok[:1]]))
    res = {k: v[: ops.n_ops] for k, v in res.items()}
    stats = EngineStats(rounds=jnp.int32(1), n_chains=jnp.int32(ops.n_ops),
                        max_chain=jnp.int32(1), n_ops=ops.n_ops,
                        scheme="nolock", path="parallel")
    return res, values, stats


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------
SCHEMES = ("tstream", "tstream_scan", "tstream_lockstep", "lock", "mvlk",
           "pat", "nolock")

# schemes whose evaluation consumes the restructured (chain-sorted) view —
# for these, ``evaluate`` lexsorts exactly once and threads the result down.
CHAIN_SCHEMES = frozenset(
    {"tstream", "tstream_scan", "tstream_lockstep", "mvlk"})


def evaluate(store: StateStore, ops: OpBatch, funs: Tuple[FunSpec, ...],
             scheme: str = "tstream", *, associative_only: bool = False,
             has_gates: bool = False, n_partitions: int = 16,
             max_dep_levels: int = 3, use_pallas: bool = False,
             prestructured: Optional[Prestructured] = None,
             rowmajor_ts: bool = False, restructure_method: str = "auto"):
    if scheme in CHAIN_SCHEMES and prestructured is None:
        prestructured = restructure(ops, store.pad_uid,
                                    rowmajor_ts=rowmajor_ts,
                                    method=restructure_method,
                                    use_pallas=use_pallas)
    if scheme == "tstream":
        if associative_only and not has_gates:
            return eval_tstream_scan(store, ops, funs, use_pallas=use_pallas,
                                     prestructured=prestructured)
        return eval_tstream_lockstep(store, ops, funs, has_gates=has_gates,
                                     max_dep_levels=max_dep_levels,
                                     prestructured=prestructured)
    if scheme == "tstream_scan":
        return eval_tstream_scan(store, ops, funs, use_pallas=use_pallas,
                                 prestructured=prestructured)
    if scheme == "tstream_lockstep":
        return eval_tstream_lockstep(store, ops, funs, has_gates=has_gates,
                                     max_dep_levels=max_dep_levels,
                                     prestructured=prestructured)
    if scheme == "lock":
        return eval_lock(store, ops, funs)
    if scheme == "mvlk":
        return eval_mvlk(store, ops, funs, has_gates=has_gates,
                         max_dep_levels=max_dep_levels,
                         prestructured=prestructured)
    if scheme == "pat":
        return eval_pat(store, ops, funs, n_partitions=n_partitions)
    if scheme == "nolock":
        return eval_nolock(store, ops, funs)
    raise ValueError(f"unknown scheme {scheme!r}; choose from {SCHEMES}")
