"""Transaction-processing engines: TStream (D2) + re-implemented baselines.

All engines share one contract::

    evaluate(store, ops, funs, ...) -> (OpResults_flat, new_values, stats)

``OpResults_flat`` is in *pre-sort* flat layout ([N] rows aligned with
(txn, slot)), so the scheduler can reshape it straight back into per-event
blotters.  ``stats`` carries structural parallelism counters (rounds, chain
counts) consumed by the benchmark harness's executor model.

Schemes (see DESIGN.md §2 for the multicore->TPU schedule mapping):

* ``tstream``   — D2 dynamic restructuring.  Associative-only apps take the
                  segmented-scan fast path (log-depth chains); otherwise the
                  lockstep path walks all chains in parallel, one op per chain
                  per round (the paper's one-thread-per-chain walk).  Gated
                  ops (cross-chain CFun deps) are scheduled level-wise like
                  the paper's iterative process; unresolved residue (cycles)
                  falls back to the sequential oracle for affected ops.
* ``lock``      — S2PL + lockAhead schedule: conflict-equivalent global ts
                  order, one transaction at a time (depth N).  Doubles as the
                  correctness oracle.
* ``mvlk``      — multiversion locking: writes serialize per state, reads are
                  served from versions in parallel.
* ``pat``       — S-Store partition-level locking: partitions advance their
                  ts-ordered fronts; a multi-partition transaction fires only
                  when it is at the front of *all* its partitions.
* ``nolock``    — no ordering (upper bound, deliberately incorrect).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .restructure import (Chains, restructure, segmented_scan_affine,
                          segmented_scan_max)
from .types import FunSpec, OpBatch, OpKind, OpResults, StateStore


# ---------------------------------------------------------------------------
# Fun application
# ---------------------------------------------------------------------------
def apply_funs(funs: Tuple[FunSpec, ...], fun_id: jnp.ndarray,
               pre: jnp.ndarray, operand: jnp.ndarray):
    """Vectorized lax.switch over the app's fun family.

    pre, operand: [N, W] -> (post [N, W], success bool[N]).
    """
    branches = [f.apply for f in funs]

    def one(fid, p, o):
        return jax.lax.switch(fid, branches, p, o)

    return jax.vmap(one)(fun_id, pre, operand)


def affine_coeffs(funs: Tuple[FunSpec, ...], fun_id: jnp.ndarray,
                  operand: jnp.ndarray):
    """Per-op (a, b) affine coefficients; identity for non-affine funs."""
    ident = (jnp.ones_like(operand), jnp.zeros_like(operand))
    branches = [(f.affine if f.affine is not None else (lambda o: (jnp.ones_like(o), jnp.zeros_like(o))))
                for f in funs]

    def one(fid, o):
        return jax.lax.switch(fid, branches, o)

    del ident
    return jax.vmap(one)(fun_id, operand)


def _gate_open(gate: jnp.ndarray, success_flat: jnp.ndarray) -> jnp.ndarray:
    """CFun gating: open when ungated, else the mate op's recorded success."""
    return jnp.where(gate >= 0, jnp.take(success_flat, jnp.maximum(gate, 0)), True)


@dataclasses.dataclass
class EngineStats:
    """Structural parallelism counters for the executor cost model."""
    rounds: jnp.ndarray          # sequential depth of the schedule
    n_chains: jnp.ndarray        # parallel width available
    max_chain: jnp.ndarray       # longest chain
    n_ops: int                   # total decomposed ops (incl. padding)
    scheme: str = ""
    path: str = ""               # "segscan" | "lockstep" | ...


jax.tree_util.register_dataclass(
    EngineStats, data_fields=["rounds", "n_chains", "max_chain"],
    meta_fields=["n_ops", "scheme", "path"])


def _empty_results(n: int, w: int):
    return dict(pre=jnp.zeros((n + 1, w)), post=jnp.zeros((n + 1, w)),
                success=jnp.zeros((n + 1,), bool))


# ---------------------------------------------------------------------------
# TStream fast path: segmented-scan chain evaluation (associative funs only)
# ---------------------------------------------------------------------------
def eval_tstream_scan(store: StateStore, ops: OpBatch,
                      funs: Tuple[FunSpec, ...], *, use_pallas: bool = False):
    sops, ch = restructure(ops, store.pad_uid)
    v0 = jnp.take(store.values, sops.uid, axis=0)          # [N, W]
    is_max_uid = jnp.take(store.uid_is_max(), sops.uid)    # [N]

    # affine family scan (non-affine & max-table ops become identity)
    a, b = affine_coeffs(funs, sops.fun, sops.operand)
    neutralize = is_max_uid[:, None]
    a = jnp.where(neutralize, jnp.ones_like(a), a)
    b = jnp.where(neutralize, jnp.zeros_like(b), b)

    # max family scan (ops on non-max tables and READs become -inf)
    is_max_fun = jnp.asarray([f.is_max for f in funs])[sops.fun]
    m = jnp.where((is_max_uid & is_max_fun)[:, None], sops.operand, -jnp.inf)

    if use_pallas:
        from repro.kernels.segscan import ops as segscan_ops
        A, B = segscan_ops.segscan_affine(a, b, ch.seg_start, exclusive=True)
        M = segscan_ops.segscan_max(m, ch.seg_start, exclusive=True)
    else:
        A, B = segmented_scan_affine(a, b, ch.seg_start, exclusive=True)
        M = segmented_scan_max(m, ch.seg_start, exclusive=True)

    pre_aff = A * v0 + B
    pre_max = jnp.maximum(v0, M)
    pre = jnp.where(is_max_uid[:, None], pre_max, pre_aff)
    post, success = apply_funs(funs, sops.fun, pre, sops.operand)

    # commit: last op of each chain defines the new state value
    n = ops.n_ops
    scatter_uid = jnp.where(ch.seg_end, sops.uid, store.pad_uid)
    new_values = store.values.at[scatter_uid].set(
        jnp.where(ch.seg_end[:, None], post, store.values[store.pad_uid]))
    new_values = new_values.at[store.pad_uid].set(0.0)

    # invalid (padding) ops record nothing — match the oracle's layout
    vmask = sops.valid
    pre = jnp.where(vmask[:, None], pre, 0.0)
    post = jnp.where(vmask[:, None], post, 0.0)
    success = success & vmask
    res = _scatter_results(n, ops.width, ch.order, pre, post, success)
    stats = EngineStats(rounds=jnp.ceil(jnp.log2(ch.max_len.astype(jnp.float32) + 1)),
                        n_chains=ch.n_chains, max_chain=ch.max_len,
                        n_ops=n, scheme="tstream", path="segscan")
    return res, new_values, stats


def _scatter_results(n, w, order, pre, post, success):
    out = _empty_results(n, w)
    out["pre"] = out["pre"].at[order].set(pre)[:n]
    out["post"] = out["post"].at[order].set(post)[:n]
    out["success"] = out["success"].at[order].set(success)[:n]
    return out


# ---------------------------------------------------------------------------
# TStream lockstep path: parallel chains, sequential within chain, level-wise
# dependency resolution (paper §IV-C2 Case 2).
# ---------------------------------------------------------------------------
def _chain_levels(sops: OpBatch, ch: Chains, n: int, max_levels: int):
    """Level-wise chain schedule for cross-chain CFun dependencies.

    level(C) = 0 if C has no gated ops, else 1 + max(level(mate chain)).
    Chains whose level does not resolve within ``max_levels`` (dependency
    cycles inside the batch) are flagged for the sequential fallback.
    """
    INF = jnp.int32(10 ** 6)
    # seg id of each op in pre-sort layout, so mate (flat idx) -> chain id
    seg_flat = jnp.zeros((n + 1,), jnp.int32).at[ch.order].set(ch.seg_id)
    gated = (sops.gate >= 0) & sops.valid
    mate_chain = seg_flat[jnp.maximum(sops.gate, 0)]
    chain_has_gate = jax.ops.segment_max(gated.astype(jnp.int32), ch.seg_id,
                                         num_segments=n) > 0
    lvl = jnp.where(chain_has_gate, INF, 0)

    def body(_, lvl):
        pred_lvl = jnp.where(gated, lvl[mate_chain], -1)
        need = jax.ops.segment_max(
            jnp.where(gated, jnp.minimum(pred_lvl + 1, INF), 0),
            ch.seg_id, num_segments=n)
        return jnp.where(chain_has_gate, jnp.minimum(need, INF), 0)

    lvl = jax.lax.fori_loop(0, max_levels, body, lvl)
    unresolved = lvl >= INF
    return lvl, unresolved


def _lockstep_sweep(values, sops: OpBatch, ch: Chains,
                    funs: Tuple[FunSpec, ...], chain_mask, results, n, pad_uid,
                    rounds):
    """Walk masked chains in lockstep: round r applies each chain's r-th op.

    Exactly one op per state per round -> conflict-free scatters, no locks.
    """
    def round_body(r, carry):
        values, res = carry
        active = (ch.pos == r) & jnp.take(chain_mask, ch.seg_id) & sops.valid
        cur = jnp.take(values, sops.uid, axis=0)
        # sops.gate holds the mate's *pre-sort* flat index; success is
        # recorded in pre-sort layout, so this gather is layout-consistent.
        gate_ok_s = _gate_open(sops.gate, res["success"][:-1])
        post, ok = apply_funs(funs, sops.fun, cur, sops.operand)
        post = jnp.where(gate_ok_s[:, None], post, cur)
        ok = ok & gate_ok_s
        scat = jnp.where(active, sops.uid, pad_uid)
        values = values.at[scat].set(jnp.where(active[:, None], post, 0.0))
        values = values.at[pad_uid].set(0.0)
        sink = jnp.where(active, ch.order, n)
        res = dict(
            pre=res["pre"].at[sink].set(cur),
            post=res["post"].at[sink].set(post),
            success=res["success"].at[sink].set(ok),
        )
        return values, res

    return jax.lax.fori_loop(0, rounds, round_body, (values, results))


def eval_tstream_lockstep(store: StateStore, ops: OpBatch,
                          funs: Tuple[FunSpec, ...], *, max_dep_levels: int = 3,
                          has_gates: bool = False):
    sops, ch = restructure(ops, store.pad_uid)
    n = ops.n_ops
    values = store.values
    results = _empty_results(n, ops.width)

    if not has_gates:
        values, results = _lockstep_sweep(
            values, sops, ch, funs, jnp.ones((n,), bool), results, n,
            store.pad_uid, ch.max_len)
        rounds = ch.max_len
        unresolved_ops = jnp.zeros((n,), bool)
    else:
        lvl, unresolved = _chain_levels(sops, ch, n, max_dep_levels)
        rounds = jnp.int32(0)
        for L in range(max_dep_levels + 1):
            mask = (lvl == L)
            # this level's sweep only needs the longest level-L chain
            in_level = jnp.take(mask, ch.seg_id) & sops.valid
            lvl_rounds = jnp.max(jnp.where(in_level, ch.pos, -1)) + 1
            values, results = _lockstep_sweep(
                values, sops, ch, funs, mask, results, n, store.pad_uid,
                lvl_rounds)
            rounds = rounds + lvl_rounds
        # sequential fallback for ops in unresolved chains (cycles)
        unresolved_ops_sorted = jnp.take(unresolved, ch.seg_id) & sops.valid
        unresolved_ops = jnp.zeros((n + 1,), bool).at[ch.order].set(
            unresolved_ops_sorted)[:n]
        values, results = _sequential_sweep(values, ops, funs, results,
                                            mask_flat=unresolved_ops,
                                            pad_uid=store.pad_uid)
        rounds = rounds + jnp.sum(unresolved_ops)

    res = {k: v[:n] for k, v in results.items()}
    stats = EngineStats(rounds=rounds, n_chains=ch.n_chains,
                        max_chain=ch.max_len, n_ops=n,
                        scheme="tstream", path="lockstep")
    return res, values, stats


# ---------------------------------------------------------------------------
# Sequential oracle / LOCK schedule
# ---------------------------------------------------------------------------
def _sequential_sweep(values, ops: OpBatch, funs, results, *, mask_flat,
                      pad_uid):
    """Apply ops one at a time in global (ts, slot) order (S2PL schedule)."""
    n = ops.n_ops
    order = jnp.lexsort((ops.slot, ops.ts))  # global timestamp order

    def step(carry, i):
        values, res = carry
        j = order[i]
        run = mask_flat[j] & ops.valid[j]
        uid = jnp.where(run, ops.uid[j], pad_uid)
        cur = values[uid]
        gate = ops.gate[j]
        gate_ok = jnp.where(gate >= 0, res["success"][jnp.maximum(gate, 0)],
                            True)
        post, ok = funs_apply_single(funs, ops.fun[j], cur, ops.operand[j])
        post = jnp.where(gate_ok, post, cur)
        ok = ok & gate_ok
        values = values.at[uid].set(jnp.where(run, post, values[pad_uid]))
        values = values.at[pad_uid].set(0.0)
        sink = jnp.where(run, j, n)
        res = dict(
            pre=res["pre"].at[sink].set(cur),
            post=res["post"].at[sink].set(post),
            success=res["success"].at[sink].set(ok),
        )
        return (values, res), None

    (values, results), _ = jax.lax.scan(step, (values, results),
                                        jnp.arange(n))
    return values, results


def funs_apply_single(funs, fid, pre, operand):
    return jax.lax.switch(fid, [f.apply for f in funs], pre, operand)


def eval_lock(store: StateStore, ops: OpBatch, funs):
    """LOCK baseline == sequential oracle (conflict-equivalent ts order)."""
    n = ops.n_ops
    results = _empty_results(n, ops.width)
    values, results = _sequential_sweep(
        store.values, ops, funs, results,
        mask_flat=jnp.ones((n,), bool), pad_uid=store.pad_uid)
    results = {k: v[:n] for k, v in results.items()}
    stats = EngineStats(rounds=jnp.sum(ops.valid), n_chains=jnp.int32(1),
                        max_chain=jnp.sum(ops.valid), n_ops=n,
                        scheme="lock", path="sequential")
    return results, values, stats


# ---------------------------------------------------------------------------
# MVLK: multiversion — writes serialize per chain, reads resolve in parallel
# ---------------------------------------------------------------------------
def eval_mvlk(store: StateStore, ops: OpBatch, funs,
              *, has_gates: bool = False, max_dep_levels: int = 3):
    """Writes run as (lockstep) chains; READs are version lookups.

    Structurally: read ops are identity within chains (their ``pre`` is the
    version with the largest ts' < ts — exactly the paper's lwm-guarded
    multiversion read), so we can reuse the lockstep machinery; the *cost
    model* difference (reads don't occupy chain rounds) is reflected in the
    stats: rounds count only write-chain depth.
    """
    sops, ch = restructure(ops, store.pad_uid)
    is_write = sops.kind != int(OpKind.READ)
    write_pos = _masked_positions(is_write, ch)
    write_depth = jnp.max(jnp.where(is_write, write_pos, -1)) + 1
    res, values, st = eval_tstream_lockstep(
        store, ops, funs, has_gates=has_gates, max_dep_levels=max_dep_levels)
    stats = EngineStats(rounds=write_depth, n_chains=ch.n_chains,
                        max_chain=st.max_chain, n_ops=ops.n_ops,
                        scheme="mvlk", path="mv")
    return res, values, stats


def _masked_positions(mask, ch: Chains):
    """Position of each op among *masked* ops of its chain."""
    inc = jnp.cumsum(mask.astype(jnp.int32))
    seg_base = jax.lax.cummax(jnp.where(ch.seg_start,
                                        inc - mask.astype(jnp.int32), 0))
    return inc - seg_base - mask.astype(jnp.int32)


# ---------------------------------------------------------------------------
# PAT: partition-level locking (S-Store)
# ---------------------------------------------------------------------------
def eval_pat(store: StateStore, ops: OpBatch, funs, *, n_partitions: int = 16):
    """Partitions advance ts-ordered fronts; a transaction fires only when it
    holds the front of *every* partition it touches (S-Store's counter-guarded
    partition-lock acquisition).  A txn's ops within one partition are
    contiguous after the (partition, ts, slot) sort, so readiness reduces to:
    each of the txn's per-partition blocks starts at that partition's front.
    """
    n = ops.n_ops
    part = jnp.where(ops.valid, ops.uid % n_partitions, n_partitions)
    order = jnp.lexsort((ops.slot, ops.ts, part))
    part_s = jnp.take(part, order)
    seg_start = jnp.concatenate([jnp.ones((1,), bool),
                                 part_s[1:] != part_s[:-1]])
    idx = jnp.arange(n, dtype=jnp.int32)
    start_idx = jax.lax.cummax(jnp.where(seg_start, idx, 0))
    pos = idx - start_idx

    sop = jax.tree_util.tree_map(lambda x: jnp.take(x, order, axis=0), ops)
    # (txn, partition) block structure — a txn's ops in one partition are
    # contiguous (same ts) and are executed under one lock acquisition.
    blk_start = seg_start | jnp.concatenate(
        [jnp.ones((1,), bool), sop.txn[1:] != sop.txn[:-1]])
    blk_start_idx = jax.lax.cummax(jnp.where(blk_start, idx, 0))
    blk_front_pos = jnp.take(pos, blk_start_idx)  # pos of block's first op
    blk_id = jnp.cumsum(blk_start.astype(jnp.int32)) - 1
    blk_len = jnp.take(
        jax.ops.segment_sum(jnp.ones((n,), jnp.int32), blk_id,
                            num_segments=n), blk_id)
    # same-uid runs inside a block execute sequentially (slot order)
    uidrun_start = blk_start | jnp.concatenate(
        [jnp.ones((1,), bool), sop.uid[1:] != sop.uid[:-1]])

    txn_total = jax.ops.segment_sum(ops.valid.astype(jnp.int32), ops.txn,
                                    num_segments=n)
    results = _empty_results(n, ops.width)
    values = store.values
    front = jnp.zeros((n_partitions + 1,), jnp.int32)
    part_len = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), part_s,
                                   num_segments=n_partitions + 1)
    fired = jnp.zeros((n,), bool)

    def cond(carry):
        values, res, front, fired, rounds = carry
        return (rounds < n) & jnp.any(~fired & sop.valid)

    def body(carry):
        values, res, front, fired, rounds = carry
        # the op's block holds its partition's lock: the front pointer lies
        # inside the block (a partially executed block keeps the lock).
        fr = jnp.take(front, part_s)
        block_at_front = (fr >= blk_front_pos) & (fr < blk_front_pos + blk_len)
        candidate = (block_at_front | fired) & sop.valid
        txn_cand = jax.ops.segment_sum(candidate.astype(jnp.int32), sop.txn,
                                       num_segments=n)
        ready = (txn_cand >= txn_total) & (txn_total > 0)
        prev_fired = jnp.concatenate([jnp.zeros((1,), bool), fired[:-1]])
        fire = block_at_front & ~fired & sop.valid \
            & jnp.take(ready, sop.txn) & (uidrun_start | prev_fired)
        cur = jnp.take(values, sop.uid, axis=0)
        # intra-txn gates: mates fire in the same round — resolve ungated first
        post0, ok0 = apply_funs(funs, sop.fun, cur, sop.operand)
        sink_now = jnp.where(fire & (sop.gate < 0), order, n)
        succ_now = jnp.zeros((n + 1,), bool).at[sink_now].set(ok0)
        succ_known = succ_now[:-1] | res["success"][:-1]
        gate_ok = jnp.where(sop.gate >= 0,
                            jnp.take(succ_known, jnp.maximum(sop.gate, 0)),
                            True)
        post = jnp.where(gate_ok[:, None], post0, cur)
        ok = ok0 & gate_ok
        scat = jnp.where(fire, sop.uid, store.pad_uid)
        values = values.at[scat].set(jnp.where(fire[:, None], post, 0.0))
        values = values.at[store.pad_uid].set(0.0)
        sink = jnp.where(fire, order, n)
        res = dict(pre=res["pre"].at[sink].set(cur),
                   post=res["post"].at[sink].set(post),
                   success=res["success"].at[sink].set(ok))
        fired = fired | fire
        adv = jax.ops.segment_sum(fire.astype(jnp.int32), part_s,
                                  num_segments=n_partitions + 1)
        front = front + adv
        return values, res, front, fired, rounds + 1

    values, results, front, fired, rounds = jax.lax.while_loop(
        cond, body, (values, results, front, fired, jnp.int32(0)))
    results = {k: v[:n] for k, v in results.items()}
    stats = EngineStats(rounds=rounds, n_chains=jnp.int32(n_partitions),
                        max_chain=jnp.max(part_len[:n_partitions]), n_ops=n,
                        scheme="pat", path="partition")
    return results, values, stats


# ---------------------------------------------------------------------------
# No-Lock upper bound (incorrect by design)
# ---------------------------------------------------------------------------
def eval_nolock(store: StateStore, ops: OpBatch, funs):
    pre = jnp.take(store.values, jnp.where(ops.valid, ops.uid, store.pad_uid),
                   axis=0)
    post, ok = apply_funs(funs, ops.fun, pre, ops.operand)
    scat = jnp.where(ops.valid & (ops.kind != int(OpKind.READ)), ops.uid,
                     store.pad_uid)
    values = store.values.at[scat].set(post)
    values = values.at[store.pad_uid].set(0.0)
    res = dict(pre=jnp.concatenate([pre, pre[:1]]),
               post=jnp.concatenate([post, post[:1]]),
               success=jnp.concatenate([ok, ok[:1]]))
    res = {k: v[: ops.n_ops] for k, v in res.items()}
    stats = EngineStats(rounds=jnp.int32(1), n_chains=jnp.int32(ops.n_ops),
                        max_chain=jnp.int32(1), n_ops=ops.n_ops,
                        scheme="nolock", path="parallel")
    return res, values, stats


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------
SCHEMES = ("tstream", "tstream_scan", "tstream_lockstep", "lock", "mvlk",
           "pat", "nolock")


def evaluate(store: StateStore, ops: OpBatch, funs: Tuple[FunSpec, ...],
             scheme: str = "tstream", *, associative_only: bool = False,
             has_gates: bool = False, n_partitions: int = 16,
             max_dep_levels: int = 3, use_pallas: bool = False):
    if scheme == "tstream":
        if associative_only and not has_gates:
            return eval_tstream_scan(store, ops, funs, use_pallas=use_pallas)
        return eval_tstream_lockstep(store, ops, funs, has_gates=has_gates,
                                     max_dep_levels=max_dep_levels)
    if scheme == "tstream_scan":
        return eval_tstream_scan(store, ops, funs, use_pallas=use_pallas)
    if scheme == "tstream_lockstep":
        return eval_tstream_lockstep(store, ops, funs, has_gates=has_gates,
                                     max_dep_levels=max_dep_levels)
    if scheme == "lock":
        return eval_lock(store, ops, funs)
    if scheme == "mvlk":
        return eval_mvlk(store, ops, funs, has_gates=has_gates,
                         max_dep_levels=max_dep_levels)
    if scheme == "pat":
        return eval_pat(store, ops, funs, n_partitions=n_partitions)
    if scheme == "nolock":
        return eval_nolock(store, ops, funs)
    raise ValueError(f"unknown scheme {scheme!r}; choose from {SCHEMES}")
