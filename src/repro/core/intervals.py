"""Punctuation-interval assembly from an unbounded out-of-order source.

The batch drivers (``scheduler.run_stream``, ``sharded_stream``) consume a
pre-shaped ``[n_intervals, interval, ...]`` event stream; a *continuous*
service (``runtime/service.py``) instead receives arrival batches in
**arrival order**, each row tagged with an integer **event time**.  The
``IntervalAssembler`` re-sequences arrivals into event-time order and cuts
punctuation intervals under a watermark policy (DESIGN.md §2.6):

* the watermark advances per arrival batch to
  ``max(event_time seen) - allowed_lateness`` and is monotone;
* a row is *late* iff its event time is below the watermark at arrival.
  Late rows are either **rerouted** — resequenced at the current watermark,
  i.e. into the earliest interval still open — or **dropped**; both are
  counted, never silent;
* a pending row is *sealed* once its effective time is at or below the
  watermark: every future arrival sequences strictly after it (on-time
  rows sit at or above the watermark, rerouted rows are clamped to it and
  carry a later arrival sequence).  Each time ``interval`` sealed rows
  accumulate, one punctuation interval is emitted in (effective time,
  arrival sequence) order.

Conservation law (pinned by the hypothesis suite): every arrived row is
emitted exactly once, counted dropped, or still pending —
``arrived == assembled + watermark_dropped + pending``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

_NEG_INF = np.iinfo(np.int64).min // 4


@dataclasses.dataclass(frozen=True)
class WatermarkPolicy:
    """Out-of-order handling: reorder window + late-row disposition."""

    allowed_lateness: int = 0       # event-time units behind the max seen
    late: str = "reroute"           # "reroute" into the next interval | "drop"

    def __post_init__(self):
        assert self.late in ("reroute", "drop"), self.late
        assert self.allowed_lateness >= 0, self.allowed_lateness


@dataclasses.dataclass
class IntervalInfo:
    """Per-interval accounting emitted alongside the event columns."""

    index: int                  # emission index (assembler-local)
    watermark: int              # watermark when the interval was sealed
    event_time: np.ndarray      # i64[interval] original event times
    seq: np.ndarray             # i64[interval] arrival sequence numbers
    enqueue_s: np.ndarray       # f64[interval] host enqueue timestamps
    n_late: int                 # rerouted rows that landed in this interval


class IntervalAssembler:
    """Cut watermarked punctuation intervals from arrival-order batches."""

    def __init__(self, interval: int,
                 policy: Optional[WatermarkPolicy] = None):
        assert interval > 0
        self.interval = int(interval)
        self.policy = policy or WatermarkPolicy()
        # pending rows as chunk dicts; consolidated to one chunk at pop time
        self._chunks: List[Dict] = []
        self._seq = 0
        self._wm = int(_NEG_INF)
        self._closed = False
        self.arrived = 0
        self.assembled = 0
        self.watermark_dropped = 0
        self.late_rerouted = 0
        self.emitted = 0
        self.watermarks: List[int] = []   # per emitted interval (monotone)

    @property
    def watermark(self) -> int:
        return self._wm

    @property
    def pending(self) -> int:
        return int(sum(c["eff"].shape[0] for c in self._chunks))

    def push(self, events: Dict[str, np.ndarray], event_time,
             enqueue_s: float = 0.0) -> None:
        """Admit one arrival batch (columns + event-time + enqueue stamp)."""
        assert not self._closed, "push after close()"
        event_time = np.asarray(event_time, np.int64)
        n = int(event_time.shape[0])
        if n == 0:
            return
        self.arrived += n
        wm = self._wm
        late = event_time < wm
        # the watermark advances from the *unfiltered* batch: a late row
        # still proves time has passed at the source
        new_wm = max(wm, int(event_time.max()) - self.policy.allowed_lateness)
        cols = {k: np.asarray(v) for k, v in events.items()}
        if self.policy.late == "drop" and late.any():
            self.watermark_dropped += int(late.sum())
            keep = ~late
            cols = {k: v[keep] for k, v in cols.items()}
            event_time, late = event_time[keep], late[keep]
            n = int(event_time.shape[0])
        else:
            self.late_rerouted += int(late.sum())
        if n:
            # reroute: clamp the sort key to the watermark — the row joins
            # the earliest interval a future arrival could still join
            eff = np.where(late, wm, event_time)
            seq = np.arange(self._seq, self._seq + n, dtype=np.int64)
            self._chunks.append(dict(
                cols=cols, eff=eff, seq=seq, time=event_time, late=late,
                enq=np.full(n, float(enqueue_s))))
        self._seq += n
        self._wm = new_wm

    def close(self) -> None:
        """End of stream: every pending row becomes sealed."""
        self._closed = True

    def pop_ready(self) -> List[Tuple[Dict[str, np.ndarray], IntervalInfo]]:
        """Emit every complete interval of sealed rows, in stream order."""
        if not self._chunks:
            return []
        ch = self._consolidate()
        eff, seq = ch["eff"], ch["seq"]
        sealed = (np.ones(eff.shape[0], bool) if self._closed
                  else eff <= self._wm)
        k = int(sealed.sum()) // self.interval
        if k == 0:
            return []
        sidx = np.flatnonzero(sealed)
        order = np.lexsort((seq[sidx], eff[sidx]))
        take = sidx[order][: k * self.interval]
        out = []
        for i in range(k):
            sl = take[i * self.interval : (i + 1) * self.interval]
            info = IntervalInfo(
                index=self.emitted + i, watermark=self._wm,
                event_time=ch["time"][sl], seq=seq[sl],
                enqueue_s=ch["enq"][sl], n_late=int(ch["late"][sl].sum()))
            out.append(({kk: v[sl] for kk, v in ch["cols"].items()}, info))
            self.watermarks.append(self._wm)
        self.emitted += k
        self.assembled += k * self.interval
        keep = np.ones(eff.shape[0], bool)
        keep[take] = False
        if keep.any():
            self._chunks = [dict(
                cols={kk: v[keep] for kk, v in ch["cols"].items()},
                eff=eff[keep], seq=seq[keep], time=ch["time"][keep],
                late=ch["late"][keep], enq=ch["enq"][keep])]
        else:
            self._chunks = []
        return out

    def _consolidate(self) -> Dict:
        if len(self._chunks) > 1:
            cat = lambda key: np.concatenate([c[key] for c in self._chunks])
            cols = {k: np.concatenate([c["cols"][k] for c in self._chunks])
                    for k in self._chunks[0]["cols"]}
            self._chunks = [dict(cols=cols, eff=cat("eff"), seq=cat("seq"),
                                 time=cat("time"), late=cat("late"),
                                 enq=cat("enq"))]
        return self._chunks[0]

    def conservation_ok(self) -> bool:
        return self.arrived == (self.assembled + self.watermark_dropped
                                + self.pending)

    @property
    def ledger(self) -> Dict[str, int]:
        """The full accounting record — the conservation law's terms plus
        the reroute count.  Merged into ``StreamService.stats`` so the
        balance stays checkable across every injected fault (crashed runs
        included): a fault may strand or drop rows, never lose them from
        the ledger."""
        return dict(arrived=self.arrived, assembled=self.assembled,
                    dropped=self.watermark_dropped, pending=self.pending,
                    rerouted=self.late_rerouted, emitted=self.emitted)

    def assert_conserved(self) -> None:
        assert self.conservation_ok(), self.ledger

    def publish(self, tele, prefix: str = "assembly") -> None:
        """Publish the conservation ledger into a telemetry registry as
        ``<prefix>.<term>`` counters (DESIGN.md §2.11).  ``tele`` is
        duck-typed (anything with ``count``) — the core layer defines
        the hook, the runtime injects the registry, so no core module
        ever imports ``repro.runtime``."""
        for k, v in self.ledger.items():
            tele.count(f"{prefix}.{k}", int(v))


class ReplaySource:
    """Deterministic replayable arrival process.

    The whole arrival sequence — event payloads, event times, and the
    out-of-order arrival permutation — is a pure function of ``seed``
    (the streaming analogue of ``runtime/ft.py``'s step-keyed batches):
    after a crash, re-iterating the source replays the identical arrival
    order, which makes punctuation-aligned recovery bitwise exact.

    ``jitter`` bounds arrival displacement: row *i* arrives within
    ``jitter`` positions of its event-time order, so a
    ``WatermarkPolicy(allowed_lateness >= jitter)`` reassembles the exact
    in-order stream (``in_order_events`` — the monolithic-driver input
    the service is bit-compared against).
    """

    def __init__(self, gen_events, n_events: int, *, seed: int = 0,
                 arrival_batch: int = 64, jitter: int = 0,
                 gen_kwargs: Optional[dict] = None):
        rng = np.random.default_rng(np.random.SeedSequence([int(seed)]))
        events = {k: np.asarray(v) for k, v in
                  gen_events(rng, int(n_events), **(gen_kwargs or {})).items()}
        self.in_order_events = events
        t = np.arange(int(n_events), dtype=np.int64)
        if jitter > 0:
            order = np.argsort(t + rng.uniform(0.0, float(jitter),
                                               int(n_events)), kind="stable")
        else:
            order = t
        self._events = {k: v[order] for k, v in events.items()}
        self._time = t[order]
        self.n_events = int(n_events)
        self.arrival_batch = int(arrival_batch)
        self.jitter = int(jitter)

    def __iter__(self) -> Iterator[Tuple[Dict[str, np.ndarray], np.ndarray]]:
        for i in range(0, self.n_events, self.arrival_batch):
            j = min(i + self.arrival_batch, self.n_events)
            yield ({k: v[i:j] for k, v in self._events.items()},
                   self._time[i:j])


class PhasedReplaySource(ReplaySource):
    """A deterministic multi-phase *workload storm*: the event stream is
    the concatenation of per-phase ``gen_events`` outputs (same generator,
    different kwargs — e.g. a key-skew flip followed by a multi-partition
    burst), drawn from ONE seeded rng stream so the whole storm is a pure
    function of ``(seed, phases)``.  Event times stay globally monotone
    across phase boundaries and the arrival jitter permutation applies to
    the concatenated stream, so everything ``ReplaySource`` guarantees
    (bounded displacement, exact ``in_order_events``, replayability for
    crash → restore → replay) holds for the storm too.

    ``phases``: sequence of ``(n_events, gen_kwargs)``.  ``phase_bounds``
    exposes the cumulative event-count boundaries, so callers (the storm
    benchmark) can map a punctuation interval to its phase:
    interval *i* covers events ``[i*interval, (i+1)*interval)``.
    """

    def __init__(self, gen_events, phases, *, seed: int = 0,
                 arrival_batch: int = 64, jitter: int = 0):
        phases = [(int(n), dict(kw)) for n, kw in phases]
        assert phases and all(n > 0 for n, _ in phases), phases

        def gen(rng, n_total, **_):
            parts = [gen_events(rng, n, **kw) for n, kw in phases]
            keys = list(parts[0])
            assert all(list(p) == keys for p in parts), \
                "every phase must emit the same event columns"
            return {k: np.concatenate([np.asarray(p[k]) for p in parts])
                    for k in keys}

        super().__init__(gen, sum(n for n, _ in phases), seed=seed,
                         arrival_batch=arrival_batch, jitter=jitter)
        self.phases = phases
        self.phase_bounds = np.cumsum([n for n, _ in phases])

    def phase_of_interval(self, interval_idx: int, interval: int) -> int:
        """Phase index of the interval's FIRST event (intervals straddling
        a boundary count toward the earlier phase)."""
        ev = interval_idx * interval
        return int(np.searchsorted(self.phase_bounds, ev, side="right"))
