"""Core datatypes for TStream-JAX.

The paper models the processing of one input event at one operator as a
*state transaction* (Definition 1): a set of READ / WRITE / READ_MODIFY
operations over shared keyed state, which must be scheduled conflict-
equivalent to timestamp order (Definition 2).

On TPU we represent a punctuation interval's worth of transactions as a
structure-of-arrays ``OpBatch``: one flat row per *operation* (the unit the
paper's dynamic restructuring decomposes transactions into).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


class OpKind(enum.IntEnum):
    """Atomic operation kinds (paper Table III)."""

    NOP = 0
    READ = 1
    WRITE = 2
    READ_MODIFY = 3


# ---------------------------------------------------------------------------
# Fun registry — the paper's system-provided / user-defined ``Fun`` family.
#
# ``apply``  : (pre[W], operand[W]) -> (post[W], success: bool scalar)
# ``affine`` : operand[W] -> (a[W], b[W]) such that post == a * pre + b.
#              Present only when the fun is *associative-affine*; these ops are
#              eligible for the segmented-scan fast path (log-depth chains).
# ``is_max`` : post == max(pre, operand) — the other associative family we
#              support (used for the TP vehicle-count LPC sketch).
# Funs with neither form are evaluated on the sequential-within-chain
# (lockstep) path — exactly the paper's one-thread-walks-one-chain semantics.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FunSpec:
    name: str
    apply: Callable[[jnp.ndarray, jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]]
    affine: Optional[Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]]] = None
    is_max: bool = False
    # (a, b_is_operand) when the affine form is one of the three simple
    # shapes identity (1, 0) / set (0, o) / add (1, o) — lets the engines
    # build coefficients from a LUT instead of a vmapped switch.  None for
    # general affine callables.
    affine_simple: Optional[Tuple[float, bool]] = None

    @property
    def associative(self) -> bool:
        return self.affine is not None or self.is_max


def _f_nop(pre, operand):
    return pre, jnp.asarray(True)


def _f_read(pre, operand):
    return pre, jnp.asarray(True)


def _f_put(pre, operand):
    return operand, jnp.asarray(True)


def _f_add(pre, operand):
    return pre + operand, jnp.asarray(True)


def _f_max(pre, operand):
    return jnp.maximum(pre, operand), jnp.asarray(True)


def _f_take(pre, operand):
    """Bounded take on lane 0: succeed iff pre[0] >= operand[0] (SL debit)."""
    ok = pre[0] >= operand[0]
    return pre - jnp.where(ok, operand, jnp.zeros_like(operand)), ok


F_NOP = FunSpec("nop", _f_nop, affine=lambda o: (jnp.ones_like(o), jnp.zeros_like(o)),
                affine_simple=(1.0, False))
F_READ = FunSpec("read", _f_read, affine=lambda o: (jnp.ones_like(o), jnp.zeros_like(o)),
                 affine_simple=(1.0, False))
F_PUT = FunSpec("put", _f_put, affine=lambda o: (jnp.zeros_like(o), o),
                affine_simple=(0.0, True))
F_ADD = FunSpec("add", _f_add, affine=lambda o: (jnp.ones_like(o), o),
                affine_simple=(1.0, True))
F_MAX = FunSpec("max", _f_max, is_max=True)
F_TAKE = FunSpec("take", _f_take)  # conditional: lockstep path only

CORE_FUNS: Tuple[FunSpec, ...] = (F_NOP, F_READ, F_PUT, F_ADD, F_MAX, F_TAKE)
ASSOC_FUNS: Tuple[FunSpec, ...] = (F_NOP, F_READ, F_PUT, F_ADD, F_MAX)


# ---------------------------------------------------------------------------
# OpBatch — flattened decomposed operations of one punctuation interval.
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OpBatch:
    """SoA of N = batch * max_ops decomposed operations.

    ``uid``  : global state id = table_base + key  (the paper's "targeted state")
    ``ts``   : transaction timestamp (the triggering event's ts)
    ``txn``  : transaction index within the interval (== event row)
    ``slot`` : op slot within its transaction (position in EventBlotter)
    ``fun``  : index into the app's fun tuple
    ``gate`` : flat pre-sort op index (txn * max_ops + slot) of the *mate* op
               whose success gates this op (paper's CFun on a different key);
               -1 when ungated.  F2 (determined read/write sets) makes this
               computable at decomposition time.
    ``operand``: [N, W] parameter lanes.
    ``valid``  : padding mask (False rows are NOPs on the padding chain).
    """

    uid: jnp.ndarray      # i32[N]
    ts: jnp.ndarray       # i32[N]
    txn: jnp.ndarray      # i32[N]
    slot: jnp.ndarray     # i32[N]
    kind: jnp.ndarray     # i32[N]
    fun: jnp.ndarray      # i32[N]
    gate: jnp.ndarray     # i32[N]
    operand: jnp.ndarray  # f32[N, W]
    valid: jnp.ndarray    # bool[N]

    @property
    def n_ops(self) -> int:
        return self.uid.shape[0]

    @property
    def width(self) -> int:
        return self.operand.shape[-1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OpResults:
    """Per-op outcomes, aligned with the *pre-sort* (txn, slot) layout.

    ``pre``     : state value observed at the op's timestamp (the paper's
                  multiversion read — the version with largest ts' < ts).
    ``post``    : value after the op applied.
    ``success`` : Fun/CFun outcome; used for abort notification ("rejected").
    """

    pre: jnp.ndarray      # f32[B, max_ops, W]
    post: jnp.ndarray     # f32[B, max_ops, W]
    success: jnp.ndarray  # bool[B, max_ops]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StateStore:
    """Fixed-capacity keyed tables, concatenated into one value array.

    ``values[S+1, W]`` — slot S is the padding chain (all invalid ops target
    it).  Table t owns slots [base[t], base[t] + capacity[t]).
    ``kind_max`` marks tables whose RMW family is max-type (LPC sketches).

    ``slot_is_max`` (optional, bool[S+1]) overrides the table-derived
    max-type flags with explicit per-slot flags.  Ownership-permuted local
    stores need this: the permutation interleaves slots from different
    tables, so max-ness no longer follows table ranges (``core/ownership``).
    """

    values: jnp.ndarray                    # f32[S+1, W]
    table_base: tuple = dataclasses.field(metadata=dict(static=True), default=())
    table_capacity: tuple = dataclasses.field(metadata=dict(static=True), default=())
    table_is_max: tuple = dataclasses.field(metadata=dict(static=True), default=())
    slot_is_max: Optional[jnp.ndarray] = None  # bool[S+1] per-slot override

    @property
    def n_slots(self) -> int:
        return self.values.shape[0] - 1

    @property
    def pad_uid(self) -> int:
        return self.values.shape[0] - 1

    def uid_of(self, table: int, key: jnp.ndarray) -> jnp.ndarray:
        return self.table_base[table] + key

    def uid_is_max(self) -> jnp.ndarray:
        """bool[S+1]: whether each slot belongs to a max-type table."""
        if self.slot_is_max is not None:
            return self.slot_is_max
        flags = jnp.zeros(self.values.shape[0], dtype=bool)
        for t, (b, c) in enumerate(zip(self.table_base, self.table_capacity)):
            if self.table_is_max[t]:
                flags = flags.at[b : b + c].set(True)
        return flags


def make_store(capacities: Sequence[int], width: int,
               is_max: Sequence[bool] | None = None,
               init: jnp.ndarray | None = None) -> StateStore:
    """Build a StateStore with the given per-table capacities."""
    caps = tuple(int(c) for c in capacities)
    bases, acc = [], 0
    for c in caps:
        bases.append(acc)
        acc += c
    vals = jnp.zeros((acc + 1, width), jnp.float32) if init is None else init
    assert vals.shape == (acc + 1, width), (vals.shape, acc + 1, width)
    im = tuple(bool(x) for x in (is_max or [False] * len(caps)))
    return StateStore(values=vals, table_base=tuple(bases),
                      table_capacity=caps, table_is_max=im)
