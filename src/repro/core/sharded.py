"""Per-batch chain-shard layouts over a device mesh — the paper's NUMA-aware
processing configurations (§IV-E) mapped to SPMD (DESIGN.md §2.5):

  shared-nothing     state slots owned by one device (contiguous after the
                     ownership permutation); chains evaluate where their
                     state lives; **zero collectives**
  shared-per-socket  state owned per 'socket' mesh axis, work split across
                     the socket's 'core' axis -> intra-socket psum only
  shared-everything  state replicated; work split across all devices ->
                     global psum of state deltas (cross-socket traffic)

All three evaluate the same restructured batch with identical results;
compiled collective bytes per layout quantify the paper's Fig. 14 finding
(shared-nothing wins; cross-socket communication hurts).

This is the **replicate-everything baseline**: every device receives the
full OpBatch (``in_specs=P()``) and masks out non-local ops, paying
O(n_dev · N) replicated bytes, a fresh restructure sort and an ownership
re-permutation *per call*.  The owner-routed fused driver
(``core/sharded_stream``) replaces all three costs for streams; this path
remains the per-batch reference the benchmarks compare against.

Ownership permutation and local-store construction are shared with the
fused driver via ``core/ownership`` — local stores carry per-slot max
flags, so heterogeneous table families (e.g. TP's max sketches) work
under every layout.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .engines import eval_tstream_scan
from .ownership import (LAYOUTS, build_ownership, chunk_shard_output,
                        make_local_store, permute_values, unchunk_output,
                        unpermute_values)
from .restructure import restructure
from .types import FunSpec, OpBatch, StateStore

__all__ = ["LAYOUTS", "evaluate_sharded"]


def _remap_ops(ops: OpBatch, fwd: jnp.ndarray, pad_new: int) -> OpBatch:
    uid = jnp.where(ops.valid, jnp.take(fwd, ops.uid), pad_new)
    return dataclasses.replace(ops, uid=uid)


def _eval_local(vals, lops, slot_is_max, funs):
    """Restructure the remapped local batch exactly once and evaluate on a
    local store built by the shared constructor."""
    lstore = make_local_store(vals, slot_is_max)
    _, new_vals, _ = eval_tstream_scan(
        lstore, lops, funs,
        prestructured=restructure(lops, lstore.pad_uid, rowmajor_ts=True))
    return new_vals


def evaluate_sharded(store: StateStore, ops: OpBatch,
                     funs: Tuple[FunSpec, ...], mesh, layout: str):
    """TStream fast-path under a chain-shard layout (per-batch baseline).

    Returns values in the *original* slot order (un-permuted) for
    comparison; the layout governs where evaluation runs and which
    collectives reconcile state.  ``ops`` must come from ``build_opbatch``
    — row order is (ts, slot).
    """
    assert layout in LAYOUTS, layout
    from jax.experimental.shard_map import shard_map

    n_dev = mesh.size
    axes = mesh.axis_names
    n_sockets = mesh.shape.get("socket", 1)
    n_owners = {"shared_nothing": n_dev,
                "shared_per_socket": n_sockets,
                "shared_everything": 1}[layout]

    own = build_ownership(store, n_owners)
    per, s_pad = own.per, own.s_pad
    has_max = own.slot_is_max is not None
    values = permute_values(own, store.values)              # [s_pad+1, W]
    sim = (own.slot_is_max if has_max
           else jnp.zeros((s_pad + 1,), bool))
    rops = _remap_ops(ops, own.fwd, s_pad)
    width = values.shape[1]

    def my_dev():
        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def blocked(x, n_blocks, fill):
        """[s_pad(+1), ...] -> [n_blocks*(per+1), ...] with per-block pad."""
        core = x[:s_pad].reshape((n_blocks, per) + x.shape[1:])
        pad = jnp.full((n_blocks, 1) + x.shape[1:], fill, x.dtype)
        return jnp.concatenate([core, pad], axis=1).reshape(
            (n_blocks * (per + 1),) + x.shape[1:])

    def unblocked(x, n_blocks):
        return x.reshape((n_blocks, per + 1) + x.shape[1:])[:, :per].reshape(
            (n_blocks * per,) + x.shape[1:])

    if layout == "shared_nothing":
        # local state block [per+1, W]; ops with non-local uid -> local pad
        def body(vals_local, sim_local, ops_rep):
            base = my_dev() * per
            local_uid = ops_rep.uid - base
            is_local = (local_uid >= 0) & (local_uid < per) & ops_rep.valid
            lops = dataclasses.replace(
                ops_rep, uid=jnp.where(is_local, local_uid, per),
                valid=is_local)
            return _eval_local(vals_local, lops,
                               sim_local if has_max else None, funs)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(axes), P(axes), P()), out_specs=P(axes),
                       check_rep=False)
        out_blocks = fn(blocked(values, n_dev, 0.0),
                        blocked(sim, n_dev, False), rops)
        out = unblocked(out_blocks, n_dev)
        # original slot order, pad row dropped (the historical contract)
        return unpermute_values(
            own, jnp.concatenate([out, jnp.zeros((1, width))]))[:-1]

    if layout == "shared_per_socket":
        core_axis = axes[-1]

        def body(vals, sim_local, ops_rep):
            sock = jax.lax.axis_index(axes[0])
            core = jax.lax.axis_index(core_axis)
            n_core = mesh.shape[core_axis]
            base = sock * per
            local_uid = ops_rep.uid - base
            mine = (local_uid >= 0) & (local_uid < per) & ops_rep.valid \
                & ((ops_rep.uid % n_core) == core)   # split chains in socket
            lops = dataclasses.replace(
                ops_rep, uid=jnp.where(mine, local_uid, per), valid=mine)
            new_vals = _eval_local(vals, lops,
                                   sim_local if has_max else None, funs)
            delta = new_vals - vals
            merged = vals + jax.lax.psum(delta, core_axis)  # intra-socket
            # output must mention EVERY mesh axis (chunk the replicated
            # socket block across cores) — see ownership.chunk_shard_output
            return chunk_shard_output(merged, core, n_core)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(axes[0]), P(axes[0]), P()),
                       out_specs=P(axes), check_rep=False)
        out_chunks = fn(blocked(values, n_sockets, 0.0),
                        blocked(sim, n_sockets, False), rops)
        out = unchunk_output(out_chunks, n_sockets, per).reshape(s_pad, width)
        return unpermute_values(
            own, jnp.concatenate([out, jnp.zeros((1, width))]))[:-1]

    # shared_everything: replicated state, global psum merge
    def body(vals, ops_rep):
        dev = my_dev()
        mine = ((ops_rep.uid % n_dev) == dev) & ops_rep.valid
        lops = dataclasses.replace(
            ops_rep, uid=jnp.where(mine, ops_rep.uid, s_pad), valid=mine)
        new_vals = _eval_local(vals, lops, sim if has_max else None, funs)
        delta = new_vals - vals
        merged = vals + jax.lax.psum(delta, axes)       # global merge
        return chunk_shard_output(merged, dev, n_dev)
    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=P(axes),
                   check_rep=False)
    out = fn(values, rops)
    out = unchunk_output(out, 1, s_pad + 1).reshape(s_pad + 1, width)
    return unpermute_values(own, out)[:-1]
