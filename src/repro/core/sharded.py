"""Chain-shard layouts over a device mesh — the paper's NUMA-aware
processing configurations (§IV-E) mapped to SPMD (DESIGN.md §2):

  shared-nothing     state slots owned by one device (contiguous after an
                     ownership permutation); chains evaluate where their
                     state lives; **zero collectives**
  shared-per-socket  state owned per 'socket' mesh axis, work split across
                     the socket's 'core' axis -> intra-socket psum only
  shared-everything  state replicated; work split across all devices ->
                     global psum of state deltas (cross-socket traffic)

All three evaluate the same restructured batch with identical results;
compiled collective bytes per layout quantify the paper's Fig. 14 finding
(shared-nothing wins; cross-socket communication hurts).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .engines import eval_tstream_scan
from .restructure import restructure
from .types import FunSpec, OpBatch, StateStore, make_store

LAYOUTS = ("shared_nothing", "shared_per_socket", "shared_everything")


def _owner_permute_store(store: StateStore, n_owners: int):
    """Pad slots to a multiple of n_owners and build old->new slot maps so
    owner(uid) = uid % n_owners becomes a *contiguous* range per owner."""
    s = store.n_slots
    per = -(-s // n_owners)
    s_pad = per * n_owners
    old = jnp.arange(s)
    new = (old % n_owners) * per + old // n_owners
    fwd = jnp.full((s + 1,), s_pad, jnp.int32).at[old].set(
        new.astype(jnp.int32))          # old uid -> new uid (pad -> s_pad)
    values = jnp.zeros((s_pad + 1, store.values.shape[1]),
                       store.values.dtype)
    values = values.at[fwd[:-1]].set(store.values[:-1])
    inv = jnp.zeros((s_pad,), jnp.int32).at[new].set(old.astype(jnp.int32))
    return values, fwd, inv, per, s_pad


def _remap_ops(ops: OpBatch, fwd: jnp.ndarray, pad_new: int) -> OpBatch:
    uid = jnp.where(ops.valid, jnp.take(fwd, ops.uid), pad_new)
    return dataclasses.replace(ops, uid=uid)


def evaluate_sharded(store: StateStore, ops: OpBatch,
                     funs: Tuple[FunSpec, ...], mesh, layout: str):
    """TStream fast-path under a chain-shard layout.

    Returns values in the *original* slot order (un-permuted) for
    comparison; the layout governs where evaluation runs and which
    collectives reconcile state.  Each shard body restructures its remapped
    local batch exactly once and threads the sorted view into the engine
    (``ops`` must come from ``build_opbatch`` — row order is (ts, slot)).
    """
    assert layout in LAYOUTS, layout
    # local stores merge tables into one slot range; per-slot max-type info
    # survives only for homogeneous stores (fine for GS/SL/OB; not TP).
    assert len(set(store.table_is_max)) == 1, \
        "sharded layouts require a homogeneous table family"
    from jax.experimental.shard_map import shard_map

    n_dev = mesh.size
    axes = mesh.axis_names
    n_sockets = mesh.shape.get("socket", 1)
    n_owners = {"shared_nothing": n_dev,
                "shared_per_socket": n_sockets,
                "shared_everything": 1}[layout]
    n_owners = max(n_owners, 1)

    values, fwd, inv, per, s_pad = _owner_permute_store(store, max(n_owners,
                                                                   1))
    rops = _remap_ops(ops, fwd, s_pad)

    def my_dev():
        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    if layout == "shared_nothing":
        # local state block [per+1, W]; ops with non-local uid -> local pad
        def body(vals_local, ops_rep):
            dev = my_dev()
            base = dev * per
            local_uid = ops_rep.uid - base
            is_local = (local_uid >= 0) & (local_uid < per) & ops_rep.valid
            lops = dataclasses.replace(
                ops_rep, uid=jnp.where(is_local, local_uid, per),
                valid=is_local)
            lstore = make_store([per], store.values.shape[1],
                                init=vals_local)
            lstore = dataclasses.replace(
                lstore, table_is_max=(any(store.table_is_max),),
                table_base=(0,), table_capacity=(per,))
            _, new_vals, _ = eval_tstream_scan(
                lstore, lops, funs,
                prestructured=restructure(lops, lstore.pad_uid,
                                          rowmajor_ts=True))
            return new_vals

        # values [s_pad+1] -> per-device blocks [per+1]: drop global pad row,
        # reshape to [n_dev, per], append a local pad row per device.
        blocks = values[:-1].reshape(n_dev, per,
                                     values.shape[1])
        blocks = jnp.concatenate(
            [blocks, jnp.zeros((n_dev, 1, values.shape[1]),
                               values.dtype)], axis=1)
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(axes), P()), out_specs=P(axes),
                       check_rep=False)
        out_blocks = fn(blocks.reshape(n_dev * (per + 1), values.shape[1]),
                        rops)
        out = out_blocks.reshape(n_dev, per + 1, -1)[:, :per].reshape(
            n_dev * per, -1)
        return jnp.take(out, fwd[:-1], axis=0)  # back to original slot order

    if layout == "shared_per_socket":
        core_axis = axes[-1]

        def body(vals, ops_rep):
            sock = jax.lax.axis_index(axes[0])
            core = jax.lax.axis_index(core_axis)
            n_core = mesh.shape[core_axis]
            base = sock * per
            local_uid = ops_rep.uid - base
            mine = (local_uid >= 0) & (local_uid < per) & ops_rep.valid \
                & ((ops_rep.uid % n_core) == core)   # split chains in socket
            lops = dataclasses.replace(
                ops_rep, uid=jnp.where(mine, local_uid, per), valid=mine)
            lstore = make_store([per], store.values.shape[1], init=vals)
            lstore = dataclasses.replace(
                lstore, table_is_max=(any(store.table_is_max),))
            _, new_vals, _ = eval_tstream_scan(
                lstore, lops, funs,
                prestructured=restructure(lops, lstore.pad_uid,
                                          rowmajor_ts=True))
            delta = new_vals - vals
            return vals + jax.lax.psum(delta, core_axis)  # intra-socket

        blocks = values[:-1].reshape(n_sockets, per, values.shape[1])
        blocks = jnp.concatenate(
            [blocks, jnp.zeros((n_sockets, 1, values.shape[1]),
                               values.dtype)], axis=1)
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(axes[0]), P()), out_specs=P(axes[0]),
                       check_rep=False)
        out_blocks = fn(blocks.reshape(n_sockets * (per + 1),
                                       values.shape[1]), rops)
        out = out_blocks.reshape(n_sockets, per + 1, -1)[:, :per].reshape(
            n_sockets * per, -1)
        return jnp.take(out, fwd[:-1], axis=0)

    # shared_everything: replicated state, global psum merge
    def body(vals, ops_rep):
        dev = my_dev()
        mine = ((ops_rep.uid % n_dev) == dev) & ops_rep.valid
        lops = dataclasses.replace(
            ops_rep, uid=jnp.where(mine, ops_rep.uid, s_pad), valid=mine)
        lstore = make_store([s_pad], store.values.shape[1], init=vals)
        lstore = dataclasses.replace(
            lstore, table_is_max=(any(store.table_is_max),))
        _, new_vals, _ = eval_tstream_scan(
            lstore, lops, funs,
            prestructured=restructure(lops, lstore.pad_uid,
                                      rowmajor_ts=True))
        delta = new_vals - vals
        return vals + jax.lax.psum(delta, axes)       # global merge

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_rep=False)
    out = fn(values, rops)
    return jnp.take(out[:-1], fwd[:-1], axis=0)
