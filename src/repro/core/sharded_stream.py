"""Device-parallel fused streaming: sharded ``run_stream`` (DESIGN.md §2.5).

The whole event stream runs as ONE jitted ``shard_map`` whose interior is
the same hoist-then-scan schedule as the single-device fused driver
(``scheduler._fused_impl``), with state partitioned by ownership:

* **compute mode is event-parallel**: each device pre-processes and
  registers ops for its contiguous slice of every punctuation interval;
* **ops are owner-routed, not replicated**: each device buckets its ops
  by ``owner(uid)`` with the capacity-padded one-pass counting partition
  (``core/ownership`` over ``kernels/radix_partition``) and ships them
  with a single ``all_to_all``
  covering *every interval at once* — O(N + padding) exchanged rows per
  interval instead of the per-batch path's O(n_dev · N) replication;
* **each device restructures and evaluates only its local chains**; the
  restructure sort, affine/max coefficient scans and per-state commit
  maps are hoisted out of the interval scan exactly as in
  ``scheduler._fused_assoc``.  The segment-relative segmented scans
  (``restructure.py``) make chain results independent of where a chain
  lands in a device's buffer, so the sharded schedule is bit-identical
  to the single-device fused driver;
* **results are returned by the reverse exchange** (same buckets,
  mirrored ``all_to_all``) and post-processing stays event-parallel.

Layouts (paper §IV-E / Fig. 14):

  shared_nothing    state blocks per device; zero collectives inside the
                    interval scan (the exchange is hoisted)
  shared_per_socket state blocks per socket, replicated across that
                    socket's cores; ops routed to the owning socket then
                    all-gathered intra-socket; chains split across cores;
                    one intra-socket merge per interval
  shared_everything state replicated; chains routed round-robin across
                    all devices; one global merge per interval

State merges use an ownership-masked ``pmax`` select (every slot has
exactly one writer), not delta addition, so all layouts stay bit-exact.

Non-associative / gated apps (SL, OB) run the lockstep schedule sharded
under ``shared_nothing`` on a 1-D mesh: chains walk locally; cross-chain
CFun gates resolve level-wise with the per-level success frontier merged
across devices ([N+1] bool ``pmax`` on global op indices); dependency-
cycle residue falls back to a replicated sequential sweep over the
gathered residue ops (all devices compute it identically, then retake
their shard).  Exchange-capacity overflow *drops* ops; drops are counted
per interval and surfaced in the engine stats — never silent.
"""
from __future__ import annotations

import dataclasses
import logging
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .blotter import AppSpec, build_opbatch
from .engines import (apply_funs, funs_apply_single, simple_affine_luts,
                      tstream_scan_coefs_stream, tstream_scan_execute,
                      tstream_scan_plan)
from .ownership import (LAYOUTS, bucket_by_owner, build_ownership,
                        build_probe_route, chunk_shard_output,
                        exchange_capacity, make_local_store, migration_plan,
                        permute_values, route_gather, unchunk_output,
                        unpermute_values, unroute_gather)
from .restructure import Chains, megakernel_engaged, restructure_stream
from .types import OpBatch, StateStore

log = logging.getLogger(__name__)

_INF = jnp.int32(10 ** 6)


def _bool_pmax(x: jnp.ndarray, axes) -> jnp.ndarray:
    return jax.lax.pmax(x.astype(jnp.int32), axes) > 0


class ShardedStream:
    """Sharded fused streaming driver bound to one (app, mesh, layout).

    The ownership permutation, routing tables and the jitted whole-stream
    program are built ONCE here — per-call work is limited to reshaping
    the host stream and one dispatch.
    """

    def __init__(self, app: AppSpec, store: StateStore, cfg, mesh,
                 layout: str = "shared_nothing", exchange_slack: float = 2.0):
        assert layout in LAYOUTS, layout
        if cfg.scheme not in ("tstream", "tstream_scan", "tstream_lockstep",
                              "mvlk"):
            raise ValueError(
                f"sharded run_stream implements the TStream/mvlk engines "
                f"only (got scheme={cfg.scheme!r})")
        self.app, self.cfg, self.mesh, self.layout = app, cfg, mesh, layout
        self.store = store
        self.exchange_slack = float(exchange_slack)
        self.axes = tuple(mesh.axis_names)
        self.n_dev = mesh.size

        self.assoc = (app.associative_only
                      and cfg.scheme in ("tstream", "tstream_scan")
                      and not (cfg.abort_repass and app.may_abort))
        if layout == "shared_per_socket":
            assert len(self.axes) == 2, \
                "shared_per_socket needs a (socket, core) mesh"
            self.n_sockets = mesh.shape[self.axes[0]]
            self.n_core = mesh.shape[self.axes[1]]
            n_owners, self.n_route = self.n_sockets, self.n_sockets
            self.route_axes = (self.axes[0],)
        else:
            n_owners = self.n_dev if layout == "shared_nothing" else 1
            self.n_route = self.n_dev
            self.route_axes = self.axes
        if not self.assoc:
            # lockstep sharding (mvlk included: eval_mvlk IS the lockstep
            # schedule) exchanges gate successes on global op ids; state
            # must be device-resident and the mesh flat
            assert layout == "shared_nothing" and len(self.axes) == 1, \
                ("non-associative/gated apps shard under shared_nothing "
                 "on a 1-D mesh")

        self._n_owners = n_owners
        self._bind_ownership(())
        # same output program as the single-device drivers (_post_stream):
        # identical function + identical [n_intervals, N, ...] shapes =>
        # identical compilation => bit-identical outputs
        from .scheduler import _post_stream
        self._post = jax.jit(partial(_post_stream, app=app))
        self.last_stats: Optional[Dict] = None

    def _bind_ownership(self, overrides) -> None:
        """(Re)build the ownership permutation, routing tables and every
        jitted entry against ``overrides`` — the one place the sharded
        plan binds to a placement (construction, restore, migration)."""
        self.own = build_ownership(self.store, self._n_owners, overrides)
        self.probe = None
        if getattr(self.cfg, "use_hash_probe_route", False):
            fwd = np.asarray(self.own.fwd)[:-1]
            if self.layout == "shared_everything":
                owner = fwd % self.n_dev
            else:
                owner = fwd // self.own.per
            self.probe = build_probe_route(self.store.n_slots, owner,
                                           miss_owner=self.n_route)
        self._impl = jax.jit(partial(_sharded_blocks_impl, eng=self),
                             donate_argnums=0)
        self._to_blocks = jax.jit(partial(_to_blocks_impl, eng=self))
        # NO donation: snapshots read the carry mid-run and keep using it
        self._from_blocks = jax.jit(partial(_from_blocks_impl, eng=self))

    @property
    def owners(self):
        """Current ownership overrides (sorted ``((uid, owner), ...)``)."""
        return self.own.overrides

    @property
    def reshardable(self) -> bool:
        """Live migration needs one state block per device (the moved-rows
        exchange is a device-level all_to_all) and >1 owner to move to."""
        return (self.layout == "shared_nothing" and self.n_dev > 1
                and self.probe is None)

    def set_ownership(self, overrides) -> None:
        """Rebind the pre-jitted plan to a new placement WITHOUT touching
        data — for restoring a snapshot taken on a migrated layout (the
        snapshot stores canonical-order values; ``carry_in`` lays them
        out under whatever ownership is bound here)."""
        overrides = tuple(sorted((int(u), int(o)) for u, o in overrides))
        if overrides != self.own.overrides:
            self._bind_ownership(overrides)

    def reshard(self, blocks, overrides):
        """Live migration: move the block carry onto a new placement.

        Ships ONLY moved rows via the owner-routed ``all_to_all`` (exact
        capacity from the host-side :func:`migration_plan` — migrations
        never drop rows), then rebinds the jitted plan to the new
        ownership.  Returns ``(blocks, moved_rows)``.  Must run at a
        punctuation boundary with the pipeline drained (the service's
        snapshot barrier).
        """
        assert self.reshardable, (self.layout, self.n_dev)
        overrides = tuple(sorted((int(u), int(o)) for u, o in overrides))
        if overrides == self.own.overrides:
            return blocks, 0
        new_own = build_ownership(self.store, self._n_owners, overrides)
        dst, nidx, cap = migration_plan(self.own, new_own)
        fn = jax.jit(partial(_migrate_impl, eng=self, cap=cap),
                     donate_argnums=0)
        blocks, moved = fn(blocks, jnp.asarray(dst), jnp.asarray(nidx))
        self._bind_ownership(overrides)
        return blocks, int(jax.device_get(moved))

    # -- block carry <-> canonical values ---------------------------------
    def carry_in(self, values):
        """[S+1, W] canonical values -> the resident block carry."""
        return self._to_blocks(values)

    def carry_out(self, blocks):
        """Block carry -> [S+1, W] canonical values (no donation)."""
        return self._from_blocks(blocks)

    # -- host driver ------------------------------------------------------
    def run_stream(self, values, event_stream, punct_interval: int):
        n = len(next(iter(event_stream.values())))
        interval = int(punct_interval)
        assert interval % self.n_dev == 0, \
            (f"punct_interval={interval} must divide evenly across "
             f"{self.n_dev} devices")
        n_intervals = n // interval
        if n_intervals == 0:
            # publish empty (not stale) exchange stats for this call
            self.last_stats = dict(
                dropped=np.zeros((0,), np.int32),
                shipped=np.zeros((0,), np.int32),
                max_fill=np.zeros((0,), np.int32),
                capacity=np.int32(0),
                exchanged_rows_per_device=np.int32(0))
            return [], values
        batched = {}
        for k, v in event_stream.items():
            v = np.asarray(v)[: n_intervals * interval]
            batched[k] = jnp.asarray(
                v.reshape((n_intervals, interval) + v.shape[1:]))
        blocks = self._to_blocks(jnp.asarray(values))
        res_all, ebs_all, blocks, stats = self._impl(
            blocks, batched, jnp.int32(0))
        values = self._from_blocks(blocks)
        stats = jax.device_get(stats)
        self.last_stats = stats
        total_dropped = int(np.sum(stats["dropped"]))
        if total_dropped:
            # overflow accounting goes through the process-wide telemetry
            # registry (DESIGN.md §2.11): counted always, logged as a
            # rate-unlimited structured event with the exact legacy
            # message.  Imported lazily so core never pulls the runtime
            # package at module-import time (layering).
            from repro.runtime.telemetry import get_default
            tele = get_default()
            tele.count("exchange.dropped", total_dropped,
                       driver="run_stream")
            tele.count("exchange.shipped", int(np.sum(stats["shipped"])),
                       driver="run_stream")
            tele.event(
                "exchange.overflow",
                "sharded exchange overflow: %d ops dropped across %d "
                "intervals (capacity=%d/bucket, slack=%.2f); results "
                "exclude dropped ops — raise exchange_slack",
                total_dropped, n_intervals, stats["capacity"],
                self.exchange_slack, logger=log, limit=-1)
        outs = jax.device_get(self._post(res_all, ebs_all))
        return ([jax.tree_util.tree_map(lambda x, i=i: x[i], outs)
                 for i in range(n_intervals)], values)

    def set_exchange_slack(self, slack: float) -> None:
        """Graceful degradation under repeated exchange overflow: widen
        the per-bucket capacity at a punctuation boundary.

        The capacity is a *python* value baked into the jitted program's
        trace, so changing the slack must rebind the jit wrapper — the
        next dispatch recompiles with the new capacity (the caller logs
        the escalation; results for shipped ops are unaffected, only the
        padding widens)."""
        self.exchange_slack = float(slack)
        self._impl = jax.jit(partial(_sharded_blocks_impl, eng=self),
                             donate_argnums=0)

    def run_chunk(self, blocks, batched, ts0: int):
        """Chunked service entry (see ``DualModeEngine.run_stream_chunk``).

        ``blocks`` is the resident block carry (``carry_in`` of the
        canonical values — the per-chunk permute/unpermute round-trip of
        the pre-elastic driver is gone) and is donated; ``batched``
        leaves are ``[K, interval, ...]``.  Returns unmaterialized device
        arrays plus the per-chunk exchange stats ``dict`` for the caller
        to aggregate — overflow is NOT logged here: the service logs each
        drop category once per run.
        """
        return self._impl(blocks, batched, jnp.int32(ts0))


# ---------------------------------------------------------------------------
# the jitted whole-stream program (block-carry form)
# ---------------------------------------------------------------------------
def _lane_width(eng: ShardedStream) -> int:
    """Pallas fast path: lane-pad state once per stream (operands pad
    after the exchange so wire bytes stay at W lanes)."""
    W = eng.app.width
    if eng.cfg.use_pallas and eng.assoc:
        from repro.kernels.segscan import kernel as K
        return max(W, K.LANES)
    return W


def _n_blocks(eng: ShardedStream) -> int:
    return eng.n_dev if eng.layout == "shared_nothing" else eng.n_sockets


def _to_blocks_impl(values, *, eng: ShardedStream):
    """[S+1, W] canonical values -> the resident block carry.

    The carry IS the per-device state layout — ``[n_blocks*(per+1), Wp]``
    (one ``[per+1, Wp]`` block per owner, pad chain last) for the
    partitioned layouts, the full ``[s_pad+1, Wp]`` permuted buffer for
    shared_everything — so chunks chain block-to-block with NO per-chunk
    permute/unpermute round-trip.
    """
    own, layout = eng.own, eng.layout
    per, s_pad, W = own.per, own.s_pad, eng.app.width
    Wp = _lane_width(eng)
    vperm = permute_values(own, values)                       # [s_pad+1, W]
    if Wp > W:
        vperm = jnp.pad(vperm, ((0, 0), (0, Wp - W)))
    if layout == "shared_everything":
        return vperm
    nb = _n_blocks(eng)
    return jnp.concatenate(
        [vperm[:-1].reshape(nb, per, Wp),
         jnp.zeros((nb, 1, Wp), vperm.dtype)],
        axis=1).reshape(nb * (per + 1), Wp)


def _from_blocks_impl(blocks, *, eng: ShardedStream):
    """Block carry -> [S+1, W] canonical values (exact gathers only)."""
    own, layout = eng.own, eng.layout
    per, s_pad, W = own.per, own.s_pad, eng.app.width
    Wp = _lane_width(eng)
    if layout == "shared_everything":
        vperm_out = blocks[:s_pad]
    else:
        vperm_out = blocks.reshape(_n_blocks(eng), per + 1, Wp)[:, :per]
        vperm_out = vperm_out.reshape(s_pad, Wp)
    vperm_out = vperm_out[:, :W]
    return unpermute_values(
        own, jnp.concatenate([vperm_out, jnp.zeros((1, W),
                                                   vperm_out.dtype)]))


def _sharded_blocks_impl(blocks, events_b, ts0, *, eng: ShardedStream):
    from jax.experimental.shard_map import shard_map

    app, cfg, own, layout = eng.app, eng.cfg, eng.own, eng.layout
    mesh, axes = eng.mesh, eng.axes
    n_dev, n_route = eng.n_dev, eng.n_route
    some = jax.tree_util.tree_leaves(events_b)[0]
    n_intervals, interval = some.shape[0], some.shape[1]
    E_loc = interval // n_dev
    N_loc = E_loc * app.max_ops
    N_glob = interval * app.max_ops
    cap = exchange_capacity(N_loc, n_route, eng.exchange_slack)
    per, s_pad = own.per, own.s_pad
    W = app.width
    has_max = any(eng.store.table_is_max)
    lpad = s_pad if layout == "shared_everything" else per
    Wp = _lane_width(eng)

    # ---- per-slot max flags in carry layout (values-independent) --------
    sim = own.slot_is_max if has_max else jnp.zeros((s_pad + 1,), bool)
    if layout == "shared_everything":
        sim_b = sim
        state_spec = P()
    else:
        nb = _n_blocks(eng)
        sim_b = jnp.concatenate(
            [sim[:-1].reshape(nb, per),
             jnp.zeros((nb, 1), bool)], axis=1).reshape(-1)
        state_spec = P(axes) if layout == "shared_nothing" else P(axes[0])

    body = partial(_stream_body, eng=eng, dims=dict(
        n_intervals=n_intervals, interval=interval, E_loc=E_loc,
        N_loc=N_loc, N_glob=N_glob, cap=cap, lpad=lpad, Wp=Wp),
        has_max=has_max, ts0=ts0)
    # specs are pytree prefixes: one spec covers a whole output subtree;
    # every spec mentions every mesh axis (see the chunk-sharding note at
    # the end of _stream_body)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, state_spec, P(None, axes)),
        out_specs=(P(None, axes), P(None, axes), P(axes), P(axes), P(axes),
                   P(axes), P(axes)),
        check_rep=False)
    (res_all, ebs_all, blocks_out, dropped, shipped, fills,
     loads) = fn(blocks, sim_b, events_b)
    dropped = jnp.sum(dropped, axis=0)                    # [n_intervals]
    shipped = jnp.sum(shipped, axis=0)
    fills = jnp.max(fills, axis=0)                        # [n_intervals]

    # ---- carry out: reassemble the canonical block layout ---------------
    if layout == "shared_nothing":
        # the body's [per+1, Wp] outputs concatenate under P(axes) into
        # exactly the carry layout — chunks chain with zero data movement
        carry = blocks_out
    elif layout == "shared_per_socket":
        vperm_out = unchunk_output(blocks_out, eng.n_sockets, per)
        nb = eng.n_sockets
        carry = jnp.concatenate(
            [vperm_out, jnp.zeros((nb, 1, Wp), vperm_out.dtype)],
            axis=1).reshape(nb * (per + 1), Wp)
    else:  # shared_everything: chunks concatenate back to the full buffer
        vperm_out = unchunk_output(blocks_out, 1, s_pad).reshape(s_pad, Wp)
        carry = jnp.concatenate(
            [vperm_out, jnp.zeros((1, Wp), vperm_out.dtype)])

    # ---- per-shard / per-slot access histogram (skew observability) -----
    # loads: [n_dev, lpad+1] valid routed ops served per local slot
    if layout == "shared_nothing":
        l2 = loads.reshape(n_dev, per + 1)[:, :per]
        shard_load = jnp.sum(l2, axis=1)                      # [n_dev]
        slot_perm = l2.reshape(s_pad)
    elif layout == "shared_per_socket":
        l3 = jnp.sum(loads.reshape(eng.n_sockets, eng.n_core, per + 1),
                     axis=1)[:, :per]
        shard_load = jnp.sum(l3, axis=1)                      # [n_sockets]
        slot_perm = l3.reshape(s_pad)
    else:  # shared_everything: owner(slot) = slot % n_dev
        slot_perm = jnp.sum(loads.reshape(n_dev, s_pad + 1), axis=0)[:s_pad]
        shard_load = jax.ops.segment_sum(
            slot_perm, jnp.arange(s_pad) % n_dev, num_segments=n_dev)
    slot_load = jnp.take(slot_perm, own.fwd[:-1])             # original uids

    stats = dict(dropped=dropped, shipped=shipped, max_fill=fills,
                 capacity=jnp.int32(cap),
                 exchanged_rows_per_device=jnp.int32(n_dev * cap),
                 shard_load=shard_load, slot_load=slot_load)
    return res_all, ebs_all, carry, stats


# ---------------------------------------------------------------------------
# live migration: moved rows only, via the owner-routed all_to_all
# ---------------------------------------------------------------------------
def _migrate_impl(blocks, dstv, nidxv, *, eng: ShardedStream, cap: int):
    """Move the block carry onto a new ownership (shared_nothing only).

    ``dstv``/``nidxv`` come from :func:`ownership.migration_plan`: per
    (device, block row) the new owner and the row's index in the new
    owner's block.  ``cap`` is the exact max moved-rows count between any
    device pair, so the exchange never drops (zero loss by construction).
    """
    from jax.experimental.shard_map import shard_map

    axes, n_dev, per = eng.axes, eng.n_dev, eng.own.per
    body = partial(_migrate_body, axes=axes, n_dev=n_dev, per=per, cap=cap)
    fn = shard_map(body, mesh=eng.mesh,
                   in_specs=(P(axes), P(axes), P(axes)),
                   out_specs=(P(axes), P(axes)), check_rep=False)
    blocks, moved = fn(blocks, dstv, nidxv)
    return blocks, jnp.sum(moved)


def _migrate_body(block, dstv, nidxv, *, axes, n_dev, per, cap):
    """Per-device migration: local stay-scatter + moved-rows exchange."""
    dev = jax.lax.axis_index(axes[0])
    dstv = dstv.reshape(per)
    nidxv = nidxv.reshape(per)
    rows = block[:per]
    stay = dstv == dev
    out = jnp.zeros_like(block)
    # rows that stay scatter straight to their new index (dead padding
    # rows carry nidx == per and land on the pad chain, zeroed below)
    out = out.at[jnp.where(stay, nidxv, per)].set(
        jnp.where(stay[:, None], rows, 0.0))
    # moved rows bucket by new owner and ship with ONE all_to_all; cells
    # beyond a pair's move count are ok=False -> value 0.0 at index per
    dst = jnp.where(stay, n_dev, dstv).astype(jnp.int32)
    plan = bucket_by_owner(dst, n_dev, cap)
    srows = route_gather(plan, rows, 0.0)                 # [n_dev, cap, Wp]
    sidx = route_gather(plan, nidxv, per)                 # [n_dev, cap]
    rrows = jax.lax.all_to_all(srows, axes, split_axis=0, concat_axis=0)
    ridx = jax.lax.all_to_all(sidx, axes, split_axis=0, concat_axis=0)
    out = out.at[ridx.reshape(-1)].set(rrows.reshape(-1, rrows.shape[-1]))
    out = out.at[per].set(0.0)
    moved = jnp.sum(plan.ok.astype(jnp.int32))
    return out, moved[None]


def _stream_body(blocks, sim_b, events_loc, *, eng: ShardedStream, dims,
                 has_max, ts0):
    """shard_map body: the per-device program for the whole stream."""
    app, cfg, own, layout = eng.app, eng.cfg, eng.own, eng.layout
    axes, mesh = eng.axes, eng.mesh
    n_dev, n_route = eng.n_dev, eng.n_route
    n_intervals, interval = dims["n_intervals"], dims["interval"]
    E_loc, N_loc, N_glob = dims["E_loc"], dims["N_loc"], dims["N_glob"]
    cap, lpad, Wp = dims["cap"], dims["lpad"], dims["Wp"]
    per, s_pad = own.per, own.s_pad
    W = app.width

    dev = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        dev = dev * mesh.shape[a] + jax.lax.axis_index(a)
    if layout == "shared_per_socket":
        sock = jax.lax.axis_index(axes[0])
        core = jax.lax.axis_index(axes[1])

    # ---- compute mode: event-parallel op registration (all intervals) ---
    ts_bases = (ts0 + jnp.arange(n_intervals, dtype=jnp.int32) * interval
                + dev * E_loc)
    ops_all, ebs_all = jax.vmap(
        lambda ev, tb: build_opbatch(app, eng.store, ev, tb))(
            events_loc, ts_bases)
    base = dev * N_loc
    gflat = jnp.broadcast_to(base + jnp.arange(N_loc, dtype=jnp.int32),
                             (n_intervals, N_loc))
    gate_all = jnp.where(ops_all.gate >= 0, ops_all.gate + base, -1)

    # ---- owner routing (values-independent, hoisted) --------------------
    uid_perm = jnp.take(own.fwd, ops_all.uid)            # [n_i, N_loc]
    if eng.probe is not None:
        dst_v = eng.probe.owners_of(
            ops_all.uid.reshape(-1)).reshape(n_intervals, N_loc)
    elif layout == "shared_everything":
        dst_v = uid_perm % n_dev
    else:
        dst_v = uid_perm // per
    dst = jnp.where(ops_all.valid, dst_v, n_route).astype(jnp.int32)
    plans = jax.vmap(lambda d: bucket_by_owner(d, n_route, cap))(dst)

    if layout == "shared_everything":
        uid_send = jnp.where(ops_all.valid, uid_perm, lpad)
    else:
        uid_send = jnp.where(ops_all.valid,
                             uid_perm - jnp.minimum(dst_v, n_route - 1) * per,
                             lpad)
    rg = jax.vmap(route_gather, in_axes=(0, 0, None))
    send = dict(
        uid=rg(plans, uid_send, lpad),
        fun=rg(plans, ops_all.fun, 0),
        operand=rg(plans, ops_all.operand, 0.0),
        valid=rg(plans, ops_all.valid, False),
        ts=rg(plans, ops_all.ts, 0),
        slot=rg(plans, ops_all.slot, 0),
    )
    if not eng.assoc:
        send["gate"] = rg(plans, gate_all, -1)
        send["gflat"] = rg(plans, gflat, N_glob)

    # ---- THE exchange: one all_to_all for the whole stream --------------
    recv = {k: jax.lax.all_to_all(v, eng.route_axes, split_axis=1,
                                  concat_axis=1)
            for k, v in send.items()}
    if layout == "shared_per_socket":
        # intra-socket: every core sees the socket's full routed set, in
        # flat source-device order (socket-major) so rows stay ts-sorted
        recv = {k: jax.lax.all_gather(v, axes[1], axis=1)
                for k, v in recv.items()}
        recv = {k: jnp.moveaxis(v, 1, 2) for k, v in recv.items()}
    R = n_dev * dims["cap"]
    recv = {k: v.reshape((n_intervals, R) + v.shape[4 if layout ==
                         "shared_per_socket" else 3:])
            for k, v in recv.items()}

    rvalid = recv["valid"]
    ruid = recv["uid"]
    if layout == "shared_per_socket":
        rvalid = rvalid & ((ruid % eng.n_core) == core)
    operand = recv["operand"]
    if Wp > W:
        operand = jnp.pad(operand, ((0, 0), (0, 0), (0, Wp - W)))
    zeros_i = jnp.zeros((n_intervals, R), jnp.int32)
    rops = OpBatch(
        uid=ruid, ts=recv["ts"], txn=zeros_i, slot=recv["slot"],
        kind=zeros_i, fun=recv["fun"],
        gate=recv.get("gate", jnp.full((n_intervals, R), -1, jnp.int32)),
        operand=operand, valid=rvalid)

    # ---- local state / store ------------------------------------------
    if layout == "shared_everything":
        vals0 = blocks                                  # [s_pad+1, Wp]
        sim_loc = sim_b
    else:
        vals0 = blocks.reshape(per + 1, Wp)
        sim_loc = sim_b.reshape(per + 1)
    lstore = make_local_store(vals0, sim_loc if has_max else None)

    # ---- evaluate -------------------------------------------------------
    if eng.assoc:
        merge_axes = None
        own_mask = None
        if layout == "shared_per_socket":
            merge_axes = (axes[1],)
            own_mask = jnp.concatenate(
                [(jnp.arange(per) % eng.n_core) == core,
                 jnp.zeros((1,), bool)])
        elif layout == "shared_everything":
            merge_axes = axes
            own_mask = jnp.concatenate(
                [(jnp.arange(s_pad) % n_dev) == dev,
                 jnp.zeros((1,), bool)])
        mega_luts = simple_affine_luts(app.funs)
        if megakernel_engaged(R, lpad + 1, method=cfg.restructure_method,
                              has_max=has_max,
                              funs_simple=mega_luts is not None):
            # megakernel rung: a light geometry-free partition plan, then
            # ONE fused dispatch per interval replaces the staged
            # plan → coefs → execute pipeline (bit-identical — see
            # kernels/megakernel).  The ownership merge is unchanged.
            from repro.kernels.megakernel import fused_chain_eval
            a_lut, b_lut = mega_luts
            sops_all, ch_all = restructure_stream(
                rops, lpad, rowmajor_ts=True, light=True,
                method="partition", use_pallas=cfg.use_pallas,
                geometry=False,
                block_rows=cfg.block_param("radix_partition"))

            def sbody(vals, xs):
                sops, ch = xs
                res, new_vals, _ = fused_chain_eval(
                    vals, sops, ch, lpad, a_lut=a_lut, b_lut=b_lut,
                    use_pallas=cfg.use_pallas)
                if own_mask is not None:
                    new_vals = jax.lax.pmax(
                        jnp.where(own_mask[:, None], new_vals, -jnp.inf),
                        merge_axes)
                    new_vals = new_vals.at[lpad].set(0.0)
                return new_vals, res

            vals_fin, res_sorted = jax.lax.scan(sbody, vals0,
                                                (sops_all, ch_all))
            res_routed = {k: jax.vmap(Chains.untake)(ch_all, v)
                          for k, v in res_sorted.items()}
        else:
            pres_all = restructure_stream(
                rops, lpad, rowmajor_ts=True, light=True,
                method=cfg.restructure_method, use_pallas=cfg.use_pallas,
                block_rows=cfg.block_param("radix_partition"))
            plan_all = jax.vmap(
                lambda o, p: tstream_scan_plan(lstore, o, app.funs,
                                               prestructured=p))(rops,
                                                                 pres_all)
            plan_all = tstream_scan_coefs_stream(
                plan_all, use_pallas=cfg.use_pallas,
                block_rows=cfg.block_param("segscan"))

            def sbody(vals, plan):
                res, new_vals, _ = tstream_scan_execute(vals, plan, lpad,
                                                        raw=True)
                if own_mask is not None:
                    # ownership-masked SELECT (one writer per slot) —
                    # exact, unlike delta summation
                    new_vals = jax.lax.pmax(
                        jnp.where(own_mask[:, None], new_vals, -jnp.inf),
                        merge_axes)
                    new_vals = new_vals.at[lpad].set(0.0)
                return new_vals, res

            vals_fin, res_sorted = jax.lax.scan(sbody, vals0, plan_all)
            res_routed = {k: jax.vmap(Chains.untake)(plan_all.ch, v)
                          for k, v in res_sorted.items()}
    else:
        pres_all = restructure_stream(
            rops, lpad, rowmajor_ts=True,
            method=cfg.restructure_method, use_pallas=cfg.use_pallas,
            block_rows=cfg.block_param("radix_partition"))
        lk = partial(
            _lockstep_interval, eng=eng, R=R, N_glob=N_glob,
            pad_uid=lpad, Wq=Wp, axis=axes[0], per=per, s_pad=s_pad,
            max_ops=app.max_ops)

        def sbody(vals, xs):
            (sops, ch), gfr = xs
            vals2, res = lk(vals, sops, ch, gfr, dev=dev)
            return vals2, res

        vals_fin, res_routed = jax.lax.scan(
            sbody, vals0, (pres_all, recv["gflat"]))

    # ---- reverse exchange: results home to their source device ----------
    if layout == "shared_per_socket":
        # socket-complete results (each op evaluated on exactly one core)
        pp = {k: (jax.lax.psum(v.astype(jnp.int32), axes[1]) > 0
                  if v.dtype == jnp.bool_ else jax.lax.psum(v, axes[1]))
              for k, v in res_routed.items()}
        back = {k: v.reshape((n_intervals, eng.n_sockets, eng.n_core, cap)
                             + v.shape[2:])
                for k, v in pp.items()}
        back = {k: jax.lax.dynamic_index_in_dim(v, core, axis=2,
                                                keepdims=False)
                for k, v in back.items()}
    else:
        back = {k: v.reshape((n_intervals, n_dev, cap) + v.shape[2:])
                for k, v in res_routed.items()}
    back = {k: jax.lax.all_to_all(v, eng.route_axes, split_axis=1,
                                  concat_axis=1)
            for k, v in back.items()}
    back = {k: v.reshape((n_intervals, n_route * cap) + v.shape[3:])
            for k, v in back.items()}
    res_loc = {
        k: jax.vmap(lambda p, v: unroute_gather(p, v, n_route, cap))(
            plans, v)
        for k, v in back.items()}

    # per-device exchange stats; reduced outside the shard_map ([1, n_i]
    # rows concatenate to [n_dev, n_i] under the fully-specified spec)
    dropped = plans.dropped[None]
    shipped = jnp.sum(plans.ok.astype(jnp.int32), axis=(1, 2))[None]
    fills = plans.fill[None]
    # per-local-slot access histogram over the whole chunk — the skew
    # signal the controller's reshard knob feeds on ([1, lpad+1] rows
    # concatenate to [n_dev, lpad+1]); each valid routed op is counted on
    # exactly one device (per_socket: the core-residue filter above)
    loads = jax.ops.segment_sum(
        rvalid.astype(jnp.int32).reshape(-1),
        jnp.minimum(ruid, lpad).reshape(-1),
        num_segments=lpad + 1)[None]

    # Every out_spec must mention every mesh axis: an under-specified
    # output (value replicated across an unmentioned axis) is treated as
    # an unreduced partial by the surrounding SPMD program and gets
    # *summed* when resharded (observed: per-socket state scaled by
    # n_core).  State replicated across axes is therefore chunk-sharded
    # (ownership.chunk_shard_output) and reassembled by the caller.
    if layout == "shared_per_socket":
        vals_fin = chunk_shard_output(vals_fin, core, eng.n_core)
    elif layout == "shared_everything":
        vals_fin = chunk_shard_output(vals_fin, dev, n_dev)
    # res/ebs leave the shard_map event-sharded; post-processing runs in
    # the enclosing jit so its reductions compile in the same (fusion)
    # context as the single-device driver and stay bit-identical to it
    return res_loc, ebs_all, vals_fin, dropped, shipped, fills, loads


# ---------------------------------------------------------------------------
# sharded lockstep (non-associative / gated apps; shared_nothing, 1-D mesh)
# ---------------------------------------------------------------------------
def _lockstep_interval(vals, sops, ch, gflat_r, *, eng: ShardedStream, R,
                       N_glob, pad_uid, Wq, axis, per, s_pad, max_ops, dev):
    """One interval of the sharded lockstep schedule (+ abort repass)."""
    app, cfg = eng.app, eng.cfg
    gflat_s = jnp.take(gflat_r, ch.order)
    ev = partial(_lockstep_eval, eng=eng, R=R, N_glob=N_glob,
                 pad_uid=pad_uid, Wq=Wq, axis=axis, per=per, s_pad=s_pad,
                 gflat_r=gflat_r, gflat_s=gflat_s, dev=dev)
    vals1, res1, succ1 = ev(vals, sops, ch)
    if not (cfg.abort_repass and app.may_abort):
        return vals1, {k: v[:R] for k, v in res1.items()}

    # abort repass: mask whole transactions whose ops failed, re-evaluate
    # from the pre-interval values.  Txn verdicts need the *global* valid
    # mask and success frontier.
    valid_r = ch.untake(sops.valid)
    gvalid = _bool_pmax(
        jnp.zeros((N_glob + 1,), bool).at[gflat_r].set(valid_r), axis)
    succ2d = succ1[:N_glob].reshape(-1, max_ops)
    valid2d = gvalid[:N_glob].reshape(-1, max_ops)
    txn_ok = jnp.all(succ2d | ~valid2d, axis=1)           # [interval]
    keep_s = jnp.take(txn_ok, jnp.minimum(gflat_s // max_ops,
                                          txn_ok.shape[0] - 1))
    keep_s = keep_s & (gflat_s < N_glob)
    sops2 = dataclasses.replace(sops, valid=sops.valid & keep_s)
    vals2, res2, _ = ev(vals, sops2, ch)
    return vals2, {k: v[:R] for k, v in res2.items()}


def _lockstep_eval(vals, sops, ch, *, eng: ShardedStream, R, N_glob,
                   pad_uid, Wq, axis, per, s_pad, gflat_r, gflat_s, dev):
    """Level-wise lockstep chain walk with a cross-device success frontier.

    Mirrors ``engines.eval_tstream_lockstep`` exactly, except success
    lookups for cross-chain gates resolve through a global [N+1] success
    array (merged with a bool pmax after each level — a gated op's mate
    chain always sits at a strictly lower level), and dependency-cycle
    residue runs as a *replicated* sequential sweep over the all-gathered
    residue ops.
    """
    app, cfg = eng.app, eng.cfg
    funs = app.funs
    res = dict(pre=jnp.zeros((R + 1, Wq)), post=jnp.zeros((R + 1, Wq)),
               success=jnp.zeros((R + 1,), bool))
    succ_glob = jnp.zeros((N_glob + 1,), bool)
    g2l = jnp.full((N_glob + 1,), R, jnp.int32).at[gflat_r].set(
        jnp.arange(R, dtype=jnp.int32))

    if not app.has_gates:
        vals, res = _sweep_sharded(vals, sops, ch, funs,
                                   jnp.ones((R,), bool), res, R, pad_uid,
                                   ch.max_len, succ_glob, g2l)
        # res is recorded at routed-flat sinks (ch.order), so it scatters
        # to global op indices directly — gflat_r is routed-flat too
        succ_glob = _bool_pmax(
            jnp.zeros((N_glob + 1,), bool).at[gflat_r].set(
                res["success"][:R]), axis)
        return vals, res, succ_glob

    lvl, unresolved = _chain_levels_sharded(
        sops, ch, gflat_s, R, N_glob, cfg.max_dep_levels, axis)
    for L in range(cfg.max_dep_levels + 1):
        mask = lvl == L
        in_level = jnp.take(mask, ch.seg_id) & sops.valid
        lvl_rounds = jnp.max(jnp.where(in_level, ch.pos, -1)) + 1
        vals, res = _sweep_sharded(vals, sops, ch, funs, mask, res, R,
                                   pad_uid, lvl_rounds, succ_glob, g2l)
        # res sinks are routed-flat (ch.order): aligned with gflat_r as-is
        succ_glob = _bool_pmax(
            jnp.zeros((N_glob + 1,), bool).at[gflat_r].set(
                res["success"][:R]), axis)
    vals, res, succ_glob = _residue_sharded(
        vals, sops, ch, unresolved, res, succ_glob, eng=eng, R=R,
        N_glob=N_glob, per=per, s_pad=s_pad, axis=axis,
        gflat_r=gflat_r, gflat_s=gflat_s, Wq=Wq, dev=dev)
    return vals, res, succ_glob


def _sweep_sharded(values, sops, ch, funs, chain_mask, res, n, pad_uid,
                   rounds, succ_glob, g2l):
    """`engines._lockstep_sweep` with gate successes resolved locally when
    the mate op lives on this device (same-chain gates) and through the
    merged global frontier otherwise."""
    def round_body(r, carry):
        values, res = carry
        active = (ch.pos == r) & jnp.take(chain_mask, ch.seg_id) & sops.valid
        cur = jnp.take(values, sops.uid, axis=0)
        mate = jnp.maximum(sops.gate, 0)
        mate_loc = jnp.take(g2l, mate)
        # mate_loc == n marks a remote mate; row n of the success array is
        # the inactive-op dump slot and must never be read as a success
        ok_loc = (mate_loc < n) & jnp.take(res["success"], mate_loc)
        ok_glob = jnp.take(succ_glob, mate)
        gate_ok_s = jnp.where(sops.gate >= 0, ok_loc | ok_glob, True)
        post, ok = apply_funs(funs, sops.fun, cur, sops.operand)
        post = jnp.where(gate_ok_s[:, None], post, cur)
        ok = ok & gate_ok_s
        scat = jnp.where(active, sops.uid, pad_uid)
        values = values.at[scat].set(jnp.where(active[:, None], post, 0.0))
        values = values.at[pad_uid].set(0.0)
        sink = jnp.where(active, ch.order, n)
        res = dict(
            pre=res["pre"].at[sink].set(cur),
            post=res["post"].at[sink].set(post),
            success=res["success"].at[sink].set(ok),
        )
        return values, res

    return jax.lax.fori_loop(0, rounds, round_body, (values, res))


def _chain_levels_sharded(sops, ch, gflat_s, R, N_glob, max_levels, axis):
    """Distributed `engines._chain_levels`: the per-chain level fixpoint
    iterates against a replicated per-op level array keyed by global op
    index (merged with pmin; levels only decrease)."""
    gated = (sops.gate >= 0) & sops.valid
    chain_has_gate = jax.ops.segment_max(
        gated.astype(jnp.int32), ch.seg_id, num_segments=R) > 0
    lvl = jnp.where(chain_has_gate, _INF, 0)

    def op_lvl_of(lvl):
        per_op = jnp.take(lvl, ch.seg_id)
        arr = jnp.full((N_glob + 1,), _INF, jnp.int32).at[gflat_s].set(
            per_op)
        return jax.lax.pmin(arr, axis)

    opl = op_lvl_of(lvl)
    for _ in range(max_levels):
        pred = jnp.take(opl, jnp.maximum(sops.gate, 0))
        need = jax.ops.segment_max(
            jnp.where(gated, jnp.minimum(pred + 1, _INF), 0),
            ch.seg_id, num_segments=R)
        lvl = jnp.where(chain_has_gate, jnp.minimum(need, _INF), 0)
        opl = op_lvl_of(lvl)
    return lvl, lvl >= _INF


def _residue_sharded(vals, sops, ch, unresolved, res, succ_glob, *,
                     eng: ShardedStream, R, N_glob, per, s_pad, axis,
                     gflat_r, gflat_s, Wq, dev):
    """Dependency-cycle residue: the affected ops run *sequentially in
    global timestamp order*, replicated on every device (each device
    gathers the residue ops and the full value array, computes the same
    sweep bit-for-bit, then takes its own shard back)."""
    funs = eng.app.funs
    un_ops = jnp.take(unresolved, ch.seg_id) & sops.valid       # sorted [R]

    allv = jax.lax.all_gather(vals[:per], axis, axis=0)         # [n_dev,per,W]
    vals_full = jnp.concatenate(
        [allv.reshape(s_pad, Wq), jnp.zeros((1, Wq), vals.dtype)])

    uid_g = jnp.where(un_ops, sops.uid + dev * per, s_pad)
    gather = lambda x: jax.lax.all_gather(x, axis, axis=0).reshape(
        (-1,) + x.shape[1:])
    g = dict(uid=gather(uid_g), ts=gather(sops.ts), slot=gather(sops.slot),
             fun=gather(sops.fun), gate=gather(sops.gate),
             operand=gather(sops.operand), run=gather(un_ops),
             gflat=gather(jnp.where(un_ops, gflat_s, N_glob)))
    ng = g["uid"].shape[0]
    order = jnp.lexsort((g["slot"], g["ts"]))
    gres = dict(pre=jnp.zeros((N_glob + 1, Wq)),
                post=jnp.zeros((N_glob + 1, Wq)),
                success=succ_glob)

    def step(carry, i):
        values, gres = carry
        j = order[i]
        run = g["run"][j]
        uid = jnp.where(run, g["uid"][j], s_pad)
        cur = values[uid]
        gate = g["gate"][j]
        gate_ok = jnp.where(gate >= 0,
                            gres["success"][jnp.maximum(gate, 0)], True)
        post, ok = funs_apply_single(funs, g["fun"][j], cur, g["operand"][j])
        post = jnp.where(gate_ok, post, cur)
        ok = ok & gate_ok
        values = values.at[uid].set(jnp.where(run, post, values[s_pad]))
        values = values.at[s_pad].set(0.0)
        sink = jnp.where(run, g["gflat"][j], N_glob)
        gres = dict(
            pre=gres["pre"].at[sink].set(cur),
            post=gres["post"].at[sink].set(post),
            success=gres["success"].at[sink].set(ok),
        )
        return (values, gres), None

    (vals_full, gres), _ = jax.lax.scan(step, (vals_full, gres),
                                        jnp.arange(ng))

    vals_new = jnp.concatenate(
        [jax.lax.dynamic_slice_in_dim(vals_full, dev * per, per),
         jnp.zeros((1, Wq), vals.dtype)])
    # merge residue results into the local routed-layout results
    un_flat = ch.untake(un_ops)                                  # [R]
    sel = lambda loc, glob: jnp.where(
        (un_flat[:, None] if loc.ndim == 2 else un_flat),
        jnp.take(glob, gflat_r, axis=0), loc[:R])
    res = dict(
        pre=jnp.concatenate([sel(res["pre"], gres["pre"]), res["pre"][R:]]),
        post=jnp.concatenate([sel(res["post"], gres["post"]),
                              res["post"][R:]]),
        success=jnp.concatenate([sel(res["success"], gres["success"]),
                                 res["success"][R:]]),
    )
    return vals_new, res, gres["success"]
