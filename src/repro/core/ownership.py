"""Ownership, local stores, and the owner-routed op exchange (DESIGN.md §2.5).

The chain-shard layouts (the paper's NUMA-aware processing configurations,
§IV-E) all start from the same primitive: an **ownership permutation** of
the state store.  ``owner(uid) = uid % n_owners`` balances hot keys across
shards; permuting slots so each owner's slots become one *contiguous*
block turns "route to owner" into integer division and lets a device hold
its shard as a dense ``[per+1, W]`` value block (``+1`` local padding
chain).  The permutation is computed **once** per engine, not per batch.

On top of it sit two op-distribution strategies:

* replicate-everything (``core/sharded.py``, the pre-exchange baseline):
  every device receives the full OpBatch and masks out non-local ops —
  O(n_dev · N) replicated bytes per batch.
* owner-routed exchange (``core/sharded_stream.py``): each device buckets
  the ops *it built* by destination owner with the one-pass counting
  partition (``kernels/radix_partition``) — destination counts, bucket
  offsets and stable cell ranks all come from the SAME histogram pass, so
  exchange capacities and overflow stats are free by-products (the
  packed-sort + separate segment_sum it replaces did the work twice).
  Buckets pad to a fixed capacity and ship with ONE ``all_to_all`` —
  O(N + padding) bytes.  Bucket overflow drops ops; drops are **counted
  and surfaced**, never silent (``bucket_by_owner``).

``make_local_store`` is the one place local (per-shard) stores are
constructed, with all fields — ``table_base``/``table_capacity``/
``table_is_max``/``slot_is_max`` — set consistently for every layout.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.radix_partition.ops import radix_partition_rank

from .restructure import packed_stable_sort, partition_permutation
from .types import StateStore

LAYOUTS = ("shared_nothing", "shared_per_socket", "shared_everything")


@dataclasses.dataclass(frozen=True)
class Ownership:
    """Ownership permutation of a state store over ``n_owners`` shards.

    ``fwd``  : i32[S+1], original uid -> permuted uid (pad -> s_pad)
    ``per``  : slots per owner; owner o holds permuted uids
               [o*per, (o+1)*per)
    ``s_pad``: n_owners * per (>= S; trailing slots are dead padding)
    ``slot_is_max``: bool[s_pad+1] per *permuted* slot, or None when the
    store has no max-type tables.
    ``overrides``: sorted ``((uid, owner), ...)`` deviations from the
    round-robin striping ``uid % n_owners`` — the skew-aware placement
    the controller migrates onto (empty at construction).
    """

    n_owners: int
    per: int
    s_pad: int
    fwd: jnp.ndarray
    slot_is_max: Optional[jnp.ndarray]
    overrides: tuple = ()


def owner_of_uids(n_slots: int, n_owners: int,
                  overrides=()) -> np.ndarray:
    """i32[S] owner per uid: round-robin striping + explicit overrides."""
    owner = (np.arange(n_slots, dtype=np.int64) % n_owners).astype(np.int32)
    for u, o in overrides:
        owner[int(u)] = int(o)
    return owner


def build_ownership(store: StateStore, n_owners: int,
                    overrides=()) -> Ownership:
    """Ownership permutation: striping + skew-aware ``overrides``.

    Slots are laid out owner-major, uid-ascending within each owner —
    with no overrides this reproduces the closed form
    ``(uid % n) * per + uid // n`` exactly (rank-within-owner equals
    ``uid // n`` under pure striping), so pre-override programs are
    bit-identical.  Overrides MUST keep every owner's bin within
    ``per`` slots; :func:`rebalance_ownership` guarantees this by
    moving keys only in placement-preserving swaps.
    """
    s = store.n_slots
    n_owners = max(int(n_owners), 1)
    per = -(-s // n_owners)
    s_pad = per * n_owners
    overrides = tuple(sorted((int(u), int(o)) for u, o in overrides))
    owner = owner_of_uids(s, n_owners, overrides)
    counts = np.bincount(owner, minlength=n_owners)
    assert counts.max(initial=0) <= per, (
        f"override bin overflow: {counts.max()} > {per}")
    order = np.lexsort((np.arange(s), owner))  # owner-major, uid-asc
    new_np = np.empty(s, np.int32)
    ranks = np.arange(s, dtype=np.int64) - np.repeat(
        np.cumsum(np.concatenate([[0], counts[:-1]])), counts)
    new_np[order] = (owner[order].astype(np.int64) * per + ranks).astype(
        np.int32)
    new = jnp.asarray(new_np)
    old = jnp.arange(s)
    fwd = jnp.full((s + 1,), s_pad, jnp.int32).at[old].set(new)
    sim = None
    if any(store.table_is_max):
        flags = store.uid_is_max()  # [S+1]
        sim = jnp.zeros((s_pad + 1,), bool).at[new].set(flags[:-1])
    return Ownership(n_owners=n_owners, per=per, s_pad=s_pad, fwd=fwd,
                     slot_is_max=sim, overrides=overrides)


def rebalance_ownership(n_slots: int, n_owners: int, overrides,
                        shard_load: np.ndarray, hot,
                        max_moves: int = 16):
    """Greedy skew-aware placement from the observed access histogram.

    ``shard_load``: i64[n_owners] ops served per shard over the decision
    window; ``hot``: ``[(uid, count), ...]`` the window's hottest slots.
    Each step moves the heaviest not-yet-moved hot uid from the most
    loaded shard to the least loaded one, *swapping* it with that
    shard's coldest hot-listed (or synthetic zero-load) resident so
    every bin keeps exactly its striped size — ``per``/``s_pad`` and
    all block shapes are migration-invariant.  Pure host arithmetic,
    deterministic (ties broken by lowest uid), replay-safe: the result
    depends only on the arguments, which the decision trace records.

    Returns the new overrides tuple (sorted), or the input overrides
    unchanged when no beneficial move exists.
    """
    n_owners = max(int(n_owners), 1)
    if n_owners <= 1 or not len(hot):
        return tuple(sorted((int(u), int(o)) for u, o in overrides))
    load = np.asarray(shard_load, np.int64).copy()
    assert load.shape == (n_owners,)
    owner = owner_of_uids(n_slots, n_owners, overrides)
    hot = sorted(((int(u), int(c)) for u, c in hot),
                 key=lambda t: (-t[1], t[0]))
    hot_count = {u: c for u, c in hot}
    moved: set = set()
    for u, c in hot:
        if len(moved) >= 2 * max_moves:
            break
        if u in moved or c <= 0:
            continue
        src = int(owner[u])
        dst = int(np.argmin(load))
        if dst == src:
            continue
        # only move when it strictly shrinks the src/dst imbalance
        if load[src] - c < load[dst]:
            continue
        # swap victim: dst's coldest resident (prefer load-0, lowest uid)
        residents = np.flatnonzero(owner == dst)
        victim, v_load = -1, None
        for v in residents:
            if int(v) in moved:
                continue
            vl = hot_count.get(int(v), 0)
            if v_load is None or vl < v_load:
                victim, v_load = int(v), vl
                if vl == 0:
                    break
        if victim < 0:
            continue
        owner[u], owner[victim] = dst, src
        load[src] += v_load - c
        load[dst] += c - v_load
        moved.add(u)
        moved.add(victim)
    stripe = np.arange(n_slots, dtype=np.int64) % n_owners
    diff = np.flatnonzero(owner != stripe)
    return tuple((int(u), int(owner[u])) for u in diff)


def migration_plan(old: Ownership, new: Ownership):
    """Host-side plan for the moved-rows migration exchange.

    For each device d and local block row r (permuted uid p = d*per+r):
      ``dst``  i32[n_dev, per]: new owner of the uid stored there under
               ``old`` (== d when the row stays put; dead padding rows
               route to their own device so no traffic is generated)
      ``nidx`` i32[n_dev, per]: the row's index in the NEW owner's block
               (dead rows -> per, the local padding slot, overwritten by
               the pad-row reset)
      ``cap``  int: max rows moved between any (src, dst) pair — the
               all_to_all bucket capacity.  Exact by construction: a
               migration never drops rows.
    Shapes are migration-invariant because swaps preserve bin sizes.
    """
    assert old.per == new.per and old.n_owners == new.n_owners
    n_dev, per = old.n_owners, old.per
    fwd_o = np.asarray(old.fwd)[:-1]   # [S]
    fwd_n = np.asarray(new.fwd)[:-1]
    dst = np.tile(np.arange(n_dev, dtype=np.int32)[:, None], (1, per))
    nidx = np.full((n_dev, per), per, np.int32)
    dst.flat[fwd_o] = (fwd_n // per).astype(np.int32)
    nidx.flat[fwd_o] = (fwd_n % per).astype(np.int32)
    src = np.repeat(np.arange(n_dev), per).reshape(n_dev, per)
    movers = dst != src
    cap = 0
    if movers.any():
        pair = src[movers].astype(np.int64) * n_dev + dst[movers]
        cap = int(np.bincount(pair).max())
    return dst, nidx, max(1, cap)


def permute_values(own: Ownership, values: jnp.ndarray) -> jnp.ndarray:
    """[S+1, W] original -> [s_pad+1, W] ownership layout (pad rows zero)."""
    out = jnp.zeros((own.s_pad + 1, values.shape[1]), values.dtype)
    return out.at[own.fwd[:-1]].set(values[:-1])


def unpermute_values(own: Ownership, values_pad: jnp.ndarray) -> jnp.ndarray:
    """[s_pad+1, W] ownership layout -> [S+1, W] original (pad row zero)."""
    s = own.fwd.shape[0] - 1
    out = jnp.zeros((s + 1, values_pad.shape[1]), values_pad.dtype)
    return out.at[:-1].set(jnp.take(values_pad, own.fwd[:-1], axis=0))


def make_local_store(values: jnp.ndarray,
                     slot_is_max: Optional[jnp.ndarray] = None) -> StateStore:
    """The ONE constructor for per-shard local stores.

    ``values`` is the shard's ``[n_local+1, W]`` block (last row = local
    padding chain); ``slot_is_max`` its per-slot max flags (ownership
    layout interleaves tables, so flags are per-slot, not per-table).
    Every layout gets identical table metadata: one merged table based at
    0 with the full local capacity.
    """
    n_local = values.shape[0] - 1
    return StateStore(
        values=values, table_base=(0,), table_capacity=(n_local,),
        table_is_max=(slot_is_max is not None,), slot_is_max=slot_is_max)


# ---------------------------------------------------------------------------
# Owner-routed exchange: capacity-padded count/sort bucketing
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoutePlan:
    """Per-batch bucketing of local ops by destination shard.

    ``take``    : i32[n_route, cap] local row feeding each bucket cell
    ``ok``      : bool[n_route, cap] cell holds a real (shipped) op
    ``rank``    : i32[N] each local row's cell within its bucket (>= cap
                  when the row overflowed and was dropped)
    ``dst``     : i32[N] destination bucket (n_route for unrouted padding)
    ``dropped`` : i32 scalar, valid ops lost to bucket overflow — the
                  exchange's accuracy/traffic trade-off, surfaced to the
                  driver's stats rather than silently discarded
    ``fill``    : i32 scalar, occupancy of the fullest real bucket
                  (pre-clamp, so ``fill > cap`` iff something dropped) —
                  the controller's predictive widen-before-drop signal
    """

    take: jnp.ndarray
    ok: jnp.ndarray
    rank: jnp.ndarray
    dst: jnp.ndarray
    dropped: jnp.ndarray
    fill: jnp.ndarray


def _exchange_counting_wins(n: int, n_route: int) -> bool:
    """Measured host-backend crossover (BENCH_restructure.json exchange
    rows): the counting pass wins while its [K, N] one-hot histogram is
    monolithic (cache-resident cumsum) and again at large N where the
    sort's log factor dominates; the band between goes to the packed
    sort (~1.3x faster there)."""
    return (n_route + 1) * n <= (1 << 20) or n >= (1 << 19)


def bucket_by_owner(dst: jnp.ndarray, n_route: int, cap: int,
                    counting: bool | None = None) -> RoutePlan:
    """Bucket local rows by ``dst`` (i32[N] in [0, n_route]; ``n_route``
    marks rows that are never shipped, e.g. padding ops).

    One counting-partition pass (``kernels/radix_partition``) yields the
    per-destination histogram, bucket offsets and each row's stable cell
    rank together — no sort, and the capacity/overflow accounting reads
    the same counts.  Bucket extraction stays pure gathers.  Inside the
    band where the packed sort measures faster, the sort-based plan is
    kept (same outputs bit for bit; the histogram then costs one
    ``segment_sum``).  ``counting`` forces a backbone (the restructure
    benchmark A/Bs the two production paths through this).
    """
    n = dst.shape[0]
    if counting is None:
        counting = _exchange_counting_wins(n, n_route)
    if counting:
        # XLA counting ref only (no use_pallas plumbing): this runs
        # vmapped over intervals inside the shard_map body, where the
        # kernel's sequential-grid carry is not reachable — the batched
        # kernel entry is for the hoisted restructure_stream call
        rank, counts = radix_partition_rank(dst, n_route + 1)
        starts, _, order = partition_permutation(dst, rank, counts)
    else:
        order, _, pos = packed_stable_sort(dst, n_route)
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), dst,
                                     num_segments=n_route + 1)
        starts = (jnp.cumsum(counts) - counts).astype(jnp.int32)
        rank = pos - jnp.take(starts, dst)
    j = starts[:n_route, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    ok = (jnp.arange(cap, dtype=jnp.int32)[None, :]
          < jnp.minimum(counts[:n_route], cap)[:, None])
    take = jnp.where(ok, jnp.take(order, jnp.minimum(j, n - 1)), 0)
    dropped = jnp.sum(jnp.maximum(counts[:n_route] - cap, 0))
    fill = jnp.max(counts[:n_route])
    return RoutePlan(take=take, ok=ok, rank=rank, dst=dst, dropped=dropped,
                     fill=fill)


def route_gather(plan: RoutePlan, field: jnp.ndarray, pad_value):
    """Gather a per-row field into its [n_route, cap, ...] bucket layout."""
    out = jnp.take(field, plan.take, axis=0)
    ok = plan.ok
    while ok.ndim < out.ndim:
        ok = ok[..., None]
    return jnp.where(ok, out, jnp.asarray(pad_value, field.dtype))


def unroute_gather(plan: RoutePlan, bucketed: jnp.ndarray, n_route: int,
                   cap: int, pad_value=0):
    """Inverse of ``route_gather`` for *returned* per-op results.

    ``bucketed``: [n_route*cap, ...] results laid out by (bucket, cell) —
    exactly how the reverse all_to_all deposits them.  Rows that were
    dropped (overflow) or never shipped get ``pad_value``.
    """
    ok = (plan.dst < n_route) & (plan.rank < cap)
    pos = (jnp.minimum(plan.dst, n_route - 1) * cap
           + jnp.minimum(plan.rank, cap - 1))
    out = jnp.take(bucketed, pos, axis=0)
    okx = ok
    while okx.ndim < out.ndim:
        okx = okx[..., None]
    return jnp.where(okx, out, jnp.asarray(pad_value, bucketed.dtype))


def exchange_capacity(n_local_ops: int, n_route: int, slack: float) -> int:
    """Bucket capacity: ``slack``× the balanced share, clamped to the
    worst case (all local ops to one owner).  slack >= n_route therefore
    guarantees zero drops at replicate-everything cost; the default
    (2.0) bounds exchange bytes at 2·N while absorbing moderate skew —
    the ownership permutation already stripes Zipf-hot keys round-robin
    across shards, so per-owner counts concentrate near N/n_route.
    """
    per_route = -(-n_local_ops // max(n_route, 1))
    cap = int(np.ceil(per_route * max(slack, 1.0)))
    return max(1, min(cap, n_local_ops))


def chunk_shard_output(x: jnp.ndarray, idx, n_rep: int) -> jnp.ndarray:
    """Fully shard a *replicated* shard_map output along a mesh axis.

    A shard_map output whose spec leaves a mesh axis unmentioned (because
    the value is replicated across it) is treated as an unreduced partial
    by the surrounding SPMD program and can get **summed** across the
    identical copies when resharded.  The reliable pattern is to mention
    every axis: each of the ``n_rep`` replicas returns a disjoint row
    chunk of the (padded) value, and the caller reassembles with
    ``unchunk_output``.  ``idx`` is this device's index along the
    replicated axis (traced).
    """
    rows = x.shape[0]
    chunk = -(-rows // n_rep)
    xp = jnp.pad(x, ((0, chunk * n_rep - rows),) + ((0, 0),) * (x.ndim - 1))
    return jax.lax.dynamic_slice_in_dim(xp, idx * chunk, chunk)


def unchunk_output(x_global: jnp.ndarray, n_groups: int,
                   rows: int) -> jnp.ndarray:
    """Inverse of ``chunk_shard_output`` over ``n_groups`` groups whose
    chunks concatenate along axis 0; returns [n_groups, rows, ...]."""
    g = x_global.reshape((n_groups, -1) + x_global.shape[1:])
    return g[:, :rows]


# ---------------------------------------------------------------------------
# Flag-gated hash-probe owner lookup (kernels/hash_probe in the hot path)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ProbeRoute:
    """uid -> destination shard via the bucketed hash-probe kernel.

    The direct-addressed stores make owner lookup a gather; sparse-key
    deployments resolve uid through a hash probe instead.  This wires
    ``kernels/hash_probe`` into the routing hot path (flag-gated via
    ``EngineConfig.use_hash_probe_route``): probe uid -> table slot, then
    read the owner recorded at insertion time.  ``ref``-checked against
    the arange table in tests.
    """

    table_lo: jnp.ndarray
    table_hi: jnp.ndarray
    slot_owner: jnp.ndarray  # i32[n_buckets*ASSOC], -1-safe via end slot

    def owners_of(self, uid: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels.hash_probe.ops import hash_probe

        slot = hash_probe(uid, self.table_lo, self.table_hi)
        # absent keys (slot -1) -> sentinel owner slot (maps to n_route)
        return jnp.take(self.slot_owner, jnp.where(slot < 0,
                                                   self.slot_owner.shape[0] - 1,
                                                   slot))


def build_probe_route(n_uids: int, owner_of_uid: np.ndarray,
                      miss_owner: int) -> ProbeRoute:
    """Insert uids 0..n_uids-1; record each uid's owner at its slot."""
    from repro.kernels.hash_probe.ref import bucket_of_np, build_table
    from repro.kernels.hash_probe.kernel import ASSOC, MAX_PROBES

    keys = np.arange(n_uids, dtype=np.int32)
    n_buckets = max(64, 2 * (-(-n_uids // ASSOC)))
    lo, hi = build_table(keys, n_buckets)
    # replay insertion to learn each key's slot
    table = np.full((n_buckets, ASSOC), -1, np.int64)
    slot_owner = np.full((n_buckets * ASSOC + 1,), miss_owner, np.int32)
    for k in keys.astype(np.int64):
        b = int(bucket_of_np(np.asarray(k), n_buckets))
        for p in range(MAX_PROBES):
            row = (b + p) % n_buckets
            free = np.flatnonzero(table[row] < 0)
            if len(free):
                table[row, free[0]] = k
                slot_owner[row * ASSOC + free[0]] = owner_of_uid[k]
                break
        else:  # pragma: no cover - build_table already raised
            raise RuntimeError("hash table overflow")
    return ProbeRoute(table_lo=jnp.asarray(lo), table_hi=jnp.asarray(hi),
                      slot_owner=jnp.asarray(slot_owner))
