"""EventBlotter & programming API (paper §IV-A, Tables II/III).

Users express an operator as the three-step procedure (F1):

    eb  = pre_process(event)          # compute mode
    state_access(blt, eb)             # records ops; postponed (D1)
    out = post_process(eb, results)   # compute mode, after txn processing

``state_access`` receives a :class:`Blotter` recorder exposing the
system-provided APIs (READ / WRITE / READ_MODIFY, with optional gating on a
mate op's success — the paper's ``CFun``).  Recording happens at trace time
under ``vmap``: each call claims one op slot; parameter values are traced
arrays.  This is the F2 property (read/write sets known from the event) made
structural.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import CORE_FUNS, FunSpec, OpBatch, OpKind, StateStore


class Blotter:
    """Per-event op recorder (thread-local EventBlotter analogue)."""

    def __init__(self, store: StateStore, funs: Tuple[FunSpec, ...],
                 max_ops: int, width: int):
        self._store = store
        self._funs = funs
        self._fun_index = {f.name: i for i, f in enumerate(funs)}
        self.max_ops = max_ops
        self.width = width
        self.rows: list = []

    # -- system-provided APIs (Table III) ---------------------------------
    def read(self, table: int, key, valid=True) -> int:
        return self._record(OpKind.READ, table, key, "read",
                            jnp.zeros((self.width,), jnp.float32), -1, valid)

    def write(self, table: int, key, value, fun="put", gate=-1,
              valid=True) -> int:
        return self._record(OpKind.WRITE, table, key, fun,
                            self._lanes(value), gate, valid)

    def read_modify(self, table: int, key, operand, fun, gate=-1,
                    valid=True) -> int:
        return self._record(OpKind.READ_MODIFY, table, key, fun,
                            self._lanes(operand), gate, valid)

    def fun_id(self, name: str) -> int:
        """Index of a fun by name — for traced (per-event) fun selection."""
        return self._fun_index[name]

    # ----------------------------------------------------------------------
    def _lanes(self, value) -> jnp.ndarray:
        v = jnp.asarray(value, jnp.float32)
        if v.ndim == 0:
            v = jnp.zeros((self.width,), jnp.float32).at[0].set(v)
        assert v.shape == (self.width,), v.shape
        return v

    def _record(self, kind: OpKind, table: int, key, fun,
                operand: jnp.ndarray, gate, valid) -> int:
        """fun may be a name or a traced fun index; gate/valid may be traced
        (data-dependent op mixes, e.g. deposit vs transfer events)."""
        slot = len(self.rows)
        assert slot < self.max_ops, f"max_ops={self.max_ops} exceeded"
        if isinstance(gate, int):
            assert gate < slot, "a gated op's mate must occupy an earlier slot"
        fun_id = self._fun_index[fun] if isinstance(fun, str) else fun
        self.rows.append(dict(
            uid=jnp.asarray(self._store.uid_of(table, jnp.asarray(key, jnp.int32)),
                            jnp.int32),
            kind=jnp.asarray(int(kind) if isinstance(kind, OpKind) else kind,
                             jnp.int32),
            fun=jnp.asarray(fun_id, jnp.int32),
            gate=jnp.asarray(gate, jnp.int32),
            operand=operand,
            valid=jnp.asarray(valid, bool),
        ))
        return slot

    def finalize(self) -> Dict[str, jnp.ndarray]:
        """Pad to max_ops and stack into per-event op rows."""
        pad_uid = self._store.pad_uid
        rows = list(self.rows)
        while len(rows) < self.max_ops:
            rows.append(dict(
                uid=jnp.int32(pad_uid), kind=jnp.int32(int(OpKind.NOP)),
                fun=jnp.int32(0), gate=jnp.int32(-1),
                operand=jnp.zeros((self.width,), jnp.float32),
                valid=jnp.asarray(False),
            ))
        out = {}
        for k in ("uid", "kind", "fun", "gate", "operand", "valid"):
            out[k] = jnp.stack([r[k] for r in rows])
        return out


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """A concurrent stateful streaming application (paper §VI-A)."""

    name: str
    funs: Tuple[FunSpec, ...]
    max_ops: int
    width: int
    make_store: Callable[..., StateStore]
    gen_events: Callable[..., Dict[str, np.ndarray]]
    pre_process: Callable
    state_access: Callable
    post_process: Callable
    has_gates: bool = False
    may_abort: bool = False

    @property
    def associative_only(self) -> bool:
        return all(f.associative for f in self.funs) and not self.has_gates


def build_opbatch(app: AppSpec, store: StateStore,
                  events: Dict[str, jnp.ndarray],
                  ts_base: jnp.ndarray) -> Tuple[OpBatch, Dict]:
    """Compute mode: vmapped pre_process + op registration (D1 postponing).

    Returns the flattened OpBatch for the whole punctuation interval plus the
    per-event blotter payloads needed by post_process.
    """
    some = jax.tree_util.tree_leaves(events)[0]
    batch = some.shape[0]

    def per_event(ev):
        eb = app.pre_process(ev)
        blt = Blotter(store, app.funs, app.max_ops, app.width)
        app.state_access(blt, eb)
        return blt.finalize(), eb

    rows, ebs = jax.vmap(per_event)(events)
    n = batch * app.max_ops
    txn = jnp.repeat(jnp.arange(batch, dtype=jnp.int32), app.max_ops)
    slot = jnp.tile(jnp.arange(app.max_ops, dtype=jnp.int32), batch)
    ts = ts_base + txn
    gate_rel = rows["gate"].reshape(n)
    gate = jnp.where(gate_rel >= 0, txn * app.max_ops + gate_rel, -1)
    ops = OpBatch(
        uid=rows["uid"].reshape(n),
        ts=ts, txn=txn, slot=slot,
        kind=rows["kind"].reshape(n),
        fun=rows["fun"].reshape(n),
        gate=gate,
        operand=rows["operand"].reshape(n, app.width),
        valid=rows["valid"].reshape(n),
    )
    return ops, ebs
