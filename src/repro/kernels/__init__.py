"""Pallas TPU kernels for TStream's state-access hot spots.

segscan         — segmented scans evaluating operation chains (the D2 hot loop)
hash_probe      — one-hot-matmul bucketed hash probe (sparse-key index lookup)
radix_partition — one-pass stable counting partition: the restructure sort
                  replacement (rank + histogram in one sweep)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper) and ref.py (pure-jnp oracle); validated in interpret mode on CPU.
"""
