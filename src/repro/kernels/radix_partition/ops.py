"""Jit'd wrapper: pad to kernel tiling, dispatch kernel vs XLA counting ref.

Accepts 1-D ``[N]`` or batched 2-D ``[BN, N]`` keys; the batched Pallas
path partitions the whole stack in ONE dispatch (grid ``(BN, blocks)``),
which is how the fused stream driver partitions every interval at once.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import autotune
from ..runtime import default_interpret
from . import kernel as K
from .ref import radix_partition_rank_ref


def kernel_fits(n_buckets: int, n_rows: int = 0) -> bool:
    """Whether the one-hot kernel applies: bucket axis within its VMEM
    bound AND per-batch rows within the f32 carry's exact-integer range
    (ranks/counts are carried in f32; beyond 2^24 they would round and
    silently corrupt the partition — the XLA ref handles such batches)."""
    return (_padded_buckets(n_buckets) <= K.MAX_KERNEL_BUCKETS
            and n_rows < K.MAX_KERNEL_ROWS)


def _padded_buckets(n_buckets: int) -> int:
    # +1: row padding goes to a private dump bucket past the real ones
    return -(-(n_buckets + 1) // K.LANES) * K.LANES


@partial(jax.jit, static_argnames=("n_buckets", "use_pallas", "interpret",
                                   "block_rows"))
def radix_partition_rank(keys: jnp.ndarray, n_buckets: int, *,
                         use_pallas: bool = False,
                         interpret: bool | None = None,
                         block_rows: int | None = None):
    """keys: i32[N] or i32[BN, N], values in [0, n_buckets).

    Returns ``(rank, counts)`` with ``rank`` the stable within-bucket rank
    of each row (shape of ``keys``) and ``counts`` the per-batch histogram
    (``[n_buckets]`` / ``[BN, n_buckets]``).  ``use_pallas`` dispatches the
    kernel when its bucket bound holds, else the XLA counting ref.
    ``block_rows=None`` resolves the tuned block at trace time
    (kernels/autotune); pass an int to force a shape.
    """
    if interpret is None:
        interpret = default_interpret()
    squeeze = keys.ndim == 1
    k2 = keys[None] if squeeze else keys
    assert k2.ndim == 2, keys.shape
    if use_pallas and kernel_fits(n_buckets, k2.shape[1]):
        bn, n = k2.shape
        if block_rows is None:
            block_rows = autotune.block_rows("radix_partition", n,
                                             dtype="int32")
        rows = -(-n // block_rows) * block_rows
        kpad = jnp.pad(k2.astype(jnp.int32), ((0, 0), (0, rows - n)),
                       constant_values=n_buckets)
        rank, counts = K.radix_partition_pallas(
            kpad, _padded_buckets(n_buckets), interpret=interpret,
            block_rows=block_rows)
        rank, counts = rank[:, :n], counts[:, :n_buckets]
    else:
        rank, counts = jax.vmap(
            partial(radix_partition_rank_ref, n_buckets=n_buckets))(k2)
    return (rank[0], counts[0]) if squeeze else (rank, counts)
