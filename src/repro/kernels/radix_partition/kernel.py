"""Pallas TPU kernel: one-pass stable radix/counting partition ranks.

Dynamic restructuring (paper §IV-C1) groups the op stream into per-state
chains.  The major keys are *bounded integers* (state uid < n_slots,
destination shard < n_dev), so the comparison-sort backbone
(``jnp.sort`` — O(N log² N) bitonic on accelerators) is overkill: a
histogram + exclusive-prefix + stable rank is O(N + K) and yields the
same stable grouping, plus the per-bucket histograms that the commit
gather map and the exchange capacities need — for free.

This kernel computes, in ONE sequential-grid pass over the key stream:

  ``rank[i]``  — number of earlier rows with the same key (the stable
                 within-bucket rank; ``pos[i] = starts[key[i]] + rank[i]``
                 then places every row without any sort), and
  ``counts[k]`` — the full key histogram (the last grid step's running
                 histogram).

TPU mapping
-----------
Keys are tiled into blocks of BLOCK_ROWS rows; the bucket axis is padded
to a lane multiple.  Each grid step builds a one-hot ``[BLOCK_ROWS, K]``
matrix (broadcasted-iota compare — the same MXU/VPU-friendly trick as
``hash_probe``), takes its within-block exclusive column cumsum, adds
the running histogram carried in VMEM scratch across grid steps (the
standard Pallas sequential-carry pattern, as in ``segscan``), and reads
each row's rank back out of its own one-hot column by a masked row-sum.
Counts stay exact in f32 (N < 2^24).

The grid is ``(batch, n_blocks)``: the batch axis lets a whole stream of
stacked intervals partition in one dispatch (the carry re-initializes at
block 0 of every batch), without relying on vmap-of-pallas_call.

VMEM per grid step: one-hot + cumsum ≈ 2 · BLOCK_ROWS · K · 4 B
(BLOCK_ROWS=256, K=2048: 4 MiB ≪ 16 MiB); larger bucket counts fall back
to the XLA counting path (``ref.py``), the next rung of the ladder.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 256
LANES = 128
MAX_KERNEL_BUCKETS = 2048  # one-hot VMEM bound; beyond -> XLA counting ref
MAX_KERNEL_ROWS = 1 << 24  # f32 carry exactness: ranks/counts < 2^24


def _radix_rank_kernel(k_ref, rank_ref, cnt_ref, hist_ref, *,
                       block_rows: int, n_buckets_padded: int):
    """Running within-bucket rank; histogram carry across a batch's blocks."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    k = k_ref[...][:, 0]                               # [B] i32 keys
    iota = jax.lax.broadcasted_iota(jnp.int32, (block_rows, n_buckets_padded),
                                    1)
    oh = (iota == k[:, None]).astype(jnp.float32)      # [B, K] one-hot
    ex = jnp.cumsum(oh, axis=0) - oh                   # within-block exclusive
    carry = hist_ref[...]                              # [1, K] running hist
    r = jnp.sum((ex + carry) * oh, axis=1)             # [B] rank (exact f32)
    rank_ref[...] = r.astype(jnp.int32)[:, None]

    new_hist = carry + jnp.sum(oh, axis=0, keepdims=True)
    hist_ref[...] = new_hist
    # constant index map: the block stays resident and the last grid step
    # of this batch leaves the total histogram
    cnt_ref[...] = new_hist.astype(jnp.int32)


def radix_partition_pallas(keys: jnp.ndarray, n_buckets_padded: int, *,
                           interpret: bool = True,
                           block_rows: int = BLOCK_ROWS):
    """keys: i32[BN, R] with R % block_rows == 0 and values in
    [0, n_buckets_padded); returns (rank i32[BN, R], counts i32[BN, K])."""
    bn, rows = keys.shape
    assert rows % block_rows == 0, (keys.shape, block_rows)
    assert n_buckets_padded % LANES == 0, (n_buckets_padded,)
    n_blocks = rows // block_rows
    kernel = functools.partial(_radix_rank_kernel, block_rows=block_rows,
                               n_buckets_padded=n_buckets_padded)
    kspec = pl.BlockSpec((block_rows, 1),
                         lambda b, t, nb=n_blocks: (b * nb + t, 0))
    rank, counts = pl.pallas_call(
        kernel,
        grid=(bn, n_blocks),
        in_specs=[kspec],
        out_specs=[kspec,
                   pl.BlockSpec((1, n_buckets_padded), lambda b, t: (b, 0))],
        out_shape=[jax.ShapeDtypeStruct((bn * rows, 1), jnp.int32),
                   jax.ShapeDtypeStruct((bn, n_buckets_padded), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, n_buckets_padded), jnp.float32)],
        interpret=interpret,
    )(keys.reshape(bn * rows, 1))
    return rank[:, 0].reshape(bn, rows), counts
