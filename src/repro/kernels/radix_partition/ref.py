"""XLA counting-partition reference: sort-free rank + histogram.

Serves two roles (mirroring the other kernels' ``ref.py``): the oracle
the Pallas kernel is validated against, and the production *fallback
rung* of the restructure ladder — the pure-jnp counting path used when
the kernel is off or the bucket count exceeds its VMEM bound.

Two formulations, switched on histogram size (everything parallel — no
scan, no sort):

* **small-K transpose** (the CPU hot path): a ``[K, N]`` one-hot whose
  row-wise inclusive cumsum IS the running histogram — ``rank[i]`` is one
  gather at ``(key[i], i)`` and ``counts`` is the last column.  O(K·N)
  contiguous vector work and **zero scatters**, which is what makes the
  counting rung beat the comparison sort on CPU XLA for compact key
  spaces (owner routing: K = n_dev+1; see BENCH_restructure.json for the
  measured crossover).
* **blocked** (large K): per-block scatter-add histograms, exclusive
  cumsum over blocks for the carry, and a lower-triangular equal-key
  count for the within-block rank — O(N·B + T·K) with bounded ``[T, K]``
  memory.  This mirrors the kernel's block/carry structure and keeps the
  path memory-sane when ``K·N`` would not fit.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 128
# small-K transpose path: bucket-count bound, and per-step one-hot elements
# kept cache-resident (the [K, N] cumsum falls off a cache cliff otherwise)
SMALL_K_MAX = 128
_SMALL_STEP_ELEMS = 1 << 20


def _rank_small(keys: jnp.ndarray, n_buckets: int):
    """[K, N] one-hot transpose cumsum: scatter-free rank + histogram.

    The column axis is processed in cache-sized blocks under a ``lax.scan``
    carrying the running histogram, so per-element cost stays flat in N.
    """
    n = keys.shape[0]
    nb = max(256, min(max(n, 1), _SMALL_STEP_ELEMS // max(n_buckets, 1)))
    steps = -(-max(n, 1) // nb)
    if steps == 1:
        nb = max(n, 1)
    # padding keys = n_buckets match no one-hot row (and gathers clamp)
    kp = jnp.full((steps * nb,), n_buckets, keys.dtype).at[:n].set(keys)
    iota = jnp.arange(n_buckets, dtype=keys.dtype)
    col = jnp.arange(nb, dtype=jnp.int32)

    def body(carry, k):
        ohT = k[None, :] == iota[:, None]                      # [K, nb]
        run = jnp.cumsum(ohT.astype(jnp.int32), axis=1) + carry[:, None]
        rank_blk = jnp.take(run.reshape(-1),
                            jnp.minimum(k.astype(jnp.int32), n_buckets - 1)
                            * nb + col)
        return run[:, -1], rank_blk

    counts, ranks = jax.lax.scan(body, jnp.zeros((n_buckets,), jnp.int32),
                                 kp.reshape(steps, nb))
    return ranks.reshape(-1)[:n] - 1, counts


def _rank_blocked(keys: jnp.ndarray, n_buckets: int):
    """Blocked histogram + carry + triangular within-block rank."""
    n = keys.shape[0]
    t = -(-max(n, 1) // BLOCK)
    # block-padding rows land in a private dump bucket (n_buckets)
    kp = jnp.full((t * BLOCK,), n_buckets, keys.dtype).at[:n].set(keys)
    k2 = kp.reshape(t, BLOCK)

    hist = jax.vmap(
        lambda k: jnp.zeros((n_buckets + 1,), jnp.int32).at[k].add(1))(k2)
    carry = jnp.cumsum(hist, axis=0) - hist                # excl over blocks
    eq = k2[:, :, None] == k2[:, None, :]                  # [t, B, B]
    tril = jnp.tril(jnp.ones((BLOCK, BLOCK), bool), k=-1)
    rank_wb = jnp.sum(eq & tril[None], axis=2)             # [t, B] i32
    rank = rank_wb + jnp.take_along_axis(carry, k2, axis=1)
    counts = jnp.sum(hist, axis=0)[:n_buckets]
    return rank.reshape(-1)[:n].astype(jnp.int32), counts


@partial(jax.jit, static_argnames=("n_buckets",))
def radix_partition_rank_ref(keys: jnp.ndarray, n_buckets: int):
    """keys: i32[N] in [0, n_buckets) -> (rank i32[N], counts i32[n_buckets]).

    ``rank[i]`` = number of rows j < i with ``keys[j] == keys[i]`` (the
    stable within-bucket rank); ``counts`` the key histogram.
    """
    if n_buckets <= SMALL_K_MAX:
        return _rank_small(keys, n_buckets)
    return _rank_blocked(keys, n_buckets)
