"""One-pass stable radix/counting partition (restructure backbone)."""
