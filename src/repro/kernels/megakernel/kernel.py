"""Pallas TPU megakernel: fused partition→segscan→commit chain evaluation.

The staged restructure fast path round-trips its intermediates through
HBM between dispatches: the counting partition emits rank/histograms, the
host-side plan materializes per-op affine coefficients ``[N, W]``, the
segscan kernel reads them back and writes the scanned ``A/B`` (and the
execute stage re-reads those to apply ``v0`` and gather the commit rows).
This kernel runs the values-dependent half of that pipeline — coefficient
expansion, the segmented affine scan, state-gather, chain evaluation and
the commit-map emission — in ONE dispatch with every intermediate
VMEM-resident.  Nothing between the sorted operand block coming in and
(pre, post, committed-accumulator) going out touches HBM.

Exactness contract (what lets this sit on the restructure ladder at all):

* grid = (1,): the whole sorted interval is one block, so the in-block
  flag-blocked Hillis–Steele sweep is step-for-step the SAME operation
  sequence as the XLA ``segmented_scan_affine`` — no cross-block carry
  fold, hence bit-identical scans on ANY row count (extra d ≥ n steps
  are no-ops: row 0 always starts a segment, so every row's flag is
  saturated by then, and padding rows are their own dead segments).
* state gather/scatter as one-hot f32 matmuls: products are exactly 0
  or the operand, and the row/column sums add exactly one non-zero —
  bit-exact for finite values (this is why the megakernel refuses
  max-typed tables: their -inf neutrals produce 0·(-inf) = NaN).  On a
  real MXU the dots need ``preferred_element_type=float32`` +
  ``precision=HIGHEST`` (f32 emulation) to keep the products exact;
  interpret mode computes them in f32 directly.

Scope: simple-affine fun families only (a ∈ {0,1}, b ∈ {0, operand} —
``engines.simple_affine_luts``), so the per-op coefficients collapse to
two scalar columns (``a_sel``, ``b_is_operand``) and the kernel never
loads an ``[N, W]`` coefficient array from HBM at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _shift_down(x: jnp.ndarray, d: int, fill) -> jnp.ndarray:
    """x[i-d] with ``fill`` for i < d (rows axis)."""
    pad = jnp.full((d,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([pad, x[:-d]], axis=0)


def _fused_chain_kernel(f_ref, asel_ref, bis_ref, valid_ref, uid_ref,
                        operand_ref, values_ref,
                        pre_ref, post_ref, acc_ref, *,
                        n_rows: int, n_slots_padded: int):
    f = f_ref[...] > 0.0                       # [N, LANES] seg-start flags
    valid = valid_ref[...] > 0.0               # [N, 1]
    uid = uid_ref[...][:, 0]                   # [N] i32 (sorted)

    # -- stage 1: coefficient expansion (VMEM; replaces the [N, W] af/bf
    #    HBM arrays of the staged plan).  Invalid rows become identity.
    a = jnp.broadcast_to(asel_ref[...], (n_rows, LANES))
    b = jnp.where(bis_ref[...] > 0.0, operand_ref[...], 0.0)
    a = jnp.where(valid, a, jnp.ones_like(a))
    b = jnp.where(valid, b, jnp.zeros_like(b))

    # -- stage 2: inclusive segmented affine scan — the exact operation
    #    sequence of core.restructure.segmented_scan_affine (shift fills
    #    flag=True / a=1 / b=0 block at the array edge).
    fi, a_inc, b_inc = f, a, b
    d = 1
    while d < n_rows:
        ap = _shift_down(a_inc, d, 1.0)
        bp = _shift_down(b_inc, d, 0.0)
        fp = _shift_down(fi, d, True)
        a_inc, b_inc = (jnp.where(fi, a_inc, a_inc * ap),
                        jnp.where(fi, b_inc, a_inc * bp + b_inc))
        fi = fi | fp
        d *= 2

    # -- exclusive view: identity at row 0 and at segment starts.
    A = _shift_down(a_inc, 1, 1.0)
    B = _shift_down(b_inc, 1, 0.0)
    A = jnp.where(f, jnp.ones_like(A), A)
    B = jnp.where(f, jnp.zeros_like(B), B)
    # inclusive = raw ∘ exclusive (engines._compose_inclusive)
    Ai = a * A
    Bi = a * B + b

    # -- stage 3: state gather as a one-hot matmul (exact for finite
    #    values; TPUs have no efficient random gather inside a kernel).
    iota = jax.lax.broadcasted_iota(jnp.int32, (n_rows, n_slots_padded), 1)
    oh = (iota == uid[:, None]).astype(jnp.float32)        # [N, S]
    v0 = jnp.dot(oh, values_ref[...],
                 preferred_element_type=jnp.float32,
                 precision=jax.lax.Precision.HIGHEST)      # [N, LANES]

    pre = A * v0 + B
    post = Ai * v0 + Bi

    # -- stage 4: commit-map emission.  The last op of each chain is the
    #    row whose successor starts a new segment; its post value lands in
    #    its uid's accumulator column via the transposed one-hot (padding
    #    rows are their own segments with uid=pad and post=v0[pad]=0, so
    #    they only add exact zeros).
    seg_end = jnp.concatenate([f[1:], jnp.full((1, LANES), True)], axis=0)
    contrib = jnp.where(seg_end, post, 0.0)
    acc_ref[...] = jax.lax.dot_general(
        oh, contrib, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST)               # [S, LANES]

    # -- invalid (padding) ops record nothing (staged-path semantics:
    #    committed values were gathered from the unmasked post above)
    pre_ref[...] = jnp.where(valid, pre, 0.0)
    post_ref[...] = jnp.where(valid, post, 0.0)


def fused_chain_pallas(flags: jnp.ndarray, a_sel: jnp.ndarray,
                       b_is: jnp.ndarray, valid: jnp.ndarray,
                       uid: jnp.ndarray, operand: jnp.ndarray,
                       values: jnp.ndarray, *, interpret: bool = True):
    """One fused dispatch over a whole sorted interval.

    flags/operand: f32[N, LANES]; a_sel/b_is/valid: f32[N, 1];
    uid: i32[N, 1]; values: f32[S, LANES] with S % LANES == 0.
    Returns (pre, post) f32[N, LANES] and acc f32[S, LANES] — the
    committed (chain-end) value per slot, zeros for chainless slots.
    """
    n, lanes = operand.shape
    s = values.shape[0]
    assert lanes == LANES and values.shape[1] == LANES, (operand.shape,
                                                        values.shape)
    assert s % LANES == 0, (s,)
    kernel = functools.partial(_fused_chain_kernel, n_rows=n,
                               n_slots_padded=s)
    rspec = pl.BlockSpec((n, LANES), lambda: (0, 0))
    cspec = pl.BlockSpec((n, 1), lambda: (0, 0))
    vspec = pl.BlockSpec((s, LANES), lambda: (0, 0))
    pre, post, acc = pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[rspec, cspec, cspec, cspec, cspec, rspec, vspec],
        out_specs=[rspec, rspec, vspec],
        out_shape=[jax.ShapeDtypeStruct((n, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((n, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((s, LANES), jnp.float32)],
        interpret=interpret,
    )(flags, a_sel, b_is, valid, uid, operand, values)
    return pre, post, acc
