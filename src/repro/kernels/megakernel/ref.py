"""XLA reference for the fused megakernel: the staged pipeline, recomposed.

This is DELIBERATELY the staged ``plan → coefs → execute`` operation
sequence inlined op-for-op (same LUT coefficient expansion, the same
``segmented_scan_affine``, the same compose/apply/commit arithmetic), so
it is bitwise identical to the staged path by construction — XLA does not
reassociate elementwise chains, only reductions.  It doubles as the
structural fallback when an interval exceeds the kernel's VMEM fit
(``ops.mega_kernel_fits``) and as the thing benchmarked on hosts, where
fusing the pipeline still pays by skipping the staged path's materialized
[N, W] coefficient arrays and per-row chain geometry.
"""
from __future__ import annotations

import jax.numpy as jnp


def fused_chain_eval_ref(values: jnp.ndarray, sops, ch, pad_uid: int, *,
                         a_lut: jnp.ndarray, b_lut: jnp.ndarray):
    from repro.core.engines import EngineStats
    from repro.core.restructure import (commit_from_histogram,
                                        segmented_scan_affine)

    n = sops.uid.shape[0]
    # coefficient expansion (== engines.affine_coeffs simple-LUT path,
    # then the no-max-table neutralization of tstream_scan_plan)
    a = jnp.broadcast_to(jnp.take(a_lut.astype(sops.operand.dtype),
                                  sops.fun)[:, None], sops.operand.shape)
    b = jnp.where(jnp.take(b_lut, sops.fun)[:, None], sops.operand,
                  jnp.zeros_like(sops.operand))
    neutralize = (~sops.valid)[:, None]
    a = jnp.where(neutralize, jnp.ones_like(a), a)
    b = jnp.where(neutralize, jnp.zeros_like(b), b)

    # exclusive segmented scan + inclusive composition (== tstream_scan_coefs)
    A, B = segmented_scan_affine(a, b, ch.seg_start, exclusive=True)
    Ai = a * A
    Bi = a * B + b

    # values-dependent stage (== tstream_scan_execute(raw=True))
    v0 = jnp.take(values, sops.uid, axis=0)
    pre = A * v0 + B
    post = Ai * v0 + Bi
    success = sops.valid

    commit_pos, commit_ok = commit_from_histogram(ch.counts, ch.starts)
    committed = jnp.take(post, commit_pos, axis=0)
    new_values = jnp.where(commit_ok[:, None], committed, values)
    new_values = new_values.at[pad_uid].set(0.0)

    vmask = sops.valid
    pre = jnp.where(vmask[:, None], pre, 0.0)
    post = jnp.where(vmask[:, None], post, 0.0)
    res = dict(pre=pre, post=post, success=success & vmask)
    stats = EngineStats(
        rounds=jnp.ceil(jnp.log2(ch.max_len.astype(jnp.float32) + 1)),
        n_chains=ch.n_chains, max_chain=ch.max_len,
        n_ops=n, scheme="tstream", path="megakernel")
    return res, new_values, stats
