"""Jit-friendly wrapper: pad to the megakernel layout, dispatch, commit.

``fused_chain_eval`` is the megakernel rung's drop-in replacement for the
staged ``tstream_scan_plan → tstream_scan_coefs → tstream_scan_execute``
pipeline of ``core/engines.py`` — same inputs (a sorted light OpBatch +
its partition Chains), same outputs (sorted-layout results, new state
values, EngineStats), bit-identical values on every shape.  The Pallas
kernel carries the interval when it fits VMEM; otherwise the XLA ref
(``ref.py`` — the staged pipeline recomposed op-for-op) handles it, the
same structural-fallback pattern as ``radix_partition.kernel_fits``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..runtime import default_interpret
from . import kernel as K
from .ref import fused_chain_eval_ref

# VMEM fit bounds for the single-block kernel (interpret-validated; a
# real-device tuning run will tighten them per device kind):
#   MEGA_MAX_ROWS  — the whole interval is ONE block, so ~8 [rows, 128]
#                    f32 residents bound the row count.
#   MEGA_MAX_CELLS — the one-hot gather/scatter matrix is
#                    [rows, n_slots_padded] f32 (4 MiB at 2^20 cells).
MEGA_MAX_ROWS = 4096
MEGA_MAX_CELLS = 1 << 22


def _pad_rows(n: int) -> int:
    return -(-n // 8) * 8  # sublane multiple


def mega_kernel_fits(n_rows: int, n_slots: int) -> bool:
    """Whether the Pallas megakernel carries this interval (else the XLA
    ref — bit-identical — does)."""
    rows = _pad_rows(int(n_rows))
    slots = -(-int(n_slots) // K.LANES) * K.LANES
    return rows <= MEGA_MAX_ROWS and rows * slots <= MEGA_MAX_CELLS


def fused_chain_eval(values: jnp.ndarray, sops, ch, pad_uid: int, *,
                     a_lut: jnp.ndarray, b_lut: jnp.ndarray,
                     use_pallas: bool = False,
                     interpret: Optional[bool] = None):
    """Evaluate all chains of one restructured interval in one dispatch.

    values: f32[S, W] state (S includes the pad slot); sops: sorted light
    OpBatch; ch: partition Chains (counts/starts REQUIRED — the commit
    map comes from the histogram).  a_lut/b_lut: the app's simple-affine
    LUTs (``engines.simple_affine_luts``).  Returns
    ``(res_sorted, new_values, stats)`` exactly like
    ``tstream_scan_execute(..., raw=True)``.
    """
    from repro.core.engines import EngineStats
    from repro.core.restructure import commit_from_histogram

    assert ch.counts is not None, "megakernel needs the partition histogram"
    n, w = sops.operand.shape
    s = values.shape[0]
    interp = default_interpret() if interpret is None else interpret

    if use_pallas and mega_kernel_fits(n, s):
        rows = _pad_rows(n)
        s_pad = -(-s // K.LANES) * K.LANES
        a_sel = jnp.take(a_lut, sops.fun).astype(jnp.float32)
        b_is = jnp.take(b_lut, sops.fun).astype(jnp.float32)
        flags = jnp.broadcast_to(
            ch.seg_start.astype(jnp.float32)[:, None], (n, K.LANES))
        # padding rows: own dead segment (flag=1), identity coefficients,
        # invalid, routed to the pad slot (post = v0[pad] = 0 — their
        # commit contributions are exact zeros)
        flags = jnp.pad(flags, ((0, rows - n), (0, 0)), constant_values=1.0)
        a_sel = jnp.pad(a_sel, (0, rows - n), constant_values=1.0)[:, None]
        b_is = jnp.pad(b_is, (0, rows - n))[:, None]
        valid = jnp.pad(sops.valid.astype(jnp.float32),
                        (0, rows - n))[:, None]
        uid = jnp.pad(sops.uid.astype(jnp.int32), (0, rows - n),
                      constant_values=pad_uid)[:, None]
        operand = jnp.pad(sops.operand.astype(jnp.float32),
                          ((0, rows - n), (0, K.LANES - w)))
        vals = jnp.pad(values.astype(jnp.float32),
                       ((0, s_pad - s), (0, K.LANES - values.shape[1])))
        pre, post, acc = K.fused_chain_pallas(
            flags, a_sel, b_is, valid, uid, operand, vals, interpret=interp)
        pre, post = pre[:n, :w], post[:n, :w]
        committed = acc[:s, :values.shape[1]]
        _, commit_ok = commit_from_histogram(ch.counts, ch.starts)
        new_values = jnp.where(commit_ok[:, None], committed, values)
        new_values = new_values.at[pad_uid].set(0.0)
        res = dict(pre=pre, post=post, success=sops.valid)
        stats = EngineStats(
            rounds=jnp.ceil(jnp.log2(ch.max_len.astype(jnp.float32) + 1)),
            n_chains=ch.n_chains, max_chain=ch.max_len,
            n_ops=n, scheme="tstream", path="megakernel")
        return res, new_values, stats

    return fused_chain_eval_ref(values, sops, ch, pad_uid,
                                a_lut=a_lut, b_lut=b_lut)
