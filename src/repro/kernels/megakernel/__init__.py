from .ops import (MEGA_MAX_CELLS, MEGA_MAX_ROWS, fused_chain_eval,
                  mega_kernel_fits)
from .ref import fused_chain_eval_ref

__all__ = ["fused_chain_eval", "fused_chain_eval_ref", "mega_kernel_fits",
           "MEGA_MAX_ROWS", "MEGA_MAX_CELLS"]
