"""Jit'd wrappers: pad to kernel tiling, dispatch, slice back.

On a CPU host the kernel executes in interpret mode (Python emulation of the
kernel body); on TPU set ``interpret=False`` (the default flips on backend,
overridable via ``JAX_PALLAS_INTERPRET`` — see ``kernels/runtime``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import autotune
from ..runtime import default_interpret as _default_interpret
from . import kernel as K


def _pad(x: jnp.ndarray, rows: int, lanes: int, fill) -> jnp.ndarray:
    n, w = x.shape
    if n == rows and w == lanes:
        return x  # already kernel-shaped (fused driver pre-pads lanes)
    return jnp.pad(x, ((0, rows - n), (0, lanes - w)), constant_values=fill)


@partial(jax.jit, static_argnames=("exclusive", "interpret", "block_rows"))
def segscan_affine(a: jnp.ndarray, b: jnp.ndarray, seg_start: jnp.ndarray,
                   exclusive: bool = True, interpret: bool | None = None,
                   block_rows: int | None = None):
    """Exclusive segmented affine scan via the Pallas kernel.

    a, b: f32[N, W]; seg_start: bool[N].  Returns (A, B) f32[N, W].
    ``block_rows=None`` resolves the tuned block at trace time
    (kernels/autotune); pass an int to force a shape.
    """
    assert exclusive, "kernel implements the exclusive scan"
    interpret = _default_interpret() if interpret is None else interpret
    n, w = a.shape
    if block_rows is None:
        block_rows = autotune.block_rows("segscan", n)
    rows = -(-n // block_rows) * block_rows
    f = jnp.broadcast_to(seg_start.astype(jnp.float32)[:, None],
                         (n, K.LANES))
    # padding rows form their own dead segment (flag=1) so the carry of the
    # real data is not consumed by them
    f = jnp.pad(f, ((0, rows - n), (0, 0)), constant_values=1.0)
    ap = _pad(a.astype(jnp.float32), rows, K.LANES, 1.0)
    bp = _pad(b.astype(jnp.float32), rows, K.LANES, 0.0)
    A, B = K.segscan_affine_pallas(f, ap, bp, interpret=interpret,
                                   block_rows=block_rows)
    return A[:n, :w], B[:n, :w]


@partial(jax.jit, static_argnames=("exclusive", "interpret", "block_rows"))
def segscan_max(m: jnp.ndarray, seg_start: jnp.ndarray,
                exclusive: bool = True, interpret: bool | None = None,
                block_rows: int | None = None):
    """Exclusive segmented max scan via the Pallas kernel."""
    assert exclusive, "kernel implements the exclusive scan"
    interpret = _default_interpret() if interpret is None else interpret
    n, w = m.shape
    if block_rows is None:
        block_rows = autotune.block_rows("segscan", n)
    rows = -(-n // block_rows) * block_rows
    f = jnp.broadcast_to(seg_start.astype(jnp.float32)[:, None],
                         (n, K.LANES))
    f = jnp.pad(f, ((0, rows - n), (0, 0)), constant_values=1.0)
    mp = _pad(m.astype(jnp.float32), rows, K.LANES, 0.0)
    M = K.segscan_max_pallas(f, mp, interpret=interpret,
                             block_rows=block_rows)
    return M[:n, :w]
