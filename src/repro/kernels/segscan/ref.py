"""Pure-jnp oracle for the segmented-scan kernels."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.restructure import segmented_scan_affine, segmented_scan_max


def segscan_affine_ref(flags, a, b):
    """flags: bool[N] (or f32 >0), a/b: f32[N, W] -> exclusive (A, B)."""
    f = jnp.asarray(flags).reshape(-1) > 0
    return segmented_scan_affine(a, b, f, exclusive=True)


def segscan_max_ref(flags, m):
    f = jnp.asarray(flags).reshape(-1) > 0
    return segmented_scan_max(m, f, exclusive=True)
