"""Pallas TPU kernel: segmented scans over sorted operation chains.

This is the compute hot spot of TStream's state-access mode: after dynamic
restructuring, every operation chain is a contiguous, timestamp-sorted
segment of the op stream.  Evaluating all chains = one segmented scan:

  * affine family — compose f(v) = a*v + b (READ/WRITE/ADD/PUT/affine RMW)
  * max family    — running elementwise max (LPC sketches)

TPU mapping
-----------
The op stream [N, W] is tiled into VMEM blocks of BLOCK_ROWS rows on the
sublane axis (W padded to the 128-lane register width by ``ops.py``).  The
grid iterates blocks *sequentially* (TPU grid order); the running segment
carry lives in VMEM scratch — the standard Pallas sequential-carry pattern.
Within a block the scan is a log2(BLOCK_ROWS)-step Hillis–Steele sweep with
segment-flag blocking, so per-chain evaluation is log-depth — strictly more
parallel than the paper's one-thread-per-chain sequential walk.

VMEM budget per grid step (BLOCK_ROWS=256, LANES=128, f32):
3 inputs + 2 outputs + 2 carries ≈ 6 × 128 KiB ≈ 0.75 MiB ≪ 16 MiB VMEM.
All matmul-free; bandwidth-bound on the VPU, which is the right regime for
a data-movement-dominated scheduling workload.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 256
LANES = 128


def _shift_down(x: jnp.ndarray, d: int, fill) -> jnp.ndarray:
    """x[i-d] with ``fill`` for i < d (rows axis)."""
    pad = jnp.full((d,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([pad, x[:-d]], axis=0)


def _segscan_affine_kernel(f_ref, a_ref, b_ref, oa_ref, ob_ref,
                           ca_ref, cb_ref, *, block_rows: int):
    """Exclusive segmented scan of affine maps, carry across blocks."""
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        ca_ref[...] = jnp.ones_like(ca_ref)
        cb_ref[...] = jnp.zeros_like(cb_ref)

    f = f_ref[...] > 0.0          # [R, LANES] raw flags (seg starts)
    a = a_ref[...]
    b = b_ref[...]

    # --- inclusive segmented scan within the block (Hillis–Steele). ------
    # combine(L, R) = R if R's range already crossed a segment start,
    #                 else R∘L:  A = A_R·A_L,  B = A_R·B_L + B_R.
    # The shift fill uses flag=True: the block boundary blocks combining;
    # the carry is folded in afterwards.
    fi, ai, bi = f, a, b
    d = 1
    while d < block_rows:
        fL = _shift_down(fi, d, True)
        aL = _shift_down(ai, d, 1.0)
        bL = _shift_down(bi, d, 0.0)
        na = jnp.where(fi, ai, ai * aL)
        nb = jnp.where(fi, bi, ai * bL + bi)
        fi, ai, bi = fi | fL, na, nb
        d *= 2

    # --- exclusive view: identity at row 0 and at segment starts. --------
    ae = _shift_down(ai, 1, 1.0)
    be = _shift_down(bi, 1, 0.0)
    ae = jnp.where(f, jnp.ones_like(ae), ae)
    be = jnp.where(f, jnp.zeros_like(be), be)

    # --- fold the running carry into rows before the first segment start.
    fint = f.astype(jnp.float32)
    seen = jnp.cumsum(fint, axis=0) - fint      # # seg starts strictly before
    open_head = (seen == 0.0) & ~f              # row continues the carry's seg
    ca, cb = ca_ref[...], cb_ref[...]
    oa_ref[...] = jnp.where(open_head, ae * ca, ae)
    ob_ref[...] = jnp.where(open_head, ae * cb + be, be)

    # --- update carry with the block's last inclusive row. ---------------
    any_flag = jnp.any(f, axis=0, keepdims=True)
    la, lb = ai[-1:], bi[-1:]
    ca_ref[...] = jnp.where(any_flag, la, la * ca)
    cb_ref[...] = jnp.where(any_flag, lb, la * cb + lb)


def _segscan_max_kernel(f_ref, m_ref, om_ref, cm_ref, *, block_rows: int):
    """Exclusive segmented running-max, carry across blocks."""
    g = pl.program_id(0)
    neg = jnp.float32(-jnp.inf)

    @pl.when(g == 0)
    def _init():
        cm_ref[...] = jnp.full_like(cm_ref, neg)

    f = f_ref[...] > 0.0
    m = m_ref[...]

    fi, mi = f, m
    d = 1
    while d < block_rows:
        fL = _shift_down(fi, d, True)
        mL = _shift_down(mi, d, neg)
        mi = jnp.where(fi, mi, jnp.maximum(mi, mL))
        fi = fi | fL
        d *= 2

    me = _shift_down(mi, 1, neg)
    me = jnp.where(f, jnp.full_like(me, neg), me)

    fint = f.astype(jnp.float32)
    seen = jnp.cumsum(fint, axis=0) - fint
    open_head = (seen == 0.0) & ~f
    cm = cm_ref[...]
    om_ref[...] = jnp.where(open_head, jnp.maximum(me, cm), me)

    any_flag = jnp.any(f, axis=0, keepdims=True)
    lm = mi[-1:]
    cm_ref[...] = jnp.where(any_flag, lm, jnp.maximum(cm, lm))


def segscan_affine_pallas(flags: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                          *, interpret: bool = True,
                          block_rows: int = BLOCK_ROWS):
    """Exclusive segmented affine scan.  flags/a/b: f32[N, LANES], N % block_rows == 0."""
    n = a.shape[0]
    assert n % block_rows == 0 and a.shape[1] == LANES, (a.shape, block_rows)
    spec = pl.BlockSpec((block_rows, LANES), lambda g: (g, 0))
    kernel = functools.partial(_segscan_affine_kernel, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(a.shape, a.dtype),
                   jax.ShapeDtypeStruct(b.shape, b.dtype)],
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.float32),
                        pltpu.VMEM((1, LANES), jnp.float32)],
        interpret=interpret,
    )(flags, a, b)


def segscan_max_pallas(flags: jnp.ndarray, m: jnp.ndarray,
                       *, interpret: bool = True,
                       block_rows: int = BLOCK_ROWS):
    """Exclusive segmented max scan.  flags/m: f32[N, LANES], N % block_rows == 0."""
    n = m.shape[0]
    assert n % block_rows == 0 and m.shape[1] == LANES, (m.shape, block_rows)
    spec = pl.BlockSpec((block_rows, LANES), lambda g: (g, 0))
    kernel = functools.partial(_segscan_max_kernel, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(m.shape, m.dtype),
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.float32)],
        interpret=interpret,
    )(flags, m)
