"""Hardware-derived kernel dispatch: autotuned grid/block parameters.

Every Pallas kernel in this repo (``segscan``, ``radix_partition``,
``hash_probe``, and the fused ``megakernel``) used to hard-code its block
shape, validated on exactly one CPU host.  BriskStream's lesson
(PAPERS.md) is that *execution-plan selection* — not just kernel quality
— is what scales stream transaction throughput across machines, so this
module makes the block parameters a function of the device:

1. **Candidate derivation** — ``candidates(kernel)`` derives a short list
   of legal block shapes from ``jax.devices()[0]`` properties (core
   count, lane width, VMEM budget).  The first candidate is the
   *default*: on every device kind it reproduces the hand-validated
   shape this repo shipped with, so behavior without a tuning run is
   exactly the pre-autotune behavior.
2. **Microbenchmark on first use** — ``decide()`` times the candidate
   list (min-of-k, interleaved) the first time a ``(kernel,
   shape-bucket, dtype, device_kind)`` key is seen on a *compiled*
   backend.  Under interpret mode (``kernels/runtime.default_interpret``
   — every CPU host, and CI's ``JAX_PALLAS_INTERPRET=1`` runs) timing a
   Python emulation is meaningless, so the decision is the deterministic
   default candidate, recorded with ``source="interpret-default"``.
3. **Caching** — winners live in an in-process dict keyed by
   ``(kernel, shape_bucket, dtype, device_kind)``; set
   ``REPRO_AUTOTUNE_CACHE=/path.json`` to also round-trip decisions
   through an on-disk JSON cache (loaded lazily, written after every new
   decision).  Decisions are deterministic given a cache: the same key
   never re-benchmarks in one process or across processes sharing the
   disk cache.
4. **Logging** — every decision is logged exactly once per process per
   key (and appended to ``REPRO_AUTOTUNE_LOG`` as JSON lines when set —
   CI uploads that file as a build artifact).
5. **Forcing** — callers pass ``force=<int>`` (threaded from
   ``EngineConfig.kernel_block_params``) to bypass derivation, bench and
   cache entirely; forced values are logged with ``source="forced"``.

The module also owns the **device tables** that turn measured win bands
into dispatch bounds:

* ``LADDER_BOUNDS`` — the restructure ladder's counting-partition auto
  bounds (``core/restructure.partition_fits``).  The CPU row is the
  measured BENCH_restructure.json crossover; accelerator rows are
  provisional estimates (bitonic sort moves the crossover far right)
  pending a real-device tuning run.
* ``MEGA_BOUNDS`` — the fused partition→segscan→commit megakernel's
  auto win band (``core/restructure.megakernel_auto``), from the
  ``kind="fused"`` rows of BENCH_restructure.json.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import time
from typing import Callable, Dict, Optional, Tuple

import jax

from .runtime import default_interpret

log = logging.getLogger(__name__)

LANES = 128  # TPU register lane width — all kernels pad lanes to this

# ---------------------------------------------------------------------------
# Device tables: measured win bands -> dispatch bounds
# ---------------------------------------------------------------------------
# Restructure-ladder counting-partition bounds (max_buckets, min_rows):
# "auto" engages the one-pass partition backbone when the key space is at
# most max_buckets and the batch at least min_rows.  The "cpu" row is THE
# measured host crossover (BENCH_restructure.json, PR 3: 1.3-1.8x for
# owner routing at >=655k rows; parity-to-1.1x for a 9-bucket store at
# 512k; loses for large sparse stores).  Accelerator rows are provisional
# — the jnp.sort baseline is an O(N log^2 N) bitonic network there, which
# moves the crossover toward the partition — and are refined by a
# real-device bench run, not trusted blindly (decide() logs which row was
# used).
LADDER_BOUNDS: Dict[str, Tuple[int, int]] = {
    "cpu": (16, 1 << 18),
    "tpu v3": (64, 1 << 16),
    "tpu v4": (64, 1 << 16),
    "tpu v5": (64, 1 << 16),
    "tpu v6": (64, 1 << 16),
}

# Fused megakernel auto band, per device kind:
#   min_rows  — smallest per-interval op count where the fused
#               partition→segscan→commit pipeline beat the staged path
#               (kind="fused" rows of BENCH_restructure.json; interleaved
#               A/B, min-wall).  None = never auto-engage (forced only).
#   max_buckets — the fused path reuses the counting partition, so its
#               bucket bound applies; beyond it the staged path wins by
#               construction.
# The "cpu" row is measured on this host (BENCH_restructure.json,
# kind="fused"): the fused XLA path — no seg_id/pos/seg_end geometry
# passes, no materialized [N, W] A/B/Ai/Bi coefficient arrays — runs at
# parity-within-noise with the staged pipeline (0.99–1.03x end-to-end
# across N ∈ [32k, 512k], slots ∈ [8, 10k]; the segmented scan dominates
# both).  The headline fusion win (one VMEM-resident dispatch instead of
# three HBM round-trips between restructure, coefs and execute) is a
# device property a host A/B cannot exhibit, so the CPU band engages the
# rung from 32k rows for cost-free continuous coverage of the fused
# path — an honest "no measured win, no measured loss", not a speedup
# claim.  Real-device rows are provisional pending a tuning run.
MEGA_BOUNDS: Dict[str, Dict] = {
    "cpu": dict(min_rows=1 << 15, max_buckets=1 << 14),
    "tpu v4": dict(min_rows=1 << 12, max_buckets=1 << 14),
    "tpu v5": dict(min_rows=1 << 12, max_buckets=1 << 14),
    "tpu v6": dict(min_rows=1 << 12, max_buckets=1 << 14),
}


def _canon_kind(device_kind: Optional[str]) -> str:
    if device_kind is None:
        device_kind = jax.devices()[0].device_kind
    return str(device_kind).strip().lower()


def _table_row(table: Dict[str, object], kind: str):
    if kind in table:
        return table[kind]
    for k, v in table.items():  # prefix match: "tpu v5" covers "TPU v5e"
        if k != "cpu" and kind.startswith(k):
            return v
    return table["cpu"]


def ladder_bounds(device_kind: Optional[str] = None) -> Tuple[int, int]:
    """(max_buckets, min_rows) for the counting-partition auto rung."""
    return _table_row(LADDER_BOUNDS, _canon_kind(device_kind))


def mega_bounds(device_kind: Optional[str] = None) -> Dict:
    """Auto win band of the fused megakernel rung."""
    return _table_row(MEGA_BOUNDS, _canon_kind(device_kind))


# ---------------------------------------------------------------------------
# Device profile + candidate derivation
# ---------------------------------------------------------------------------
def device_profile(device=None) -> Dict:
    """Coarse hardware profile of one device, with conservative fallbacks
    for backends that don't expose a property (CPU hosts expose almost
    nothing — the fallbacks reproduce the hand-validated CPU shapes)."""
    if device is None:
        device = jax.devices()[0]
    kind = _canon_kind(device.device_kind)
    cores = getattr(device, "num_cores", None) or getattr(
        device, "core_count", None) or os.cpu_count() or 1
    # per-core VMEM budget: 16 MiB on every shipped TPU core; on CPU the
    # "VMEM" is L2-ish — the same 16 MiB keeps interpret-mode shapes
    # identical to the TPU shapes (interpret mode is a TPU emulator, not
    # a CPU backend in its own right)
    vmem = getattr(device, "vmem_size_bytes", None) or 16 * 2 ** 20
    return dict(kind=kind, cores=int(cores), lanes=LANES,
                vmem_bytes=int(vmem),
                platform=getattr(device, "platform", "cpu"))


def candidates(kernel: str, profile: Optional[Dict] = None) -> Tuple[int, ...]:
    """Short candidate list of the kernel's tunable block parameter.

    The FIRST entry is the default (== the shape this repo shipped with
    and validated on CPU); the rest bracket it within the device's VMEM
    budget.  Kernels interpret the parameter as:

      segscan          block_rows  (sublane rows per grid step)
      radix_partition  block_rows  (key rows per grid step)
      hash_probe       block_q     (query rows per grid step)
      megakernel       block_rows  (single-block row capacity)
    """
    p = profile or device_profile()
    # rows such that the kernel's dominant VMEM tenant fits the budget:
    # segscan holds ~7 [rows, LANES] f32 arrays; radix's one-hot is
    # [rows, K<=2048]; hash_probe's one-hot is [rows, n_buckets<=8192]
    budget_rows = max(p["vmem_bytes"] // (8 * LANES * 4), 128)
    if kernel == "segscan":
        cand = [256, 128, 512, 1024]
    elif kernel == "radix_partition":
        cand = [256, 128, 512]
    elif kernel == "hash_probe":
        cand = [128, 256, 512]
    elif kernel == "megakernel":
        cand = [4096, 2048, 8192]
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    out = [c for c in cand if c <= budget_rows]
    return tuple(out or cand[:1])


def shape_bucket(n: int) -> str:
    """Power-of-two shape bucket: one tuning decision covers a 2x range
    of row counts (block choice is insensitive within a bucket; keying
    raw N would re-bench every distinct shape)."""
    b = max(int(n) - 1, 1).bit_length()
    return f"2^{b}"


# ---------------------------------------------------------------------------
# The decision cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Decision:
    kernel: str
    shape_bucket: str
    dtype: str
    device_kind: str
    param: int
    source: str            # interpret-default | microbench | forced | disk
    candidates: Tuple[int, ...] = ()
    timings_us: Optional[Dict[str, float]] = None

    @property
    def key(self) -> Tuple[str, str, str, str]:
        return (self.kernel, self.shape_bucket, self.dtype, self.device_kind)


_CACHE: Dict[Tuple[str, str, str, str], Decision] = {}
_LOGGED: set = set()
_DISK_LOADED: set = set()  # cache paths already read this process

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_LOG_ENV = "REPRO_AUTOTUNE_LOG"


def clear_cache() -> None:
    """Test hook: forget all in-process decisions (disk cache untouched)."""
    _CACHE.clear()
    _LOGGED.clear()
    _DISK_LOADED.clear()


def _record(d: Decision) -> None:
    _CACHE[d.key] = d
    if d.key not in _LOGGED:
        _LOGGED.add(d.key)
        log.info("autotune: %s[%s,%s,%s] -> %d (%s)", d.kernel,
                 d.shape_bucket, d.dtype, d.device_kind, d.param, d.source)
        logp = os.environ.get(_LOG_ENV, "")
        if logp:
            try:
                with open(logp, "a") as f:
                    f.write(json.dumps(dataclasses.asdict(d)) + "\n")
            except OSError as e:  # artifact logging must never break dispatch
                log.warning("autotune: cannot append to %s: %s", logp, e)


def _disk_path(cache_path: Optional[str]) -> Optional[str]:
    return cache_path or os.environ.get(_CACHE_ENV) or None


def _load_disk(path: str) -> None:
    if path in _DISK_LOADED:
        return
    _DISK_LOADED.add(path)
    if not os.path.exists(path):
        return
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError) as e:
        log.warning("autotune: ignoring unreadable cache %s: %s", path, e)
        return
    for rec in raw.get("decisions", []):
        try:
            d = Decision(kernel=rec["kernel"],
                         shape_bucket=rec["shape_bucket"],
                         dtype=rec["dtype"],
                         device_kind=rec["device_kind"],
                         param=int(rec["param"]), source="disk",
                         candidates=tuple(rec.get("candidates", ())))
        except (KeyError, TypeError, ValueError):
            continue  # skip malformed rows, keep the rest
        if d.key not in _CACHE:  # in-process decisions win over disk
            _CACHE[d.key] = d
    log.debug("autotune: loaded %d decisions from %s", len(raw.get(
        "decisions", [])), path)


def _save_disk(path: str) -> None:
    try:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(dict(decisions=[dataclasses.asdict(d)
                                      for d in _CACHE.values()]), f, indent=2)
        os.replace(tmp, path)
    except OSError as e:
        log.warning("autotune: cannot write cache %s: %s", path, e)


def decisions_log() -> list:
    """All decisions made (or loaded) this process, as plain dicts."""
    return [dataclasses.asdict(d) for d in _CACHE.values()]


# ---------------------------------------------------------------------------
# decide / kernel-facing lookups
# ---------------------------------------------------------------------------
def _microbench(cands: Tuple[int, ...],
                bench_fn: Callable[[int], float],
                iters: int = 3) -> Tuple[int, Dict[str, float]]:
    """Min-of-k interleaved timing of the candidate list.  ``bench_fn``
    runs one blocked dispatch with the given parameter and returns wall
    seconds (it must block until ready)."""
    for c in cands:          # warm every compile before timing any
        bench_fn(c)
    best: Dict[int, float] = {c: float("inf") for c in cands}
    for _ in range(iters):
        for c in cands:
            best[c] = min(best[c], bench_fn(c))
    winner = min(cands, key=lambda c: best[c])
    return winner, {str(c): best[c] * 1e6 for c in cands}


def decide(kernel: str, n: int, *, dtype: str = "float32",
           device_kind: Optional[str] = None,
           force: Optional[int] = None,
           bench_fn: Optional[Callable[[int], float]] = None,
           interpret: Optional[bool] = None,
           cache_path: Optional[str] = None) -> Decision:
    """Resolve the kernel's block parameter for an ``n``-row dispatch.

    Resolution order: ``force`` (no cache interaction, logged once) ->
    in-process cache -> on-disk cache -> microbenchmark (compiled
    backends with a ``bench_fn``) or the deterministic default candidate
    (interpret mode / no bench_fn).
    """
    kind = _canon_kind(device_kind)
    if force is not None:
        d = Decision(kernel=kernel, shape_bucket=shape_bucket(n),
                     dtype=dtype, device_kind=kind, param=int(force),
                     source="forced")
        if d.key + ("forced",) not in _LOGGED:
            _LOGGED.add(d.key + ("forced",))
            log.info("autotune: %s[%s,%s,%s] -> %d (forced)", kernel,
                     d.shape_bucket, dtype, kind, int(force))
        return d

    key = (kernel, shape_bucket(n), dtype, kind)
    path = _disk_path(cache_path)
    if key not in _CACHE and path:
        _load_disk(path)
    if key in _CACHE:
        return _CACHE[key]

    cands = candidates(kernel)
    interp = default_interpret() if interpret is None else interpret
    if interp or bench_fn is None:
        d = Decision(kernel=kernel, shape_bucket=key[1], dtype=dtype,
                     device_kind=kind, param=cands[0],
                     source="interpret-default" if interp else "default",
                     candidates=cands)
    else:
        winner, timings = _microbench(cands, bench_fn)
        d = Decision(kernel=kernel, shape_bucket=key[1], dtype=dtype,
                     device_kind=kind, param=winner, source="microbench",
                     candidates=cands, timings_us=timings)
    _record(d)
    if path:
        _save_disk(path)
    return d


def _default_bench(kernel: str, n: int) -> Optional[Callable[[int], float]]:
    """Self-contained microbenchmark thunk for a compiled backend: one
    synthetic blocked dispatch per candidate.  Returns None in interpret
    mode (decide() then takes the deterministic default)."""
    if default_interpret():
        return None
    import jax.numpy as jnp

    rows = max(-(-int(n) // 128) * 128, 128)
    if kernel == "segscan":
        from .segscan import kernel as K
        a = jnp.ones((rows, LANES), jnp.float32)
        f = jnp.zeros((rows, LANES), jnp.float32).at[0].set(1.0)

        def bench(c: int) -> float:
            rp = -(-rows // c) * c
            ap = jnp.pad(a, ((0, rp - rows), (0, 0)), constant_values=1.0)
            fp = jnp.pad(f, ((0, rp - rows), (0, 0)), constant_values=1.0)
            t0 = time.perf_counter()
            jax.block_until_ready(K.segscan_affine_pallas(
                fp, ap, ap, interpret=False, block_rows=c))
            return time.perf_counter() - t0
        return bench
    if kernel == "radix_partition":
        from .radix_partition import kernel as K
        keys = jnp.zeros((rows,), jnp.int32)

        def bench(c: int) -> float:
            rp = -(-rows // c) * c
            kp = jnp.pad(keys, (0, rp - rows))[None]
            t0 = time.perf_counter()
            jax.block_until_ready(K.radix_partition_pallas(
                kp, LANES, interpret=False, block_rows=c))
            return time.perf_counter() - t0
        return bench
    if kernel == "hash_probe":
        from .hash_probe import kernel as K
        lo = jnp.zeros((256, K.ASSOC), jnp.float32)
        q = jnp.zeros((rows,), jnp.int32)

        def bench(c: int) -> float:
            rp = -(-rows // c) * c
            qp = jnp.pad(q, (0, rp - rows))
            t0 = time.perf_counter()
            jax.block_until_ready(K.hash_probe_pallas(
                qp, lo, lo, interpret=False, block_q=c))
            return time.perf_counter() - t0
        return bench
    return None


def block_rows(kernel: str, n: int, *, force: Optional[int] = None,
               dtype: str = "float32") -> int:
    """The kernel-facing lookup: tuned block parameter for an ``n``-row
    dispatch (called by the ops wrappers at trace time — the result is a
    static argument of the inner ``pallas_call``)."""
    return decide(kernel, n, dtype=dtype, force=force,
                  bench_fn=_default_bench(kernel, n)).param


def main() -> None:  # pragma: no cover - CLI artifact helper
    import argparse
    ap = argparse.ArgumentParser(
        description="dump autotune decisions / device tables")
    ap.add_argument("--dump", default="", help="write decisions JSON here")
    args = ap.parse_args()
    out = dict(profile=device_profile(), decisions=decisions_log(),
               ladder_bounds=ladder_bounds(), mega_bounds=mega_bounds())
    text = json.dumps(out, indent=2)
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":  # pragma: no cover
    main()
