"""Shared kernel-runtime knobs.

``default_interpret`` resolves whether a Pallas kernel runs in interpret
mode.  Resolution order:

1. ``JAX_PALLAS_INTERPRET`` environment variable, when set: truthy values
   ("1", "true", "yes", "on") force interpret mode — this is how CI
   exercises the *kernel bodies* (not just their jnp refs) on CPU
   runners; falsy values ("0", "false", "no", "off") force compiled
   dispatch.
2. Otherwise: interpret everywhere except on a real TPU backend.

Resolution happens when a wrapper *traces* (``interpret`` is a static
jit argument), so a given input shape bakes the mode into its
compilation-cache entry — flip the environment before the first call on
a shape, not between calls.
"""
from __future__ import annotations

import os

import jax

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def default_interpret() -> bool:
    env = os.environ.get("JAX_PALLAS_INTERPRET", "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    return jax.default_backend() != "tpu"
