"""Jit'd wrapper for the hash-probe kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..runtime import default_interpret
from . import kernel as K


@partial(jax.jit, static_argnames=("interpret",))
def hash_probe(keys: jnp.ndarray, table_lo: jnp.ndarray,
               table_hi: jnp.ndarray, interpret: bool | None = None):
    """keys i32[N] -> slot i32[N] (-1 if absent); pads N to the block size."""
    if interpret is None:
        interpret = default_interpret()
    n = keys.shape[0]
    rows = -(-n // K.BLOCK_Q) * K.BLOCK_Q
    kp = jnp.pad(keys.astype(jnp.int32), (0, rows - n), constant_values=0)
    out = K.hash_probe_pallas(kp, table_lo, table_hi, interpret=interpret)
    return out[:n]
