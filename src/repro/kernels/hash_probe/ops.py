"""Jit'd wrapper for the hash-probe kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import autotune
from ..runtime import default_interpret
from . import kernel as K


@partial(jax.jit, static_argnames=("interpret", "block_q"))
def hash_probe(keys: jnp.ndarray, table_lo: jnp.ndarray,
               table_hi: jnp.ndarray, interpret: bool | None = None,
               block_q: int | None = None):
    """keys i32[N] -> slot i32[N] (-1 if absent); pads N to the block size.

    ``block_q=None`` resolves the tuned query block at trace time
    (kernels/autotune); pass an int to force a shape.
    """
    if interpret is None:
        interpret = default_interpret()
    n = keys.shape[0]
    if block_q is None:
        block_q = autotune.block_rows("hash_probe", n, dtype="int32")
    rows = -(-n // block_q) * block_q
    kp = jnp.pad(keys.astype(jnp.int32), (0, rows - n), constant_values=0)
    out = K.hash_probe_pallas(kp, table_lo, table_hi, interpret=interpret,
                              block_q=block_q)
    return out[:n]
