"""Pallas TPU kernel: bucketed cuckoo-style hash probe (key -> slot).

The paper's time-breakdown (§VI-D) finds index lookup to be the residual
bottleneck once locking is removed (the *No-Lock* "Others" share).  TStream's
state tables use direct addressing for dense keys; for *sparse* keys (the
framework's data-pipeline dedup / per-domain statistics), this kernel
resolves key -> table slot.

TPU adaptation: TPUs have no efficient random gather inside a kernel, so the
probe is reformulated as a **one-hot matmul gather** (MXU-friendly): a query
block builds a one-hot [BLK, n_buckets] matrix and multiplies it against the
bucketed key table [n_buckets, assoc].  Key equality is checked exactly by
splitting 32-bit keys into two 16-bit halves (each exact in f32).  Linear
probing over MAX_PROBES consecutive buckets handles overflow.

VMEM: table 8192×8 ×2 halves ×4B = 512 KiB + one-hot BLK×8192×4B (BLK=128:
4 MiB) — fits; larger tables tile the bucket axis via the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
ASSOC = 8
MAX_PROBES = 4
_MULT = 2654435761  # Knuth multiplicative hash


def bucket_of(key: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    h = (key.astype(jnp.uint32) * jnp.uint32(_MULT)) >> jnp.uint32(16)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def _probe_kernel(q_ref, tlo_ref, thi_ref, out_ref, *, n_buckets: int):
    q = q_ref[...]                       # [BLK, 1] i32 query keys
    qk = q[:, 0]
    qlo = (qk & 0xFFFF).astype(jnp.float32)[:, None]        # [BLK, 1]
    qhi = ((qk >> 16) & 0xFFFF).astype(jnp.float32)[:, None]
    tlo = tlo_ref[...]                   # [n_buckets, ASSOC] f32 halves
    thi = thi_ref[...]

    base = bucket_of(qk, n_buckets)      # [BLK]
    found_slot = jnp.full((q.shape[0],), -1, jnp.int32)
    for p in range(MAX_PROBES):
        bkt = (base + p) % n_buckets
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], n_buckets), 1)
                  == bkt[:, None]).astype(jnp.float32)
        cand_lo = jnp.dot(onehot, tlo)   # [BLK, ASSOC] exact 16-bit values
        cand_hi = jnp.dot(onehot, thi)
        match = (cand_lo == qlo) & (cand_hi == qhi)
        lane = jnp.argmax(match, axis=1).astype(jnp.int32)
        hit = jnp.any(match, axis=1)
        slot = bkt * ASSOC + lane
        found_slot = jnp.where((found_slot < 0) & hit, slot, found_slot)
    out_ref[...] = found_slot[:, None]


def hash_probe_pallas(keys: jnp.ndarray, table_lo: jnp.ndarray,
                      table_hi: jnp.ndarray, *, interpret: bool = True,
                      block_q: int = BLOCK_Q):
    """keys: i32[N] (N % block_q == 0); table halves f32[n_buckets, ASSOC].

    Returns i32[N] slot index, -1 if absent.
    """
    n = keys.shape[0]
    n_buckets = table_lo.shape[0]
    assert n % block_q == 0 and table_lo.shape == (n_buckets, ASSOC)
    kernel = functools.partial(_probe_kernel, n_buckets=n_buckets)
    out = pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[pl.BlockSpec((block_q, 1), lambda g: (g, 0)),
                  pl.BlockSpec((n_buckets, ASSOC), lambda g: (0, 0)),
                  pl.BlockSpec((n_buckets, ASSOC), lambda g: (0, 0))],
        out_specs=pl.BlockSpec((block_q, 1), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(keys[:, None], table_lo, table_hi)
    return out[:, 0]
