"""Pure-jnp oracle for the hash-probe kernel, plus table construction."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .kernel import ASSOC, MAX_PROBES, _MULT


def bucket_of_np(key: np.ndarray, n_buckets: int) -> np.ndarray:
    h = (key.astype(np.uint64) * np.uint64(_MULT)) & np.uint64(0xFFFFFFFF)
    return ((h >> np.uint64(16)) % np.uint64(n_buckets)).astype(np.int32)


def build_table(keys: np.ndarray, n_buckets: int) -> Tuple[np.ndarray, np.ndarray]:
    """Insert keys (distinct, int32 >= 0) with linear probing over buckets.

    Returns the two exact-f32 16-bit half tables used by kernel and ref.
    """
    table = np.full((n_buckets, ASSOC), -1, np.int64)
    for k in keys.astype(np.int64):
        b = int(bucket_of_np(np.asarray(k), n_buckets))
        for p in range(MAX_PROBES):
            row = (b + p) % n_buckets
            free = np.flatnonzero(table[row] < 0)
            if len(free):
                table[row, free[0]] = k
                break
        else:
            raise RuntimeError("hash table overflow; grow n_buckets")
    lo = (table & 0xFFFF).astype(np.float32)
    hi = ((table >> 16) & 0xFFFF).astype(np.float32)
    # empty slots (-1) become (0xFFFF, 0xFFFF) halves of -1's two's
    # complement; queries are >= 0 so they never match.
    return lo, hi


def hash_probe_ref(keys: jnp.ndarray, table_lo: jnp.ndarray,
                   table_hi: jnp.ndarray) -> jnp.ndarray:
    """Oracle: same probing, via direct jnp indexing (no one-hot matmul)."""
    n_buckets = table_lo.shape[0]
    qk = keys.astype(jnp.int32)
    qlo = (qk & 0xFFFF).astype(jnp.float32)[:, None]
    qhi = ((qk >> 16) & 0xFFFF).astype(jnp.float32)[:, None]
    h = (qk.astype(jnp.uint32) * jnp.uint32(_MULT)) >> jnp.uint32(16)
    base = (h % jnp.uint32(n_buckets)).astype(jnp.int32)
    found = jnp.full(qk.shape, -1, jnp.int32)
    for p in range(MAX_PROBES):
        bkt = (base + p) % n_buckets
        cand_lo = jnp.take(table_lo, bkt, axis=0)
        cand_hi = jnp.take(table_hi, bkt, axis=0)
        match = (cand_lo == qlo) & (cand_hi == qhi)
        lane = jnp.argmax(match, axis=1).astype(jnp.int32)
        hit = jnp.any(match, axis=1)
        found = jnp.where((found < 0) & hit, bkt * ASSOC + lane, found)
    return found
