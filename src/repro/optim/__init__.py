from .adamw import AdamWConfig, adamw_init, adamw_update
from .schedule import wsd_schedule, cosine_schedule
from .compress import compress_int8, decompress_int8, compressed_psum
