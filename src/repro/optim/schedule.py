"""LR schedules: cosine and MiniCPM's WSD (warmup–stable–decay)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, warmup: int, total: int, floor: float = 0.1):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def wsd_schedule(step, *, warmup: int, stable: int, decay: int,
                 floor: float = 0.01):
    """MiniCPM WSD: linear warmup, flat plateau, short exponential-ish decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    in_decay = step - (warmup + stable)
    dec = jnp.exp(jnp.log(floor) * jnp.clip(in_decay / jnp.maximum(decay, 1),
                                            0, 1))
    return jnp.where(step < warmup, warm,
                     jnp.where(in_decay < 0, 1.0, dec))
