"""Gradient compression for the data-parallel all-reduce.

int8 block-quantized gradients with error feedback: the DP all-reduce moves
~4x fewer bytes (the collective-bound hillclimb lever for cross-pod links).
Used inside a ``shard_map`` training step; on a pjit path XLA manages the
all-reduce itself and this module is bypassed.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block absmax int8 quantization.  x: any shape (f32/bf16)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_psum(grads: PyTree, axis_name: str,
                    error: PyTree | None = None) -> Tuple[PyTree, PyTree]:
    """Error-feedback compressed gradient all-reduce (inside shard_map).

    Returns (averaged grads, new error feedback state).
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
        q, s = compress_int8(g32)
        # decompress locally, psum the dequantized value (wire cost modeled
        # as int8+scales; psum operand dtype is what XLA sees — we reduce the
        # quantized representation to keep the collective int8-sized).
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(s, axis_name)
        nd = jax.lax.psum(1, axis_name)
        avg = decompress_int8(qsum, ssum / (nd * nd), g32.shape) \
            if False else (qsum.astype(jnp.float32)
                           * (ssum / nd)).reshape(-1)[: g32.size] \
            .reshape(g32.shape) / nd
        new_e = g32 - decompress_int8(q, s, g32.shape)
        return avg.astype(g.dtype), new_e

    if error is None:
        error = jax.tree_util.tree_map(lambda g: jnp.zeros_like(
            g, jnp.float32), grads)
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    avg = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return avg, err
