"""AdamW with ZeRO-style sharding-by-inheritance.

Optimizer state mirrors the parameter pytree, so pjit shards m/v exactly like
the (FSDP-sharded) params — ZeRO-1/2 behaviour falls out of the sharding
rules with no extra code.  ``dtype``-configurable state for the memory
hillclimb (fp32 default, bf16 option halves optimizer HBM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


def adamw_init(params: PyTree, cfg: AdamWConfig) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return dict(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def _global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params: PyTree, grads: PyTree, state: PyTree,
                 cfg: AdamWConfig, lr_scale=1.0) -> Tuple[PyTree, PyTree]:
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, dict(m=new_m, v=new_v, step=step)
