from .ft import TrainLoop, TrainLoopConfig
from .service import ServiceConfig, ServiceRun, StreamService
from .straggler import StragglerPolicy, ShardDispatcher
