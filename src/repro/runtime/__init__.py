from .ft import TrainLoop, TrainLoopConfig
from .straggler import StragglerPolicy, ShardDispatcher
