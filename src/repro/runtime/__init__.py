from .faults import (Fault, FaultPlane, HangAborted, InjectedCrashError,
                     InjectedFault, TransientSourceError, corrupt_snapshot,
                     random_schedule, schedule_from_json, schedule_to_json)
from .ft import TrainLoop, TrainLoopConfig
from .service import (ExecutorHungError, ServiceConfig, ServiceRun,
                      StragglerPolicy, StreamService)
