"""Fault-tolerant training loop: checkpoint/restart with exact resume.

Determinism contract: the batch for step *i* is a pure function of
(data_seed, i) — after a crash, resuming from the last checkpoint replays
the identical data order, so the recovered run is bitwise identical to an
uninterrupted one (tested in tests/test_ft.py by injecting a crash).

At 1000+ node scale the same structure holds per coordinator: jax.distributed
initializes the mesh, every host computes its addressable slice of the
(step-keyed) batch, and the checkpoint manifest carries the mesh so elastic
restarts reshard (ckpt.reshard) instead of requiring the old topology.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt import (latest_step, load_checkpoint, prune_checkpoints,
                        save_checkpoint)

PyTree = Any


@dataclasses.dataclass
class TrainLoopConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_steps: int = 200
    data_seed: int = 0
    keep_last: int = 3


class TrainLoop:
    """Driver around a jitted train_step with restart-from-checkpoint."""

    def __init__(self, cfg: TrainLoopConfig, step_fn: Callable,
                 make_batch: Callable[[int, np.random.Generator], Dict],
                 params: PyTree, opt_state: PyTree):
        self.cfg = cfg
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.params = params
        self.opt_state = opt_state
        self.start_step = 0
        self.losses: list = []

    def try_resume(self) -> bool:
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            return False
        tree = dict(params=self.params, opt=self.opt_state)
        restored = load_checkpoint(self.cfg.ckpt_dir, last, tree)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.start_step = last
        return True

    def _batch_for(self, step: int) -> Dict:
        # data order is a pure function of (seed, step): replay-exact resume
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.data_seed, step]))
        return self.make_batch(step, rng)

    def run(self, until: Optional[int] = None,
            crash_at: Optional[int] = None) -> PyTree:
        until = until or self.cfg.max_steps
        for step in range(self.start_step, until):
            if crash_at is not None and step == crash_at:
                raise RuntimeError(f"injected failure at step {step}")
            batch = self._batch_for(step)
            self.params, self.opt_state, loss = self.step_fn(
                self.params, self.opt_state, batch)
            self.losses.append(float(loss))
            done = step + 1
            if done % self.cfg.ckpt_every == 0 or done == until:
                save_checkpoint(self.cfg.ckpt_dir, done,
                                dict(params=self.params, opt=self.opt_state))
                self._gc()
        return self.params

    def _gc(self):
        prune_checkpoints(self.cfg.ckpt_dir, self.cfg.keep_last)
