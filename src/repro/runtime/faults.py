"""Deterministic, seeded fault-injection plane (DESIGN.md §2.7).

A *fault site* is a named host-side point in the service loop where a
scheduled fault can act.  The plane never touches device code: every
fault models something the host runtime must survive — a flaky or
stalled source, an executor thread dying between dispatch and commit, a
hung executor, a snapshot torn mid-write.  Sites:

=====================  ====================================================
``source.pull``        before each ``next(source)``: raise a
                       ``TransientSourceError`` (retryable) or stall
``executor.crash``     on the executor thread between a chunk's dispatch
                       and its commit: raise ``InjectedCrashError``
``executor.hang``      same point: stall for ``duration_s`` — an
                       *abortable* wait, so the service watchdog can cut
                       it short (``HangAborted``)
``snapshot.publish``   after a snapshot's atomic publish: corrupt it on
                       disk (torn manifest, flipped or truncated leaf,
                       crashed-writer debris directory)
``controller.decide``  on the main thread right after the adaptive
                       controller appends a decision to its trace and
                       BEFORE the decided chunk is submitted — the
                       decision exists but no snapshot has recorded it
                       yet (DESIGN.md §2.9 replay contract)
=====================  ====================================================

A ``FaultSchedule`` is a **pure function of its seed**
(:func:`random_schedule`): the same seed always yields the same faults
at the same site visits, so every chaos run is replayable.  The plane
records every fault it fires in ``FaultPlane.fired`` and the service
merges that log into ``stats["faults"]``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

SOURCE_PULL = "source.pull"
EXECUTOR_CRASH = "executor.crash"
EXECUTOR_HANG = "executor.hang"
SNAPSHOT_PUBLISH = "snapshot.publish"
CONTROLLER_DECIDE = "controller.decide"
RESHARD_APPLY = "reshard.apply"

#: every site -> the fault kinds that may act there.  New sites append
#: LAST: random_schedule's draw order follows this dict, so inserting a
#: site earlier would silently re-deal every pre-existing seed.
SITE_KINDS: Dict[str, tuple] = {
    SOURCE_PULL: ("raise", "stall"),
    EXECUTOR_CRASH: ("crash",),
    EXECUTOR_HANG: ("hang",),
    SNAPSHOT_PUBLISH: ("torn_manifest", "corrupt_leaf", "truncate_leaf",
                       "debris"),
    CONTROLLER_DECIDE: ("crash",),
    RESHARD_APPLY: ("crash",),
}
SITES = tuple(SITE_KINDS)


class InjectedFault(RuntimeError):
    """Base class of every error the fault plane raises."""


class TransientSourceError(InjectedFault):
    """A retryable source failure (the service's retry/backoff target)."""


class InjectedCrashError(InjectedFault):
    """Executor death between dispatch and commit (worker crash)."""


class HangAborted(InjectedFault):
    """An injected hang that the watchdog cut short."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: fire ``kind`` on the ``at``-th visit (0-based)
    of ``site``."""

    site: str
    at: int
    kind: str
    duration_s: float = 0.0     # stall/hang only

    def __post_init__(self):
        assert self.site in SITE_KINDS, self.site
        assert self.kind in SITE_KINDS[self.site], (self.site, self.kind)
        assert self.at >= 0, self.at
        assert self.duration_s >= 0.0, self.duration_s


def random_schedule(seed: int, *, n_pulls: int, n_chunks: int,
                    n_snapshots: int, n_decisions: int = 0,
                    n_reshards: int = 0, max_faults: int = 3,
                    hang_s: float = 8.0,
                    stall_s: float = 0.1) -> List[Fault]:
    """Deterministic schedule: a pure function of ``seed`` (and the site
    ranges).  At most one hang per schedule (a hang costs one watchdog
    timeout of wall clock); ``hang_s`` should exceed the watchdog timeout
    so an injected hang is always *detected*, never slept through.
    ``n_decisions`` opens the ``controller.decide`` site (adaptive runs
    only) and ``n_reshards`` the ``reshard.apply`` site (elastic runs);
    the defaults of 0 keep them closed, so pre-existing seeds yield
    byte-identical schedules."""
    rng = np.random.default_rng(np.random.SeedSequence([0xFA017, int(seed)]))
    n_faults = int(rng.integers(1, max_faults + 1))
    ranges = dict(zip(SITES, (n_pulls, n_chunks, n_chunks, n_snapshots,
                              n_decisions, n_reshards)))
    sites, weights = [], []
    for site, w in ((SOURCE_PULL, 0.35), (EXECUTOR_CRASH, 0.25),
                    (EXECUTOR_HANG, 0.15), (SNAPSHOT_PUBLISH, 0.25),
                    (CONTROLLER_DECIDE, 0.2), (RESHARD_APPLY, 0.2)):
        if ranges[site] > 0:
            sites.append(site)
            weights.append(w)
    if not sites:
        return []
    weights = np.asarray(weights) / np.sum(weights)
    out: List[Fault] = []
    used = set()
    hung = False
    for _ in range(n_faults):
        site = sites[int(rng.choice(len(sites), p=weights))]
        if site == EXECUTOR_HANG and hung:
            site = EXECUTOR_CRASH      # at most one hang per schedule
        at = int(rng.integers(0, ranges[site]))
        if (site, at) in used:
            continue
        used.add((site, at))
        kind = SITE_KINDS[site][int(rng.integers(0, len(SITE_KINDS[site])))]
        dur = 0.0
        if kind == "stall":
            dur = float(stall_s)
        elif kind == "hang":
            dur, hung = float(hang_s), True
        out.append(Fault(site=site, at=at, kind=kind, duration_s=dur))
    return sorted(out, key=lambda f: (f.site, f.at))


class FaultPlane:
    """Consults the schedule at each site visit and acts.

    Per-site visit counters make the plane deterministic: the *n*-th
    visit of a site always observes the same scheduled fault, whatever
    the wall-clock interleaving.  ``abort()`` (called by the service
    watchdog) wakes every injected stall/hang immediately.
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        self._sched: Dict[tuple, Fault] = {}
        for f in faults:
            assert (f.site, f.at) not in self._sched, \
                f"duplicate fault at {(f.site, f.at)}"
            self._sched[(f.site, f.at)] = f
        self.visits: Dict[str, int] = {s: 0 for s in SITES}
        self.fired: List[Dict] = []
        self._abort = threading.Event()

    def abort(self) -> None:
        """Cut every in-progress injected stall/hang short (watchdog)."""
        self._abort.set()

    def publish(self, tele) -> None:
        """Mirror the injection ledger into a telemetry registry
        (DESIGN.md §2.11): one structured record per fired fault plus a
        per-site visit counter.  ``tele`` is duck-typed (anything with
        ``ensure_records``/``record_doc``/``count``) so this layer never
        imports the runtime telemetry module."""
        tele.ensure_records("faults")
        for f in self.fired:
            tele.record_doc("faults", dict(f))
        tele.count("faults.fired", len(self.fired))
        for site, n in self.visits.items():
            if n:
                tele.count("faults.visits", n, site=site)

    def _visit(self, site: str) -> Optional[Fault]:
        i = self.visits[site]
        self.visits[site] = i + 1
        f = self._sched.get((site, i))
        if f is not None:
            self.fired.append(dict(site=site, visit=i, kind=f.kind,
                                   duration_s=f.duration_s))
        return f

    # -- sites (called by runtime/service.py) --------------------------
    def on_source_pull(self) -> None:
        f = self._visit(SOURCE_PULL)
        if f is None:
            return
        if f.kind == "raise":
            raise TransientSourceError(
                f"injected source fault at pull {f.at}")
        self._abort.wait(f.duration_s)          # stall (abortable)

    def on_executor_chunk(self) -> None:
        """Between a chunk's dispatch and its commit."""
        f = self._visit(EXECUTOR_CRASH)
        if f is not None:
            raise InjectedCrashError(
                f"injected executor crash at chunk {f.at}")
        f = self._visit(EXECUTOR_HANG)
        if f is not None and self._abort.wait(f.duration_s):
            raise HangAborted(
                f"injected executor hang at chunk {f.at} aborted")

    def on_snapshot_publish(self, step_dir: str) -> None:
        f = self._visit(SNAPSHOT_PUBLISH)
        if f is not None:
            corrupt_snapshot(step_dir, f.kind)

    def on_controller_decide(self) -> None:
        """After the controller appended >= 1 decision to its trace, on
        the main thread, BEFORE the decided chunk is submitted.  The
        visit counter indexes decision *boundaries*, so ``at=k`` crashes
        on the k-th boundary that actually switched a knob — between the
        decision and any snapshot that would record it."""
        f = self._visit(CONTROLLER_DECIDE)
        if f is not None:
            raise InjectedCrashError(
                f"injected controller crash at decision boundary {f.at}")

    def on_reshard_apply(self) -> None:
        """Right after a live migration moved the state onto its new
        placement and BEFORE the next chunk is dispatched — the worst
        crash point for elastic resharding: the device layout changed but
        no snapshot records the migrated run yet.  Recovery must land on
        a *consistent* layout (the pre-migration snapshot's canonical
        values re-enter under whatever ownership the replayed trace
        folds to)."""
        f = self._visit(RESHARD_APPLY)
        if f is not None:
            raise InjectedCrashError(
                f"injected crash after reshard apply {f.at}")


# ---------------------------------------------------------------------------
# on-disk snapshot corruption (torn-write simulation; also used directly
# by tests and examples/streaming_service.py --corrupt-latest)
# ---------------------------------------------------------------------------
def corrupt_snapshot(step_dir: str, kind: str) -> str:
    """Damage a *published* snapshot the way a torn write / crashed
    writer would.  Returns a short description of what was done."""
    assert kind in SITE_KINDS[SNAPSHOT_PUBLISH], kind
    if kind == "torn_manifest":
        path = os.path.join(step_dir, "manifest.json")
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
        return f"truncated manifest.json to {max(1, size // 2)}B"
    if kind == "debris":
        # a crashed writer's half-made step directory with a HIGHER step:
        # it must never shadow the valid snapshot it sits next to
        parent = os.path.dirname(step_dir.rstrip(os.sep))
        m = re.match(r"step_(\d+)$", os.path.basename(step_dir.rstrip(os.sep)))
        step = int(m.group(1)) if m else 0
        debris = os.path.join(parent, f"step_{step + 1:08d}")
        os.makedirs(debris, exist_ok=True)
        with open(os.path.join(debris, "values.npy"), "wb") as f:
            f.write(b"\x93NUMPY partial")
        return f"planted manifest-less debris dir {os.path.basename(debris)}"
    leaves = sorted(f for f in os.listdir(step_dir) if f.endswith(".npy"))
    assert leaves, f"no leaves under {step_dir}"
    path = os.path.join(step_dir, leaves[0])
    size = os.path.getsize(path)
    if kind == "truncate_leaf":
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
        return f"truncated {leaves[0]} to {max(1, size // 2)}B"
    with open(path, "r+b") as f:            # corrupt_leaf: flip last byte
        f.seek(size - 1)
        b = f.read(1)
        f.seek(size - 1)
        f.write(bytes([b[0] ^ 0xFF]))
    return f"flipped a byte in {leaves[0]}"


def schedule_to_json(faults: Sequence[Fault]) -> str:
    return json.dumps([dataclasses.asdict(f) for f in faults])


def schedule_from_json(s: str) -> List[Fault]:
    return [Fault(**d) for d in json.loads(s)]
