"""Unified telemetry plane (DESIGN.md §2.11).

One registry for every observability surface the runtime grew piecemeal:

* **Labeled counters / gauges** — monotone totals (drops by category,
  exchange ship/drop counts, the assembler's conservation ledger) and
  point-in-time levels (watermark, exchange capacity, backfill ratio).
* **Deterministic log-bucketed histograms** — latency distributions with
  geometric bucket bounds fixed at construction, so two histograms built
  from the same observations are bit-equal and *merge exactly*: bucket
  counts are integer sums, the running total is kept in integer
  nanoseconds, and min/max merge by min/max.  Merge is associative and
  conservation-respecting (pinned by tests/test_telemetry.py).
* **Bounded structured record logs** — the chunk-record ring, decision
  trace, fired faults, migrations: ordered lists of JSON documents.
* **Rate-limited events** — the once-per-run log lines ("watermark
  policy dropped …") become structured events that still emit through
  the caller's logger with the exact legacy message, but carry a
  occurrence count and a per-registry emission limit instead of
  hand-rolled "logged once" flags.
* **Span tracing** — Chrome-trace / Perfetto-compatible JSONL covering
  the whole service pipeline (source pull → interval assembly →
  admission → chunk dispatch → device execute → commit →
  ``controller.decide`` → snapshot publish → ``reshard.apply``), plus
  opt-in per-chunk cost attribution (compiled-HLO flops/bytes via
  ``launch/hlo_analysis.py``, achieved-vs-peak roofline fractions).

**Replay-safety contract** (the §2.11 hard invariant): telemetry is
observability only.  The tracer reads a clock *only when a trace sink is
attached*; span data and histograms never feed ``controller.decide``;
a tracing-enabled run is bitwise identical to a tracing-off run —
including crash → restore → replay.  The only sanctioned timing→control
bridge is the *advisory* channel (``runtime/controller.AdvisoryTiming``):
timing-tier hints are logged and recorded here but never applied while
snapshots are on.

The registry snapshot is versioned (``SCHEMA`` / ``SCHEMA_VERSION``);
``stats_view`` renders the legacy ``StreamService.stats`` dict from a
snapshot so the old surface survives as a compatibility view.
"""
from __future__ import annotations

import json
import logging
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

log = logging.getLogger(__name__)

SCHEMA = "repro.telemetry"
SCHEMA_VERSION = 1

# default latency-histogram geometry: 4 buckets per octave from 1 µs,
# 30 octaves (~18 min) before the overflow bucket — wide enough for a
# cold-compile chunk, fine enough for sub-ms percentile reads
HIST_LO_S = 1e-6
HIST_GROWTH = 2.0 ** 0.25
HIST_BUCKETS = 120

_LabelKey = Tuple[Tuple[str, Any], ...]


def _label_key(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted(labels.items()))


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------
class Histogram:
    """Log-bucketed histogram with deterministic bucketing + exact merge.

    Bucket *i* covers ``(bound[i-1], bound[i]]`` with
    ``bound[i] = lo * growth**i`` (bucket 0 additionally absorbs
    everything ``<= lo``, the last bucket is the overflow).  The bounds
    are a pure function of ``(lo, growth, n_buckets)``, so any two
    histograms with the same geometry bucket identically and merging is
    per-bucket integer addition — associative and lossless.  The value
    total is kept in integer nanoseconds (``total_ns``) so merged sums
    are exact, not float-order-dependent.
    """

    __slots__ = ("lo", "growth", "n_buckets", "_bounds", "counts",
                 "count", "total_ns", "vmin", "vmax")

    def __init__(self, lo: float = HIST_LO_S, growth: float = HIST_GROWTH,
                 n_buckets: int = HIST_BUCKETS):
        assert lo > 0 and growth > 1.0 and n_buckets >= 1
        self.lo = float(lo)
        self.growth = float(growth)
        self.n_buckets = int(n_buckets)
        self._bounds = self.lo * self.growth ** np.arange(self.n_buckets)
        self.counts = np.zeros(self.n_buckets + 1, np.int64)
        self.count = 0
        self.total_ns = 0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def geometry(self) -> Tuple[float, float, int]:
        return (self.lo, self.growth, self.n_buckets)

    def observe(self, value: float) -> None:
        self.observe_many([value])

    def observe_many(self, values) -> None:
        a = np.asarray(values, np.float64).ravel()
        if a.size == 0:
            return
        idx = np.searchsorted(self._bounds, a, side="left")
        np.add.at(self.counts, idx, 1)
        self.count += int(a.size)
        self.total_ns += int(np.rint(a * 1e9).astype(np.int64).sum())
        self.vmin = min(self.vmin, float(a.min()))
        self.vmax = max(self.vmax, float(a.max()))

    @property
    def mean_s(self) -> float:
        return (self.total_ns / 1e9 / self.count) if self.count else float("nan")

    def percentile(self, q: float) -> float:
        """Deterministic percentile read: the upper bound of the bucket
        holding the q-th ranked observation, clipped to the observed
        [min, max] — exact to within one bucket's width."""
        if self.count == 0:
            return float("nan")
        rank = max(1.0, q / 100.0 * self.count)
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank, side="left"))
        est = self._bounds[min(i, self.n_buckets - 1)]
        return float(min(max(est, self.vmin), self.vmax))

    def merge(self, other: "Histogram") -> "Histogram":
        assert self.geometry() == other.geometry(), \
            (f"histogram geometry mismatch: {self.geometry()} != "
             f"{other.geometry()} — exact merge requires identical buckets")
        self.counts = self.counts + other.counts
        self.count += other.count
        self.total_ns += other.total_ns
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    def to_dict(self) -> Dict:
        nz = np.nonzero(self.counts)[0]
        return dict(
            lo=self.lo, growth=self.growth, n_buckets=self.n_buckets,
            counts={str(int(i)): int(self.counts[i]) for i in nz},
            count=int(self.count), total_ns=int(self.total_ns),
            min=(None if self.count == 0 else self.vmin),
            max=(None if self.count == 0 else self.vmax))

    @staticmethod
    def from_dict(d: Dict) -> "Histogram":
        h = Histogram(lo=float(d["lo"]), growth=float(d["growth"]),
                      n_buckets=int(d["n_buckets"]))
        for i, c in d.get("counts", {}).items():
            h.counts[int(i)] = int(c)
        h.count = int(d["count"])
        h.total_ns = int(d["total_ns"])
        if h.count:
            h.vmin = float(d["min"])
            h.vmax = float(d["max"])
        return h


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------
class Telemetry:
    """Thread-safe metrics registry: counters, gauges, histograms,
    bounded record logs and rate-limited events, snapshotted behind the
    versioned schema.  One instance per service run (merged views come
    from :meth:`merge`); a process-wide instance serves code paths with
    no run context (:func:`get_default`)."""

    def __init__(self, record_cap: int = 4096):
        self._lock = threading.RLock()
        self.record_cap = int(record_cap)
        self._counters: Dict[str, Dict[_LabelKey, float]] = {}
        self._gauges: Dict[str, Dict[_LabelKey, float]] = {}
        self._hists: Dict[str, Histogram] = {}
        self._records: Dict[str, List[Any]] = {}
        self._events: Dict[str, Dict[str, int]] = {}

    # -- counters / gauges -------------------------------------------------
    def count(self, name: str, n: float = 1, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            series = self._counters.setdefault(name, {})
            series[key] = series.get(key, 0) + n

    def gauge(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._gauges.setdefault(name, {})[_label_key(labels)] = value

    def counter_value(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get(name, {}).get(_label_key(labels), 0)

    # -- histograms --------------------------------------------------------
    def histogram(self, name: str, lo: float = HIST_LO_S,
                  growth: float = HIST_GROWTH,
                  n_buckets: int = HIST_BUCKETS) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(lo, growth, n_buckets)
            return h

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    def observe_many(self, name: str, values) -> None:
        a = np.asarray(values, np.float64).ravel()
        if a.size:
            self.histogram(name).observe_many(a)

    # -- structured record logs --------------------------------------------
    def ensure_records(self, name: str) -> None:
        with self._lock:
            self._records.setdefault(name, [])

    def record(self, name: str, **fields) -> None:
        self.record_doc(name, fields)

    def record_doc(self, name: str, doc: Any) -> None:
        with self._lock:
            lst = self._records.setdefault(name, [])
            lst.append(doc)
            if len(lst) > self.record_cap:
                del lst[: len(lst) - self.record_cap]

    def records(self, name: str) -> List[Any]:
        with self._lock:
            return list(self._records.get(name, ()))

    # -- rate-limited structured events ------------------------------------
    def event(self, name: str, msg: str, *args, logger=None,
              level: int = logging.WARNING, limit: int = 1) -> bool:
        """Count an occurrence of ``name``; emit ``msg % args`` through
        ``logger`` for the first ``limit`` occurrences (``limit=-1``:
        always).  Returns whether this occurrence was emitted — the
        replacement for the hand-rolled "logged once per run" flags."""
        with self._lock:
            st = self._events.setdefault(
                name, dict(count=0, emitted=0, limit=int(limit)))
            st["count"] += 1
            emit = st["limit"] < 0 or st["emitted"] < st["limit"]
            if emit:
                st["emitted"] += 1
        if emit and logger is not None:
            logger.log(level, msg, *args)
        return emit

    # -- merge / snapshot --------------------------------------------------
    def merge(self, other: "Telemetry") -> "Telemetry":
        """Fold ``other`` into this registry: counters and event counts
        add, histograms merge exactly, records concatenate (cap kept),
        gauges take ``other``'s value (latest wins)."""
        with self._lock, other._lock:
            for name, series in other._counters.items():
                mine = self._counters.setdefault(name, {})
                for k, v in series.items():
                    mine[k] = mine.get(k, 0) + v
            for name, series in other._gauges.items():
                self._gauges.setdefault(name, {}).update(series)
            for name, h in other._hists.items():
                if name in self._hists:
                    self._hists[name].merge(h)
                else:
                    self._hists[name] = Histogram.from_dict(h.to_dict())
            for name, lst in other._records.items():
                for doc in lst:
                    self.record_doc(name, doc)
            for name, st in other._events.items():
                mine = self._events.setdefault(
                    name, dict(count=0, emitted=0, limit=st["limit"]))
                mine["count"] += st["count"]
                mine["emitted"] += st["emitted"]
        return self

    def snapshot(self) -> Dict:
        """The versioned schema document (JSON-serializable)."""
        with self._lock:
            return {
                "schema": SCHEMA,
                "schema_version": SCHEMA_VERSION,
                "counters": [
                    dict(name=name, labels=dict(k), value=v)
                    for name, series in sorted(self._counters.items())
                    for k, v in sorted(series.items())],
                "gauges": [
                    dict(name=name, labels=dict(k), value=v)
                    for name, series in sorted(self._gauges.items())
                    for k, v in sorted(series.items())],
                "histograms": {name: h.to_dict()
                               for name, h in sorted(self._hists.items())},
                "events": [dict(name=name, **st)
                           for name, st in sorted(self._events.items())],
                "records": {name: list(lst)
                            for name, lst in self._records.items()},
            }

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, default=_json_default)


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o)}")


_DEFAULT: Optional[Telemetry] = None
_DEFAULT_LOCK = threading.Lock()


def get_default() -> Telemetry:
    """The process-wide registry — for code paths outside a service run
    (the batch drivers' overflow accounting, ad-hoc counters)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = Telemetry()
        return _DEFAULT


# ---------------------------------------------------------------------------
# snapshot accessors (consumed by benchmarks/report over saved JSON too)
# ---------------------------------------------------------------------------
def counter_value(snap: Dict, name: str, default: float = 0, **labels):
    want = dict(labels)
    for c in snap.get("counters", ()):
        if c["name"] == name and dict(c.get("labels", {})) == want:
            return c["value"]
    return default


def gauge_value(snap: Dict, name: str, default: float = 0, **labels):
    want = dict(labels)
    for g in snap.get("gauges", ()):
        if g["name"] == name and dict(g.get("labels", {})) == want:
            return g["value"]
    return default


def has_gauge(snap: Dict, name: str) -> bool:
    return any(g["name"] == name for g in snap.get("gauges", ()))


def record_entries(snap: Dict, name: str) -> List[Any]:
    return list(snap.get("records", {}).get(name, ()))


def has_records(snap: Dict, name: str) -> bool:
    return name in snap.get("records", {})


def counters_with_prefix(snap: Dict, prefix: str) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for c in snap.get("counters", ()):
        if c["name"].startswith(prefix) and not c.get("labels"):
            out[c["name"][len(prefix):]] = c["value"]
    return out


def histogram_from(snap: Dict, name: str) -> Optional[Histogram]:
    d = snap.get("histograms", {}).get(name)
    return None if d is None else Histogram.from_dict(d)


def load_snapshot(path: str) -> Dict:
    with open(path) as f:
        snap = json.load(f)
    assert snap.get("schema") == SCHEMA, f"not a telemetry snapshot: {path}"
    assert int(snap.get("schema_version", 0)) <= SCHEMA_VERSION, \
        (f"telemetry snapshot {path} has schema_version "
         f"{snap.get('schema_version')} > supported {SCHEMA_VERSION}")
    return snap


# ---------------------------------------------------------------------------
# the legacy stats dict as a view over the schema
# ---------------------------------------------------------------------------
def stats_view(snap: Dict) -> Dict:
    """Render ``StreamService.stats``' legacy shape from a registry
    snapshot — the compatibility view: every consumer of the old merged
    dict keeps working while the registry is the source of truth."""
    def C(name, **labels):
        return counter_value(snap, name, **labels)

    def G(name, default=0.0):
        return gauge_value(snap, name, default)

    assembly = dict(arrived=0, assembled=0, dropped=0, pending=0,
                    rerouted=0, emitted=0)
    assembly.update({k: int(v) for k, v in
                     counters_with_prefix(snap, "assembly.").items()})
    stats: Dict[str, Any] = dict(
        arrived=int(C("service.arrived")),
        processed=int(C("service.processed")),
        replayed=int(C("service.replayed")),
        late_rerouted=int(C("service.late_rerouted")),
        drops=dict(
            watermark=int(C("service.drops", kind="watermark")),
            admission=int(C("service.drops", kind="admission")),
            exchange=int(C("service.drops", kind="exchange"))),
        unprocessed=int(C("service.unprocessed")),
        snapshots=[int(r["step"]) for r in record_entries(snap, "snapshots")],
        watermark=int(G("service.watermark")),
        crashed=bool(G("service.crashed")),
        assembly=assembly,
        source=dict(
            pulls=int(C("source.pulls")),
            retries=int(C("source.retries")),
            deadline_misses=int(C("source.deadline_misses")),
            backoff_s=float(C("source.backoff_s")),
            backfill_ratio=float(G("source.backfill_ratio")),
            alarm_threshold=float(G("source.alarm_threshold")),
            alarm=bool(G("source.alarm"))),
        chunks=[dict(r) for r in record_entries(snap, "chunks")],
    )
    ctl = record_entries(snap, "controller")
    if ctl:
        stats["controller"] = dict(
            dict(ctl[0]),
            decisions=[dict(d) for d in record_entries(snap, "decisions")])
        adv = record_entries(snap, "advisory")
        if adv:
            stats["controller"]["advisory"] = [dict(h) for h in adv]
    err = record_entries(snap, "error")
    if err:
        stats["error"] = dict(err[0])
    if has_records(snap, "faults"):
        stats["faults"] = record_entries(snap, "faults")
    if has_gauge(snap, "exchange.capacity"):
        stats["exchange"] = dict(
            dropped=int(C("exchange.dropped")),
            shipped=int(C("exchange.shipped")),
            capacity=int(G("exchange.capacity")),
            escalations=int(G("exchange.escalations")),
            slack=float(G("exchange.slack")))
        pl = record_entries(snap, "placement")
        placement = (dict(pl[0]) if pl
                     else dict(shard_events=[], imbalance=1.0, owners=[]))
        placement["migrations"] = [dict(m) for m
                                   in record_entries(snap, "migrations")]
        placement["moved_rows"] = int(sum(
            m.get("moved", 0) for m in placement["migrations"]))
        stats["placement"] = placement
    return stats


def empty_stats() -> Dict:
    """The schema-valid zero record ``StreamService.stats`` returns
    before any run (the old ``None`` footgun, fixed)."""
    return stats_view(Telemetry().snapshot())


# ---------------------------------------------------------------------------
# span tracing (Chrome trace event format / Perfetto JSON)
# ---------------------------------------------------------------------------
# the pipeline stages a service trace must cover (CI validation list);
# "reshard.apply" joins when an elastic run actually migrates
PIPELINE_STAGES = ("source.pull", "admission", "assembly", "chunk.submit",
                   "chunk.dispatch", "chunk.execute", "chunk.commit",
                   "snapshot.publish")


class TraceWriter:
    """Incremental Chrome-trace JSON array writer.  Events stream out
    one-per-line so a crashed run leaves a readable prefix (the format's
    closing ``]`` is optional for trace viewers and for
    :func:`validate_trace`); :meth:`close` makes the file strict JSON."""

    def __init__(self, path: str):
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self.path = path
        self._f = open(path, "w")
        self._f.write("[")
        self._first = True
        self._lock = threading.Lock()
        self._n = 0

    def emit(self, ev: Dict) -> None:
        line = json.dumps(ev, separators=(",", ":"), default=_json_default)
        with self._lock:
            if self._f.closed:
                return
            self._f.write(("\n" if self._first else ",\n") + line)
            self._first = False
            self._n += 1
            if self._n % 32 == 0:
                self._f.flush()

    def flush(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.write("\n]\n")
                self._f.close()


class _Span:
    """A ``ph="X"`` complete event; ``set(**args)`` attaches arguments
    any time before exit (cost attribution lands this way)."""

    __slots__ = ("_tr", "name", "cat", "args", "_t0")

    def __init__(self, tracer, name, cat, args):
        self._tr = tracer
        self.name = name
        self.cat = cat
        self.args = dict(args)

    def set(self, **kw) -> "_Span":
        self.args.update(kw)
        return self

    def __enter__(self) -> "_Span":
        self._t0 = time.monotonic_ns()
        return self

    def __exit__(self, *exc) -> bool:
        self._tr._emit_complete(self.name, self.cat, self._t0,
                                time.monotonic_ns(), self.args)
        return False


class _NullSpan:
    __slots__ = ()

    def set(self, **kw) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing off: never reads a clock, never allocates — the replay
    path's proof that telemetry is pure observability."""

    enabled = False

    def span(self, name, cat="pipeline", **args):
        return _NULL_SPAN

    def complete_at(self, name, t0_s, t1_s, cat="pipeline", **args):
        pass

    def instant(self, name, cat="pipeline", **args):
        pass

    def close(self):
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Span emitter over a :class:`TraceWriter`.  Timestamps come from
    ``time.monotonic_ns`` anchored at construction; span durations also
    land in the registry as ``span.<name>`` histograms (observability
    only — nothing on the decision path reads them)."""

    enabled = True

    def __init__(self, writer: TraceWriter, registry: Optional[Telemetry]
                 = None, process_name: str = "repro-stream-service"):
        self._w = writer
        self._reg = registry
        self.pid = os.getpid()
        self.epoch_ns = time.monotonic_ns()
        self._tids: Dict[int, int] = {}
        self._tlock = threading.Lock()
        self._w.emit(dict(name="process_name", ph="M", ts=0, pid=self.pid,
                          tid=0, args=dict(name=process_name)))

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._tlock:
            tid = self._tids.get(ident)
            if tid is None:
                tid = self._tids[ident] = len(self._tids) + 1
                self._w.emit(dict(
                    name="thread_name", ph="M", ts=0, pid=self.pid, tid=tid,
                    args=dict(name=threading.current_thread().name)))
        return tid

    def span(self, name: str, cat: str = "pipeline", **args) -> _Span:
        return _Span(self, name, cat, args)

    def _emit_complete(self, name, cat, t0_ns, t1_ns, args) -> None:
        ev = dict(name=name, cat=cat, ph="X",
                  ts=(t0_ns - self.epoch_ns) / 1e3,
                  dur=max((t1_ns - t0_ns) / 1e3, 0.0),
                  pid=self.pid, tid=self._tid())
        if args:
            ev["args"] = args
        self._w.emit(ev)
        if self._reg is not None:
            self._reg.observe("span." + name, (t1_ns - t0_ns) / 1e9)

    def complete_at(self, name: str, t0_s: float, t1_s: float,
                    cat: str = "pipeline", **args) -> None:
        """Emit a complete event from two ``time.monotonic()`` stamps the
        caller already took for its own accounting — the execute span is
        reconstructed this way so tracing adds no clock read of its own
        to the dispatch/commit path."""
        t0_ns = int(t0_s * 1e9)
        t1_ns = int(t1_s * 1e9)
        self._emit_complete(name, cat, t0_ns, t1_ns, args)

    def instant(self, name: str, cat: str = "pipeline", **args) -> None:
        ev = dict(name=name, cat=cat, ph="i", s="t",
                  ts=(time.monotonic_ns() - self.epoch_ns) / 1e3,
                  pid=self.pid, tid=self._tid())
        if args:
            ev["args"] = args
        self._w.emit(ev)

    def close(self) -> None:
        self._w.close()


@dataclass(frozen=True)
class TelemetryConfig:
    """Opt-in observability surfaces for one service run.  Everything
    defaults off; any combination is replay-safe (DESIGN.md §2.11)."""

    trace_path: str = ""        # Perfetto/Chrome JSONL sink; "" = no tracing
    profile_dir: str = ""       # jax.profiler per-chunk windows; "" = off
    hlo_attribution: bool = False  # compiled-HLO cost per chunk shape
    record_cap: int = 4096      # bound on every structured record log


def make_tracer(tcfg: Optional[TelemetryConfig],
                registry: Optional[Telemetry] = None):
    if tcfg is None or not tcfg.trace_path:
        return NULL_TRACER
    return Tracer(TraceWriter(tcfg.trace_path), registry)


# ---------------------------------------------------------------------------
# profiling hooks (opt-in; never on the replay path)
# ---------------------------------------------------------------------------
class ChunkProfiler:
    """Per-chunk ``jax.profiler`` windows: one ``StepTraceAnnotation``
    per dispatched chunk inside a run-scoped ``start_trace`` window.
    Fully inert unless ``profile_dir`` is set; failures degrade to a
    one-time warning, never to a run error."""

    def __init__(self, profile_dir: str = ""):
        self.profile_dir = profile_dir
        self.active = False

    def start(self) -> None:
        if not self.profile_dir:
            return
        try:
            import jax
            jax.profiler.start_trace(self.profile_dir)
            self.active = True
        except Exception as e:
            log.warning("jax.profiler start failed (%s: %s) — profiling "
                        "disabled for this run", type(e).__name__, e)

    def chunk(self, step: int):
        if not self.active:
            return _NULL_SPAN
        import jax
        return jax.profiler.StepTraceAnnotation("service_chunk",
                                                step_num=int(step))

    def stop(self) -> None:
        if not self.active:
            return
        self.active = False
        try:
            import jax
            jax.profiler.stop_trace()
        except Exception as e:
            log.warning("jax.profiler stop failed (%s: %s)",
                        type(e).__name__, e)


# modest host fallback when benchmarks/roofline.py is not importable
# (scripts run outside the repo root); matches its "cpu" row
_FALLBACK_PEAKS = dict(peak_flops=1e12, hbm_bw=40e9, link_bw=20e9)


class CostAttributor:
    """Opt-in per-chunk cost attribution: lower+compile the chunk program
    for the observed shapes once per (variant, slack, owners, K) shape
    key, run ``launch/hlo_analysis.analyze_hlo`` over the compiled HLO,
    and annotate execute spans with achieved-vs-peak roofline fractions.
    The AOT lowering is a real compile — documented one-time cost per
    shape, which is why this is opt-in (``hlo_attribution=True``)."""

    def __init__(self, n_devices: int = 1):
        self.n_devices = max(int(n_devices), 1)
        self._peaks: Optional[Dict[str, float]] = None
        self._warned = False

    def chunk_cost(self, engine, values, batched,
                   variant=None) -> Optional[Dict]:
        """Trip-weighted flops/bytes/wire for the chunk program that runs
        these shapes (None on any failure — attribution never breaks a
        run)."""
        try:
            from repro.launch.hlo_analysis import analyze_hlo
            hlo = engine.chunk_lowered_text(values, batched, variant=variant)
            return analyze_hlo(hlo, self.n_devices)
        except Exception as e:
            if not self._warned:
                self._warned = True
                log.warning("per-chunk HLO cost attribution failed "
                            "(%s: %s) — execute spans will carry no cost "
                            "args", type(e).__name__, e)
            return None

    def peaks(self) -> Dict[str, float]:
        if self._peaks is None:
            try:
                from benchmarks.roofline import device_peaks
                self._peaks = device_peaks()
            except Exception:
                self._peaks = dict(_FALLBACK_PEAKS)
        return self._peaks

    def annotate(self, cost: Dict, dur_s: float) -> Dict:
        """Achieved-vs-peak annotation for one executed chunk window."""
        pk = self.peaks()
        dur = max(float(dur_s), 1e-12)
        flops = float(cost.get("dot_flops", 0.0))
        byts = float(cost.get("bytes_written", 0.0))
        wire = float(cost.get("wire_bytes_per_device", 0.0))
        fracs = dict(
            frac_compute=flops / dur / pk["peak_flops"],
            frac_memory=byts / dur / pk["hbm_bw"],
            frac_link=wire / dur / pk["link_bw"])
        bound = max(fracs, key=fracs.get)
        return dict(
            flops=flops, bytes_written=byts, wire_bytes_per_device=wire,
            gflops_s=flops / dur / 1e9, gbytes_s=byts / dur / 1e9,
            bound=bound.replace("frac_", ""), **fracs)


# ---------------------------------------------------------------------------
# trace validation (the CI telemetry-smoke contract)
# ---------------------------------------------------------------------------
_VALID_PH = {"X", "i", "I", "C", "M", "B", "E"}


def _parse_trace(path: str) -> List[Dict]:
    with open(path) as f:
        raw = f.read()
    body = raw.strip()
    if body.startswith("["):
        body = body[1:]
    if body.rstrip().endswith("]"):
        body = body.rstrip()[:-1]
    events = []
    for i, line in enumerate(body.splitlines(), 1):
        line = line.strip().rstrip(",")
        if not line:
            continue
        try:
            events.append(json.loads(line))
        except ValueError as e:
            raise ValueError(f"{path}:{i}: invalid trace event JSON: {e}")
    return events


def validate_trace(path: str, require_stages: Sequence[str] = ()
                   ) -> Tuple[bool, str, Dict]:
    """Validate a trace file against the Chrome trace event schema:
    every event needs ``name``/``ph``/``ts``/``pid``/``tid`` with sane
    types, ``X`` events need a non-negative ``dur``, ``M`` events a
    ``args.name``.  ``require_stages`` additionally demands a complete
    span for each named pipeline stage.  Returns ``(ok, why, info)``."""
    try:
        events = _parse_trace(path)
    except (OSError, ValueError) as e:
        return False, str(e), dict(n_events=0, stages=[])
    if not events:
        return False, "empty trace", dict(n_events=0, stages=[])
    for i, ev in enumerate(events):
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            return False, f"event {i}: missing name", {}
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            return False, f"event {i} ({ev['name']}): bad ph {ph!r}", {}
        if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
            return False, f"event {i} ({ev['name']}): bad ts", {}
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                return False, f"event {i} ({ev['name']}): bad {k}", {}
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                return False, f"event {i} ({ev['name']}): X needs dur", {}
        if ph == "M" and not (ev.get("args") or {}).get("name"):
            return False, f"event {i}: M needs args.name", {}
    stages = sorted({ev["name"] for ev in events
                     if ev.get("ph") == "X"
                     and ev.get("cat") in ("pipeline", "ckpt")})
    missing = [s for s in require_stages if s not in stages]
    info = dict(n_events=len(events), stages=stages)
    if missing:
        return False, f"missing pipeline stages: {missing}", info
    return True, "ok", info


def stage_summary(path: str) -> List[Dict]:
    """Per-stage duration table from a trace file (count, total, mean,
    p50/p99 in ms) — the ``report.py --trace`` view."""
    events = _parse_trace(path)
    by: Dict[str, List[float]] = {}
    for ev in events:
        if ev.get("ph") == "X":
            by.setdefault(ev["name"], []).append(float(ev["dur"]))
    rows = []
    for name in sorted(by):
        durs = np.asarray(by[name], np.float64) / 1e3   # µs -> ms
        rows.append(dict(
            stage=name, count=int(durs.size),
            total_ms=float(durs.sum()), mean_ms=float(durs.mean()),
            p50_ms=float(np.percentile(durs, 50)),
            p99_ms=float(np.percentile(durs, 99))))
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def _main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        description="validate a Perfetto/Chrome trace emitted by the "
                    "service telemetry plane")
    p.add_argument("trace", help="trace JSONL path")
    p.add_argument("--require-stages", default="",
                   help="comma-separated span names that must be present")
    p.add_argument("--summary", action="store_true",
                   help="print the per-stage duration table")
    args = p.parse_args(argv)
    stages = [s for s in args.require_stages.split(",") if s]
    ok, why, info = validate_trace(args.trace, require_stages=stages)
    print(f"{args.trace}: {'OK' if ok else 'INVALID'} ({why}); "
          f"{info.get('n_events', 0)} events, "
          f"stages={info.get('stages', [])}")
    if ok and args.summary:
        for r in stage_summary(args.trace):
            print(f"  {r['stage']:<20} n={r['count']:>5} "
                  f"total={r['total_ms']:>10.2f}ms p50={r['p50_ms']:.3f}ms "
                  f"p99={r['p99_ms']:.3f}ms")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(_main())
