"""Straggler mitigation for the data plane (control-plane logic).

On a large fleet, per-host input pipelines stall (GCS tail latency, host
preemption).  The dispatcher tracks per-shard fetch deadlines and applies
bounded-staleness backfill: a shard that misses its deadline is served the
deterministic *backup batch* for that (step, shard) — a different sample
from the same distribution — so the SPMD step never blocks on one host.

The streaming service applies the same ``StragglerPolicy`` to its source
pulls (``runtime/service.py``, DESIGN.md §2.7): ``deadline_s`` classifies
a slow pull as a straggler, transient pull failures retry with bounded
backoff, and the combined backfill ratio (retries + deadline misses over
total pulls) trips the ``max_backfill_ratio`` alarm — counted in
``StreamService.stats["source"]`` and logged once per run.

Pure-python control logic with an injectable clock — unit-testable without
a fleet.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    deadline_s: float = 1.0        # per-shard fetch budget
    max_backfill_ratio: float = 0.2  # alarm threshold
    backup_seed_offset: int = 1_000_003


class ShardDispatcher:
    """Tracks shard fetch latencies; decides fetch vs backfill per shard."""

    def __init__(self, n_shards: int, policy: StragglerPolicy,
                 clock: Callable[[], float] = time.monotonic):
        self.n = n_shards
        self.policy = policy
        self.clock = clock
        self.backfilled: Dict[int, int] = {}   # step -> count
        self.latencies: list = []

    def dispatch(self, step: int, fetchers: Dict[int, Callable[[], object]],
                 backup: Callable[[int, int], object]):
        """fetchers: shard -> thunk (may be slow).  backup(step, shard) is
        the deterministic replacement.  Returns shard -> batch."""
        out = {}
        n_backfilled = 0
        for shard in range(self.n):
            t0 = self.clock()
            batch = None
            try:
                batch = fetchers[shard]()
            except TimeoutError:
                batch = None
            dt = self.clock() - t0
            self.latencies.append(dt)
            if batch is None or dt > self.policy.deadline_s:
                batch = backup(step, shard)
                n_backfilled += 1
            out[shard] = batch
        self.backfilled[step] = n_backfilled
        return out

    @property
    def backfill_alarm(self) -> bool:
        total = sum(self.backfilled.values())
        steps = max(len(self.backfilled), 1)
        return total / (steps * self.n) > self.policy.max_backfill_ratio
