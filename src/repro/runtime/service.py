"""Continuous streaming service runtime (DESIGN.md §2.6, failure model §2.7).

``StreamService`` turns the batch-replay drivers into a steady-state
pipeline over an unbounded arrival source:

    arrivals -> admission (bounded queue) -> IntervalAssembler (watermark)
             -> ready intervals -> chunked fused scan (K intervals per
             dispatch, state carry donated chunk-to-chunk)
             -> commit (post-process + D2H) -> outputs + latency record

* **Double-buffered device feed**: chunks are dispatched and committed in
  order on a dedicated executor thread while the main thread pulls,
  assembles and stages (H2D) the next chunk — XLA releases the GIL
  during execution, so interval *i+1*'s transfer and compute-mode
  pre-processing overlap interval *i*'s state-access scan on every
  backend (``run_stream_chunk`` itself returns unmaterialized device
  arrays; the executor blocks on chunk *i*'s outputs only after chunk
  *i+1* is in flight).
* **Chunked == monolithic**: chunk boundaries are punctuation boundaries
  and the carry is the donated state buffer, so K-chunked execution is
  bit-identical to one ``run_stream`` over the same events, on both the
  single-device and sharded drivers (pinned in tests/test_service.py and
  tests/service_worker.py).
* **Backpressure / admission control**: the ready queue is bounded
  (``queue_intervals``); when the source outruns the engine the service
  either stops pulling (``admission="block"``) or drops whole arrival
  batches with accounting (``admission="drop"``).
* **Punctuation-aligned recovery**: every ``snapshot_every`` intervals
  the service drains the pipeline and writes the state buffer through
  ``ckpt/`` (the checkpoint step number IS the punctuation index).
  Recovery restores the newest snapshot that *verifies* — a torn or
  corrupted latest falls back to the previous valid one — and replays
  the deterministic source, discarding the first ``intervals_done``
  re-assembled intervals: the resumed run is bitwise identical to an
  uninterrupted one.

Hardened failure path (DESIGN.md §2.7):

* **Source retry/backoff**: transient pull failures
  (``faults.TransientSourceError`` / ``TimeoutError``) retry up to
  ``source_retries`` times with exponential backoff; pulls slower than
  the ``StragglerPolicy`` deadline count as deadline misses, and the
  combined backfill ratio trips the policy's alarm (logged once,
  recorded in ``stats["source"]``).
* **Executor watchdog**: with ``watchdog_factor`` set, a monitor thread
  declares the executor hung when no progress lands within
  ``watchdog_factor ×`` the median recent chunk latency (never below
  ``watchdog_min_s``; ``watchdog_grace_s`` covers every possibly
  compiling chunk — the first, and the first at any new
  (plan variant, slack, chunk-size) shape).  On fire it aborts the
  executor, drains every
  committable in-flight chunk, writes an *emergency* punctuation-aligned
  snapshot when the carry is safe, and surfaces a structured
  ``ExecutorHungError`` with the merged stats intact.
* **Fault injection**: ``run(..., faults=FaultPlane(...))`` consults the
  deterministic fault plane (``runtime/faults.py``) at each named site.

Adaptive control plane (DESIGN.md §2.9, ``runtime/controller.py``):

* With ``ServiceConfig.controller`` set, a deterministic feedback
  controller runs on the main thread at every chunk boundary: it reads
  the per-chunk record window (see below), moves the live plan inside a
  small legal lattice (scheme degradation, exchange slack, chunk size K,
  restructure rung), and the chunk is submitted *carrying* its plan — the
  executor rebinds the pre-jitted variant / slack at the dispatch that
  first observes a new plan.  Every switch appends to a monotone decision
  trace; punctuation-aligned snapshots publish the trace (+ the record
  window tail) in their manifest and ``resume`` folds it back, so
  crash → restore → replay of an adaptive run is bitwise identical to the
  uninterrupted adaptive run.
* ``escalate_overflow`` is now sugar for an implicit slack-only
  controller (PR 5's one-way escalation hack, subsumed): a sharded chunk
  that dropped ops triggers a logged ``exchange_slack`` widening at a
  later boundary, up to ``escalate_overflow`` times — and because the
  escalation is a traced decision, it composes with snapshots instead of
  being statically forbidden.
* **Per-chunk time series**: the service keeps a ring buffer of the last
  ``chunk_record_ring`` chunk records (latency, failed ops, chain stats,
  exchange drop/fill, queue fill) — the controller's observation window,
  exposed as ``stats["chunks"]``.

Elastic resharding (DESIGN.md §2.10):

* On the sharded driver every chunk record also carries the per-shard
  access histogram (``x_shard``) and the chunk's hottest slots
  (``hot``).  With ``ControllerConfig.reshard_imbalance`` set, sustained
  imbalance emits a ``reshard`` decision — a skew-aware ownership
  permutation computed by greedy bin-packing over the observed load —
  and the dispatch that first observes the new plan applies it as a
  *live migration*: drain the pipe at the punctuation boundary, ship
  only the rows whose owner changed through the owner-routed
  ``all_to_all``, rebind the pre-jitted plan, resume.  Migrations are
  traced decisions and snapshots store canonical uid-order values, so
  crash → restore → replay across a migration stays bitwise identical;
  the run's placement ledger lands in ``stats["placement"]``.

``StreamService.stats`` is the one merged accounting record: watermark
drops, admission drops, sharded exchange overflow, the assembler ledger,
source retry/backfill counters, fired faults, the chunk-record ring, the
controller trace and any structured error land in a single dict; each
category is logged at most once per run.

Unified telemetry plane (DESIGN.md §2.11, ``runtime/telemetry.py``):

* Every run owns a ``Telemetry`` registry (``run.telemetry``); ``stats``
  is now a *view* rendered from the registry's versioned snapshot, and
  the once-per-run log lines are rate-limited structured events (same
  messages, same logger, no hand-rolled flags).
* With ``ServiceConfig.telemetry.trace_path`` set, every pipeline stage
  (source pull → assembly → admission → dispatch → execute → commit →
  ``controller.decide`` → snapshot publish → ``reshard.apply``) emits a
  Chrome-trace/Perfetto span; ``profile_dir`` adds per-chunk
  ``jax.profiler`` windows and ``hlo_attribution`` attaches compiled-HLO
  flops/bytes + roofline fractions to execute spans.
* Replay-safety contract: telemetry never feeds ``decide()`` — a
  tracing-enabled run is bitwise identical to a tracing-off run,
  including crash → restore → replay (tests/test_telemetry.py).  The
  only timing→control bridge is the *advisory* channel: when snapshots
  force ``allow_timing`` off, a shadow controller still evaluates the
  timing tier and its would-be decisions are logged + recorded under
  ``stats["controller"]["advisory"]`` — never applied.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import queue
import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import (checkpoint_steps, load_checkpoint, prune_checkpoints,
                        read_manifest_meta, save_checkpoint,
                        verify_checkpoint)
from repro.core.intervals import IntervalAssembler, WatermarkPolicy

from .controller import (AdvisoryTiming, ControllerConfig, Plan,
                         PlanController, replay_plan)
from .faults import FaultPlane, TransientSourceError
from .telemetry import (ChunkProfiler, CostAttributor, Telemetry,
                        TelemetryConfig, empty_stats, make_tracer,
                        stats_view)

log = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    """"A shard is slow" has exactly one owner: this policy classifies a
    slow *source pull* (``deadline_s``; misses + retries trip the
    ``max_backfill_ratio`` alarm in ``stats["source"]``), and a slow
    *device shard* — sustained load imbalance — is the controller's
    ``reshard`` knob reading the same per-chunk records
    (``runtime/controller.py``, DESIGN.md §2.10).  The old standalone
    ``runtime/straggler.py`` dispatcher duplicated the deadline half of
    this split and is gone."""

    deadline_s: float = 1.0          # per-pull fetch budget
    max_backfill_ratio: float = 0.2  # alarm threshold
    backup_seed_offset: int = 1_000_003


class ExecutorHungError(RuntimeError):
    """Watchdog verdict: the executor made no progress within its budget.

    ``info`` is the structured record (idle/timeout seconds, committed
    intervals, in-flight chunks, emergency snapshot step if one was
    written) — also merged into ``stats["error"]``.
    """

    def __init__(self, msg: str, info: Optional[Dict] = None):
        super().__init__(msg)
        self.info = dict(info or {})


class _Aborted(Exception):
    """Internal: the run was already declared failed; stop silently."""


def ts_base_for(global_interval: int, interval: int) -> int:
    """int32-safe timestamp base for an unbounded run.

    Engine timestamps are only meaningful *within* one punctuation
    interval's restructure sort (nothing persists them across intervals),
    so the base wraps at an interval-aligned boundary below 2**30 —
    within any chunk the bases stay monotone and the per-op ``ts_base +
    arange(interval)`` stays well inside int32 forever.  Below the wrap
    (~2**30 events) this equals ``global_interval * interval`` exactly,
    which is what the chunked-vs-monolithic bit-identity tests compare.
    """
    wrap = max(1, 2 ** 30 // interval)
    return (global_interval % wrap) * interval


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    punct_interval: int
    chunk_intervals: int = 4        # K — scan window per device dispatch
    queue_intervals: int = 16       # ready-queue bound (admission control)
    admission: str = "block"        # "block" (backpressure) | "drop"
    watermark: WatermarkPolicy = WatermarkPolicy()
    snapshot_every: int = 0         # intervals between snapshots; 0 = off
    ckpt_dir: Optional[str] = None
    keep_last: int = 0              # snapshot retention; 0 = keep all
    # -- hardened failure path (DESIGN.md §2.7) ------------------------
    straggler: StragglerPolicy = StragglerPolicy()
    source_retries: int = 2         # bounded retry on transient pull errors
    retry_backoff_s: float = 0.05   # exponential backoff base
    watchdog_factor: float = 0.0    # × median recent chunk latency; 0 = off
    watchdog_min_s: float = 5.0     # timeout floor once latencies exist
    watchdog_grace_s: float = 120.0  # before the first commit (covers jit)
    escalate_overflow: int = 0      # max automatic slack escalations; 0 = off
    escalate_factor: float = 2.0
    # -- adaptive control plane (DESIGN.md §2.9) -----------------------
    controller: Optional[ControllerConfig] = None
    chunk_record_ring: int = 32     # per-chunk time series depth
    # -- observability plane (DESIGN.md §2.11); None = metrics only ----
    telemetry: Optional[TelemetryConfig] = None

    def __post_init__(self):
        assert self.punct_interval > 0
        assert self.chunk_intervals > 0
        assert self.admission in ("block", "drop"), self.admission
        assert self.queue_intervals >= self.chunk_intervals, \
            "queue_intervals must cover at least one chunk"
        assert self.keep_last >= 0
        assert self.source_retries >= 0 and self.retry_backoff_s >= 0
        assert self.watchdog_factor >= 0
        if self.watchdog_factor:
            assert self.watchdog_min_s > 0 and self.watchdog_grace_s > 0
        assert self.escalate_overflow >= 0
        if self.escalate_overflow:
            assert self.escalate_factor > 1.0
            # NOTE: escalation + snapshots used to be statically excluded
            # (a mid-run capacity change was not replayable).  Escalations
            # are now controller decisions recorded in the snapshot's
            # decision trace and folded back by ``resume``, so the modes
            # compose (DESIGN.md §2.9).
        assert self.chunk_record_ring >= 1
        if self.controller is not None:
            c = self.controller
            assert c.window >= 1 and 1 <= c.sustain <= c.window, \
                "controller needs 1 <= sustain <= window"
            assert c.cooldown >= 1, "controller cooldown must be >= 1"
        if self.snapshot_every:
            assert self.snapshot_every % self.chunk_intervals == 0, \
                ("snapshots are taken at chunk boundaries: snapshot_every "
                 "must be a multiple of chunk_intervals")
            assert self.ckpt_dir, "snapshot_every needs a ckpt_dir"
            # admission drops depend on ready-queue occupancy, and replay
            # (skip_intervals) bypasses the queue for the skipped prefix —
            # a dropping queue is therefore not replayable and would break
            # the crash -> restore -> replay bit-identity guarantee
            assert self.admission == "block", \
                "snapshot/recovery requires admission='block'"


@dataclasses.dataclass
class ServiceRun:
    """Mutable record of one service run (kept on ``service.last_run`` so
    a crashed run's committed prefix stays inspectable)."""

    outputs: List = dataclasses.field(default_factory=list)   # per interval
    commits: List[Dict] = dataclasses.field(default_factory=list)
    latencies: List[np.ndarray] = dataclasses.field(default_factory=list)
    snapshots: List[int] = dataclasses.field(default_factory=list)
    # adaptive control plane: live alias of the controller's decision
    # trace (monotone in g; includes any restored prefix) and the final
    # chunk-record ring (per-chunk time series, newest last)
    decisions: List[Dict] = dataclasses.field(default_factory=list)
    chunk_records: List[Dict] = dataclasses.field(default_factory=list)
    # elastic resharding: one dict per applied live migration (boundary
    # interval, rows moved, override count) and the per-shard observed
    # event totals behind stats["placement"]
    migrations: List[Dict] = dataclasses.field(default_factory=list)
    shard_events: Optional[np.ndarray] = None
    admission_dropped: int = 0
    replayed_intervals: int = 0
    exchange_dropped: int = 0
    exchange_shipped: int = 0
    exchange_capacity: int = 0
    t_first_enqueue: Optional[float] = None
    t_last_commit: Optional[float] = None
    final_values: Optional[np.ndarray] = None
    stats: Optional[Dict] = None
    # the run's telemetry registry (DESIGN.md §2.11): counters, gauges,
    # histograms and record logs behind the versioned schema; ``stats``
    # is rendered from its snapshot by _finish
    telemetry: Optional[Telemetry] = None

    def latency_s(self) -> np.ndarray:
        """Per-event end-to-end latency (enqueue -> interval commit)."""
        if not self.latencies:
            return np.zeros((0,), np.float64)
        return np.concatenate(self.latencies)

    def latency_percentiles(self, qs=(50, 99)) -> Dict[str, float]:
        lat = self.latency_s()
        if lat.size == 0:
            return {f"p{q}": float("nan") for q in qs}
        return {f"p{q}": float(np.percentile(lat, q)) for q in qs}

    def sustained_events_per_s(self) -> float:
        n = sum(len(l) for l in self.latencies)
        if not n or self.t_first_enqueue is None \
                or self.t_last_commit is None:
            return 0.0
        span = self.t_last_commit - self.t_first_enqueue
        return n / span if span > 0 else 0.0


class StreamService:
    """Long-running punctuation pipeline over a ``DualModeEngine``."""

    def __init__(self, engine, cfg: ServiceConfig):
        self.engine = engine
        self.cfg = cfg
        if engine._sharded is not None:
            assert cfg.punct_interval % engine._sharded.n_dev == 0, \
                (f"punct_interval={cfg.punct_interval} must divide evenly "
                 f"across {engine._sharded.n_dev} devices")
        self.last_run: Optional[ServiceRun] = None

    # ------------------------------------------------------------------
    def run(self, source, values=None, *, skip_intervals: int = 0,
            max_intervals: Optional[int] = None,
            crash_after_interval: Optional[int] = None,
            faults: Optional[FaultPlane] = None,
            controller_state: Optional[Dict] = None) -> ServiceRun:
        """Drive the service until the source drains (or ``max_intervals``).

        ``skip_intervals`` is the recovery path: the first N re-assembled
        intervals are discarded without execution (the snapshot already
        contains their effects) and execution resumes at global interval
        index N with the restored state — assembly is deterministic, so
        the continuation is bitwise identical to the uninterrupted run.
        ``crash_after_interval`` injects a failure once the interval with
        that global index has committed (tests/CI restart drill);
        ``faults`` is the general, scheduled fault plane
        (``runtime/faults.py``).  ``controller_state`` is the adaptive
        recovery path (normally supplied by :meth:`resume` from the
        snapshot manifest): the decision trace is folded back into the
        plan and the stored record window seeds the controller's
        observations, so post-restore decisions recompute exactly as the
        uninterrupted run made them.
        """
        cfg, eng = self.cfg, self.engine
        if skip_intervals and cfg.admission != "block":
            raise ValueError(
                "replay (skip_intervals) requires admission='block': a "
                "dropping queue makes the arrival->interval mapping depend "
                "on commit progress, which replay does not reproduce")
        interval, K = cfg.punct_interval, cfg.chunk_intervals
        asm = IntervalAssembler(interval, cfg.watermark)
        ready = collections.deque()
        in_flight = collections.deque()
        rec = ServiceRun()
        self.last_run = rec
        # -- observability plane (DESIGN.md §2.11) ---------------------
        # The registry is always on (no clocks of its own); the tracer,
        # profiler and cost attributor are opt-in and provably off the
        # replay path: with all three enabled the run stays bitwise
        # identical to a bare one.
        tcfg = cfg.telemetry
        tele = Telemetry(record_cap=tcfg.record_cap if tcfg else 4096)
        rec.telemetry = tele
        tracer = make_tracer(tcfg, tele)
        profiler = ChunkProfiler(tcfg.profile_dir if tcfg else "")
        cost_attr = None
        if tcfg is not None and tcfg.hlo_attribution:
            cost_attr = CostAttributor(
                n_devices=(eng._sharded.n_dev
                           if eng._sharded is not None else 1))
        costs: Dict = {}     # shape key -> analyze_hlo dict (or None)
        init = eng.init_store.values if values is None else values
        src = iter(source)
        state = dict(exhausted=False, to_skip=int(skip_intervals), err=None)
        g_next = int(skip_intervals)    # global index of next interval
        executed = 0                    # intervals submitted this run
        srcst = dict(pulls=0, retries=0, deadline_misses=0, backoff_s=0.0)
        vals_ok = dict(safe=True)       # carry readable (not mid-donation)

        # -- adaptive control plane (DESIGN.md §2.9) -----------------------
        ctl = self._make_controller(controller_state)
        # advisory timing channel (DESIGN.md §2.11): the user asked for
        # the timing tier but snapshots forced it off — shadow-evaluate
        # it anyway; hints are logged/recorded, never applied
        advisory = None
        if (ctl is not None and cfg.controller is not None
                and cfg.snapshot_every and cfg.controller.allow_timing):
            advisory = AdvisoryTiming(ctl)
        # the engine carry: canonical uid-order values enter the engine's
        # native carry layout (ownership blocks on the sharded driver, the
        # plain buffer on one device).  _make_controller already rebound
        # any restored ownership, so the blocks are built on the layout
        # the replayed trace folds to.
        vals = eng.carry_in(jnp.array(init, copy=True))
        if ctl is not None:
            rec.decisions = ctl.trace       # live alias (monotone trace)
        # per-chunk record ring: the controller's observation window and
        # the stats["chunks"] time series.  Records are appended by the
        # commit path (executor thread / post-hang drain) and read by the
        # main thread's decision step under ``rec_cv``.
        ring = cfg.chunk_record_ring
        if ctl is not None:
            ring = max(ring, ctl.cfg.window + 4)
        hist: collections.deque = collections.deque(maxlen=ring)
        rec_cv = threading.Condition()
        chunks_done0 = int((controller_state or {}).get("chunks_done", 0))
        # n: committed-chunk count (== next record's global index);
        # last_i: newest committed record; j: chunks submitted this run
        chn = dict(n=chunks_done0, last_i=chunks_done0 - 1, j=0)
        for r in (controller_state or {}).get("records", ()):
            hist.append(dict(r))
        # the plan the engine is actually bound to (slack applied at
        # restore by _make_controller; scheme/rung rebind lazily at the
        # first dispatch that observes a different plan)
        # slack AND ownership are already live at restore (applied by
        # _make_controller), so the first dispatch must not re-apply them
        applied = dict(plan=None if ctl is None else dataclasses.replace(
            ctl.init_plan, slack=ctl.plan.slack, owners=ctl.plan.owners))
        # watchdog progress record: ``busy`` is True only while the
        # executor is actively processing (dispatch/commit/drain), ``t``
        # is bumped at every step forward, ``lat`` holds recent
        # commit-to-commit chunk latencies
        progress = dict(busy=False, t=time.monotonic(), last_commit=None,
                        lat=collections.deque(maxlen=8))
        # staged chunks queued for the executor thread; maxsize=1 plus the
        # executor's depth-2 in_flight window bounds the pipeline
        work_q: queue.Queue = queue.Queue(maxsize=1)

        def drain_asm():
            for ev_iv, info in asm.pop_ready():
                if state["to_skip"] > 0:
                    state["to_skip"] -= 1
                    rec.replayed_intervals += 1
                else:
                    ready.append((ev_iv, info))

        def guarded_pull():
            """One source pull under the straggler policy: transient
            failures retry with exponential backoff (bounded by
            ``source_retries``), slow pulls count as deadline misses."""
            attempt = 0
            with tracer.span("source.pull") as sp:
                while True:
                    t0 = time.monotonic()
                    try:
                        if faults is not None:
                            faults.on_source_pull()
                        item = next(src)
                    except StopIteration:
                        raise
                    except (TransientSourceError, TimeoutError):
                        srcst["retries"] += 1
                        if attempt >= cfg.source_retries:
                            raise
                        delay = cfg.retry_backoff_s * (2.0 ** attempt)
                        srcst["backoff_s"] += delay
                        attempt += 1
                        time.sleep(delay)
                        continue
                    srcst["pulls"] += 1
                    if time.monotonic() - t0 > cfg.straggler.deadline_s:
                        srcst["deadline_misses"] += 1
                    if attempt:
                        sp.set(retries=attempt)
                    return item

        def pull_one() -> bool:
            """Admit one arrival batch; False = backpressure (queue full)."""
            if state["exhausted"] or state["err"] is not None:
                return False
            if len(ready) >= cfg.queue_intervals and cfg.admission == "block":
                return False
            with tracer.span("admission", qfill=len(ready)) as adm:
                try:
                    ev, t = guarded_pull()
                except StopIteration:
                    state["exhausted"] = True
                    asm.close()
                    adm.set(outcome="exhausted")
                else:
                    if len(ready) >= cfg.queue_intervals:  # admission=="drop"
                        n_drop = int(np.asarray(t).shape[0])
                        rec.admission_dropped += n_drop
                        adm.set(outcome="dropped", events=n_drop)
                    else:
                        now = time.perf_counter()
                        if rec.t_first_enqueue is None:
                            rec.t_first_enqueue = now
                        asm.push(ev, t, enqueue_s=now)
                        adm.set(outcome="admitted")
            with tracer.span("assembly") as asp:
                before = len(ready)
                drain_asm()
                asp.set(intervals=len(ready) - before)
            return True

        def commit_oldest(check_crash: bool = True):
            (g0, kk, res, ebs, infos, xst, item_plan, qfill,
             t_disp, cost) = in_flight.popleft()
            commit_span = tracer.span("chunk.commit", g0=g0, k=kk)
            commit_span.__enter__()
            outs = eng.post_outputs(res, ebs, kk)
            t_commit = time.perf_counter()
            rec.t_last_commit = t_commit
            now = time.monotonic()
            # the device-execute span: the dispatch->commit wall window,
            # reconstructed from stamps the accounting already takes (no
            # extra clock reads on the replay path); cost attribution
            # and roofline fractions ride on its args
            if tracer.enabled:
                xargs = dict(g0=g0, k=kk)
                if cost is not None and cost_attr is not None:
                    xargs.update(cost_attr.annotate(cost, now - t_disp))
                tracer.complete_at("chunk.execute", t_disp, now, **xargs)
            if progress["last_commit"] is not None:
                progress["lat"].append(now - progress["last_commit"])
            progress["last_commit"] = now
            progress["t"] = now
            # -- per-chunk record (the controller's observation unit) ----
            entry = dict(
                i=chn["n"], g0=g0, k=kk, events=kk * interval,
                lat_s=float(now - t_disp), qfill=int(qfill),
                scheme=(item_plan.scheme if item_plan is not None
                        else eng.cfg.scheme),
                fail=0, ops=0, max_chain=0, n_chains=0, rounds=0,
                x_drop=0, x_ship=0, x_fill=0, x_cap=0)
            suc = np.asarray(jax.device_get(res["success"]))
            entry["ops"] = int(suc.size)
            entry["fail"] = int(suc.size - np.sum(suc))
            st_d = xst or {}
            est = st_d.get("engine")
            if est is not None:
                es = jax.device_get(est)
                entry["max_chain"] = int(np.max(es.max_chain))
                entry["n_chains"] = int(np.min(es.n_chains))
                entry["rounds"] = int(np.max(es.rounds))
            xs = st_d.get("exchange")
            if xs is not None:
                st = jax.device_get(xs)
                dropped_now = int(np.sum(st["dropped"]))
                rec.exchange_dropped += dropped_now
                rec.exchange_shipped += int(np.sum(st["shipped"]))
                rec.exchange_capacity = int(st["capacity"])
                entry["x_drop"] = dropped_now
                entry["x_ship"] = int(np.sum(st["shipped"]))
                entry["x_fill"] = (int(np.max(st["max_fill"]))
                                   if np.size(st["max_fill"]) else 0)
                entry["x_cap"] = int(st["capacity"])
                sl = st.get("shard_load")
                if sl is not None:
                    # per-shard access histogram (state rows touched on
                    # each ownership shard this chunk) — the controller's
                    # skew signal, and the top hot slots its placement
                    # input.  The stable argsort makes the hot list (and
                    # therefore every reshard decision derived from it)
                    # replay-exact.
                    shard = np.asarray(sl, np.int64)
                    entry["x_shard"] = [int(v) for v in shard]
                    slot = np.asarray(st["slot_load"], np.int64)
                    top = np.argsort(-slot, kind="stable")[:32]
                    entry["hot"] = [[int(u), int(slot[u])]
                                    for u in top if slot[u] > 0]
                    if rec.shard_events is None:
                        rec.shard_events = np.zeros(shard.size, np.int64)
                    rec.shard_events = rec.shard_events + shard
            with rec_cv:
                hist.append(entry)
                chn["last_i"] = entry["i"]
                chn["n"] += 1
                rec_cv.notify_all()
            for i in range(kk):
                info = infos[i]
                rec.outputs.append(outs[i])
                rec.latencies.append(t_commit - info.enqueue_s)
                rec.commits.append(dict(
                    interval=g0 + i, commit_s=t_commit,
                    watermark=int(info.watermark), n_late=int(info.n_late)))
            commit_span.__exit__(None, None, None)
            if check_crash and crash_after_interval is not None \
                    and g0 + kk - 1 >= crash_after_interval:
                raise RuntimeError(
                    f"injected failure after interval {g0 + kk - 1}")

        def take_snapshot(step: int, emergency: bool = False):
            snap_span = tracer.span("snapshot.publish", step=step,
                                    emergency=emergency)
            snap_span.__enter__()
            # the carry leaves in canonical uid order (carry_out inverts
            # the ownership-block layout), so a snapshot restores onto ANY
            # placement — in particular onto the migrated layout the
            # replayed decision trace folds to
            host_vals = np.asarray(jax.device_get(eng.carry_out(vals)))
            extra = dict(intervals_done=step, punct_interval=interval,
                         emergency=emergency)
            if eng._sharded is not None:
                # the ownership the engine is bound to at this boundary ==
                # replay_plan(init_plan, trace g < step).owners; recorded
                # so operators (and tests) can see the layout a snapshot
                # was cut on without replaying the trace
                extra["ownership"] = dict(
                    n_owners=int(eng._sharded.n_dev),
                    overrides=[[int(u), int(o)] for (u, o) in eng.owners])
            if ctl is not None:
                # decisions AT the boundary (g == step) race with this
                # write on the main thread, so the manifest records the
                # strict prefix g < step; the first post-restore decision
                # recomputes from the stored record tail — same window,
                # same decision (DESIGN.md §2.9 replay contract)
                trace = [dict(d) for d in list(ctl.trace)
                         if d["g"] < step]
                extra["controller"] = dict(
                    init_plan=ctl.init_plan.as_dict(),
                    plan=replay_plan(ctl.init_plan, trace).as_dict(),
                    trace=trace,
                    records=[dict(r) for r in
                             list(hist)[-(ctl.cfg.window + 1):]],
                    chunks_done=chn["n"])
            path = save_checkpoint(
                cfg.ckpt_dir, step, dict(values=host_vals),
                extra_meta=extra,
                tracer=(tracer if tracer.enabled else None))
            if faults is not None and not emergency:
                faults.on_snapshot_publish(path)
            if cfg.keep_last:
                prune_checkpoints(cfg.ckpt_dir, cfg.keep_last)
            rec.snapshots.append(step)
            snap_span.__exit__(None, None, None)

        seen_shapes = set()     # (variant-key, chunk size) already compiled

        def dispatch(batched, kk: int, infos, plan, qfill):
            nonlocal vals, g_next
            if state["err"] is not None:
                raise _Aborted()
            variant = None
            if plan is not None:
                prev = applied["plan"]
                if eng._sharded is not None and plan.slack != prev.slack:
                    # graceful degradation, now a replayed decision: widen
                    # the exchange at the boundary the trace recorded
                    # (recompiles the sharded program; shipped results
                    # are unaffected)
                    eng._sharded.set_exchange_slack(plan.slack)
                    tele.event(
                        "controller.slack_widen",
                        "controller: exchange slack %.2f -> %.2f at "
                        "punctuation boundary %d",
                        prev.slack, plan.slack, g_next,
                        logger=log, limit=-1)
                if eng._sharded is not None and plan.owners != prev.owners:
                    # live migration (DESIGN.md §2.10): drain the pipe so
                    # the carry is exactly this punctuation boundary's
                    # state, ship only the rows whose owner changed
                    # through the owner-routed all_to_all, rebind the
                    # pre-jitted plan to the new ownership and resume —
                    # the stream never stops
                    while in_flight:
                        commit_oldest()
                    vals_ok["safe"] = False
                    t0m = time.monotonic()
                    with tracer.span("reshard.apply", g=g_next,
                                     overrides=len(plan.owners)) as rsp:
                        vals, moved = eng.apply_resharding(vals, plan.owners)
                        rsp.set(moved=int(moved))
                    vals_ok["safe"] = True
                    progress["t"] = time.monotonic()
                    rec.migrations.append(dict(
                        g=g_next, moved=int(moved),
                        overrides=len(plan.owners),
                        apply_s=float(time.monotonic() - t0m)))
                    tele.event(
                        "controller.migration",
                        "controller: live migration at punctuation "
                        "boundary %d (%d rows moved, %d overrides)",
                        g_next, int(moved), len(plan.owners),
                        logger=log, limit=-1)
                    if faults is not None:
                        faults.on_reshard_apply()
                if eng._sharded is None:
                    variant = eng.ensure_variant(
                        scheme=plan.scheme, restructure_method=plan.rung)
                    if (plan.scheme, plan.rung) != (prev.scheme, prev.rung):
                        tele.event(
                            "controller.variant_switch",
                            "controller: plan variant %s/%s -> %s/%s at "
                            "punctuation boundary %d",
                            prev.scheme, prev.rung, plan.scheme, plan.rung,
                            g_next, logger=log, limit=-1)
                applied["plan"] = plan
            shape = (variant, None if plan is None else plan.slack,
                     None if plan is None else plan.owners, kk)
            if shape not in seen_shapes:
                # first dispatch of this (variant, slack, K) compiles a
                # new program: drop the warm-chunk latency window so the
                # watchdog judges it against ``watchdog_grace_s``, not
                # the warm median — same reason grace covers chunk 0
                seen_shapes.add(shape)
                progress["lat"].clear()
            if cost_attr is not None and shape not in costs:
                # opt-in per-chunk-shape attribution: shapes/dtypes are
                # read BEFORE the donating call; the AOT compile is the
                # documented one-time cost per shape (DESIGN.md §2.11)
                costs[shape] = cost_attr.chunk_cost(
                    eng, vals, batched, variant=variant)
            vals_ok["safe"] = False     # the carry is being donated
            t_disp = time.monotonic()
            with tracer.span("chunk.dispatch", g0=g_next, k=kk):
                with profiler.chunk(g_next):
                    res, ebs, new_vals, xst = eng.run_stream_chunk(
                        vals, batched, ts_base_for(g_next, interval),
                        variant=variant)
            vals = new_vals
            vals_ok["safe"] = True
            progress["t"] = time.monotonic()
            in_flight.append((g_next, kk, res, ebs, infos, xst, plan,
                              qfill, t_disp, costs.get(shape)))
            g_next += kk
            if faults is not None:
                faults.on_executor_chunk()
            # double buffer depth 2: block on the oldest chunk only once a
            # newer one is in flight (its assembly/H2D already overlapped)
            while len(in_flight) > 1:
                commit_oldest()
            if cfg.snapshot_every and g_next % cfg.snapshot_every == 0:
                # punctuation-aligned snapshot: drain the pipe so the carry
                # is this boundary's state, then publish through ckpt/
                while in_flight:
                    commit_oldest()
                if state["err"] is not None:    # abandoned run: never write
                    raise _Aborted()
                take_snapshot(g_next)

        def executor():
            """Chunk executor thread: dispatch/commit strictly in order so
            the donated state carry chains exactly as the monolithic scan's
            would.  Running it off the main thread is what makes the feed
            double-buffered on every backend: XLA releases the GIL during
            execution, so the main thread assembles and stages chunk i+1
            while chunk i computes.  The loop re-checks ``state['err']``
            between items so a watchdog verdict stops it promptly."""
            try:
                while state["err"] is None:
                    try:
                        item = work_q.get(timeout=0.05)
                    except queue.Empty:
                        continue
                    if item is None:
                        break
                    progress["busy"] = True
                    progress["t"] = time.monotonic()
                    try:
                        dispatch(*item)
                    finally:
                        progress["busy"] = False
                if state["err"] is None:
                    progress["busy"] = True
                    progress["t"] = time.monotonic()
                    try:
                        while in_flight:
                            commit_oldest()
                    finally:
                        progress["busy"] = False
            except _Aborted:
                pass
            except BaseException as e:
                if state["err"] is None:
                    state["err"] = e
                try:                    # unblock the producer
                    while True:
                        work_q.get_nowait()
                except queue.Empty:
                    pass

        def watchdog():
            """Fires when the busy executor lands no progress within
            ``watchdog_factor`` × the median recent chunk latency
            (``watchdog_grace_s`` before the first commit)."""
            while not wd_stop.wait(0.02):
                if not progress["busy"] or state["err"] is not None:
                    continue
                if progress["lat"]:
                    timeout = max(cfg.watchdog_min_s, cfg.watchdog_factor
                                  * float(np.median(progress["lat"])))
                else:
                    timeout = cfg.watchdog_grace_s
                idle = time.monotonic() - progress["t"]
                if idle > timeout:
                    state["err"] = ExecutorHungError(
                        f"executor made no progress for {idle:.2f}s "
                        f"(timeout {timeout:.2f}s)",
                        info=dict(idle_s=idle, timeout_s=timeout,
                                  committed_intervals=len(rec.outputs),
                                  in_flight_chunks=len(in_flight)))
                    if faults is not None:
                        faults.abort()  # wake any injected stall/hang
                    return

        worker = threading.Thread(target=executor, daemon=True,
                                  name="stream-service-executor")
        worker.start()
        wd_stop = threading.Event()
        wd_thread = None
        if cfg.watchdog_factor:
            wd_thread = threading.Thread(target=watchdog, daemon=True,
                                         name="stream-service-watchdog")
            wd_thread.start()

        def submit(kk: int, plan):
            nonlocal executed
            g0 = int(skip_intervals) + executed
            qfill = len(ready)      # deterministic backlog signal
            chunk = [ready.popleft() for _ in range(kk)]
            # count at pop time: a chunk stranded by a crash (in work_q,
            # in_flight, or aborted here) is executed-but-uncommitted and
            # must land in the stats as unprocessed, not vanish
            executed += kk
            chn["j"] += 1
            with tracer.span("chunk.submit", g0=g0, k=kk, qfill=qfill):
                batched = {k: jnp.asarray(np.stack([c[0][k] for c in chunk]))
                           for k in chunk[0][0]}
            item = (batched, kk, [c[1] for c in chunk], plan, qfill)
            while state["err"] is None:
                try:
                    work_q.put(item, timeout=0.05)
                    return
                except queue.Full:
                    continue

        def wait_records(need_i: int) -> bool:
            """Block until the record of global chunk ``need_i`` exists.

            The decision for the j-th submitted chunk reads records of
            chunks committed strictly before submission j-1 — the newest
            record whose presence does not depend on the commit/decide
            race, so the window is identical on replay.  No deadlock: the
            needed commit happens inside the executor's dispatch of the
            previous chunk, which never waits on the main thread.
            """
            if chn["last_i"] >= need_i:
                return True
            with rec_cv:
                while chn["last_i"] < need_i and state["err"] is None:
                    rec_cv.wait(0.05)
            return state["err"] is None and chn["last_i"] >= need_i

        profiler.start()
        try:
            while state["err"] is None:
                # admission: a "drop" source never waits — one arrival
                # batch is admitted (or dropped at the full queue) per
                # dispatch cycle, modelling an arrival rate the service
                # cannot defer; a "block" source is backpressured: pulled
                # only while the next chunk is still short.
                if cfg.admission == "drop" and not state["exhausted"]:
                    pull_one()
                if ctl is not None:
                    K = ctl.plan.chunk
                while not state["exhausted"] and len(ready) < K:
                    if not pull_one():
                        break
                room = (K if max_intervals is None
                        else max(0, int(max_intervals) - executed))
                if min(K, len(ready), room) == 0:
                    break
                if ctl is not None:
                    # decide BEFORE building the submission, at the
                    # boundary of the chunk about to submit
                    gj = chunks_done0 + chn["j"]
                    if not wait_records(gj - 2):
                        break       # run already declared failed
                    window = [r for r in list(hist) if r["i"] <= gj - 2]
                    with tracer.span("controller.decide", g=int(
                            skip_intervals) + executed) as dsp:
                        decisions = ctl.step(int(skip_intervals) + executed,
                                             window)
                        dsp.set(n=len(decisions))
                    if advisory is not None:
                        # shadow timing tier: hints are logged + recorded,
                        # never applied — the replay path is untouched
                        for h in advisory.step(int(skip_intervals) + executed,
                                               window, decisions):
                            tele.record_doc("advisory", dict(h))
                            tele.event(
                                "controller.advisory",
                                "advisory (timing tier, NOT applied): "
                                "%s %s -> %s at g=%d",
                                h["knob"], h["old"], h["new"], h["g"],
                                logger=log, level=logging.INFO, limit=8)
                    if decisions and faults is not None:
                        faults.on_controller_decide()
                    if ctl.plan.chunk != K:
                        K = ctl.plan.chunk
                        while not state["exhausted"] and len(ready) < K:
                            if not pull_one():
                                break
                        room = (K if max_intervals is None
                                else max(0, int(max_intervals) - executed))
                kk = min(K, len(ready), room)
                if kk == 0:
                    break
                submit(kk, ctl.plan if ctl is not None else None)
        except BaseException as e:
            # a fatal source error (retries exhausted) lands here: fold it
            # into the structured crash path so stats stay intact
            if state["err"] is None:
                state["err"] = e
        finally:
            profiler.stop()
            if wd_thread is not None:
                wd_stop.set()
                wd_thread.join()
            # always shut the executor down — even when the source raised —
            # so no run leaks a thread blocked on the work queue
            if state["err"] is None:
                work_q.put(None)
                worker.join()
            else:
                try:
                    work_q.put_nowait(None)
                except queue.Full:
                    pass
                # a cooperatively-aborted executor exits promptly; a truly
                # hung one (blocked inside a device call) is abandoned as a
                # daemon after the timeout and recorded in the stats
                worker.join(timeout=2.0 if isinstance(
                    state["err"], ExecutorHungError) else None)

        err = state["err"]
        hung_thread = worker.is_alive()
        if isinstance(err, ExecutorHungError) and not hung_thread:
            # the watchdog's contract: drain every committable in-flight
            # chunk (their device arrays are valid results), then publish
            # an emergency punctuation-aligned snapshot so recovery starts
            # from this boundary instead of the last periodic one
            try:
                while in_flight:
                    commit_oldest(check_crash=False)
                if cfg.snapshot_every and vals_ok["safe"] \
                        and g_next not in rec.snapshots:
                    take_snapshot(g_next, emergency=True)
                    err.info["emergency_snapshot"] = g_next
            except Exception:
                log.exception("post-hang drain/snapshot failed")
        stranded = max(0, executed - len(rec.outputs))
        if err is not None:
            self._finish(rec, asm, ready, crashed=True, stranded=stranded,
                         source=srcst, error=err, plane=faults,
                         chunks=list(hist), controller=ctl,
                         hung_thread=hung_thread, advisory=advisory)
            tracer.close()
            raise err

        rec.final_values = np.asarray(jax.device_get(eng.carry_out(vals)))
        self._finish(rec, asm, ready, crashed=False, stranded=stranded,
                     source=srcst, plane=faults, chunks=list(hist),
                     controller=ctl, advisory=advisory)
        tracer.close()
        return rec

    def _make_controller(self, controller_state: Optional[Dict]
                         ) -> Optional[PlanController]:
        """Build the run's controller: the configured one, or the implicit
        slack-only controller that subsumes ``escalate_overflow``, or
        None.  Restoring from ``controller_state`` folds the snapshot's
        decision trace back into the plan and re-applies its slack;
        single-device scheme/rung variants pre-build here so a mid-storm
        switch costs a rebind, not a surprise trace."""
        cfg, eng = self.cfg, self.engine
        ctl_cfg = cfg.controller
        if (ctl_cfg is None and cfg.escalate_overflow
                and eng._sharded is not None):
            # PR 5's escalate_overflow contract as a one-knob controller:
            # widen on observed drops only, one boundary of cool-down,
            # bounded by the configured escalation budget
            ctl_cfg = ControllerConfig(
                window=1, sustain=1, cooldown=cfg.chunk_intervals,
                slack_widen=True, slack_factor=cfg.escalate_factor,
                max_escalations=cfg.escalate_overflow, fill_widen=0.0,
                degrade_scheme="", chunk_ladder=(), rung_ladder=())
        elif ctl_cfg is not None and cfg.escalate_overflow:
            ctl_cfg = dataclasses.replace(
                ctl_cfg, max_escalations=cfg.escalate_overflow,
                slack_factor=cfg.escalate_factor)
        if ctl_cfg is None:
            assert not controller_state, \
                ("snapshot records an adaptive run: configure "
                 "ServiceConfig.controller (or escalate_overflow) to "
                 "resume it")
            return None
        if cfg.snapshot_every and ctl_cfg.allow_timing:
            # wall latencies are not replayable signals: a snapshotted
            # run must decide from the deterministic tier only
            ctl_cfg = dataclasses.replace(ctl_cfg, allow_timing=False)
        sharded = eng._sharded is not None
        init_plan = Plan(
            scheme=eng.cfg.scheme, rung=eng.cfg.restructure_method,
            slack=(eng._sharded.exchange_slack if sharded else 0.0),
            chunk=cfg.chunk_intervals, owners=eng.owners)
        if controller_state and controller_state.get("init_plan"):
            stored = Plan.from_dict(controller_state["init_plan"])
            # scheme/rung/chunk come from the engine/service config and
            # must match (config mismatch is a caller error); slack and
            # ownership may differ when the same engine object already
            # escalated or migrated — the stored value is the original
            # run's ground truth
            assert (stored.scheme, stored.rung, stored.chunk) == \
                (init_plan.scheme, init_plan.rung, init_plan.chunk), \
                ("snapshot's adaptive run started from plan "
                 f"{stored.as_dict()}, this service is configured for "
                 f"{init_plan.as_dict()}")
            init_plan = stored
        ctl = PlanController(
            ctl_cfg, init_plan, sharded=sharded,
            snap_align=cfg.snapshot_every, queue_cap=cfg.queue_intervals,
            # the reshard knob only opens on an engine that can actually
            # migrate (shared_nothing, >1 device, index routing)
            n_owners=(eng._sharded.n_dev if eng.reshardable else 0),
            n_slots=(eng.init_store.n_slots if eng.reshardable else 0))
        if controller_state:
            # pre-elastic manifests recorded plans without an "owners"
            # key; round-tripping through Plan normalizes the dict so the
            # restore check compares like with like
            plan_check = controller_state.get("plan")
            if plan_check is not None:
                plan_check = Plan.from_dict(plan_check).as_dict()
            ctl.restore(controller_state.get("trace", ()),
                        plan_check=plan_check)
        if sharded:
            # re-enter the restored layout: the snapshot's canonical
            # uid-order values are loaded by run() AFTER this rebind, so
            # they enter under the ownership the replayed trace folds to
            eng.rebind_ownership(ctl.plan.owners)
            if ctl.plan.slack != eng._sharded.exchange_slack:
                eng._sharded.set_exchange_slack(ctl.plan.slack)
        else:
            for sch in {ctl_cfg.degrade_scheme} - {""}:
                eng.ensure_variant(scheme=sch)
            for rung in ctl_cfg.rung_ladder:
                eng.ensure_variant(restructure_method=rung)
        return ctl

    def resume(self, source, **run_kwargs) -> ServiceRun:
        """Restore the newest *valid* punctuation-aligned snapshot, replay.

        Fallback order (DESIGN.md §2.7): candidate steps descend; a
        snapshot that fails :func:`repro.ckpt.verify_checkpoint` (torn
        manifest, truncated or corrupted leaf) or fails to load is logged
        and skipped, so corruption of the latest snapshot never escapes
        ``resume`` — it falls back to the previous valid one.  Raises
        ``FileNotFoundError`` only when no valid snapshot exists at all.
        """
        cfg = self.cfg
        assert cfg.ckpt_dir, "resume needs a ckpt_dir"
        rejected = []
        for step in checkpoint_steps(cfg.ckpt_dir):
            ok, why = verify_checkpoint(cfg.ckpt_dir, step)
            if not ok:
                log.warning("snapshot step %d failed verification (%s); "
                            "falling back to an older one", step, why)
                rejected.append(step)
                continue
            try:
                restored = load_checkpoint(
                    cfg.ckpt_dir, step,
                    dict(values=self.engine.init_store.values))
                meta = read_manifest_meta(cfg.ckpt_dir, step)
                assert meta is not None   # verified above
            except Exception as e:
                log.warning("snapshot step %d failed to load (%s: %s); "
                            "falling back to an older one",
                            step, type(e).__name__, e)
                rejected.append(step)
                continue
            # a config mismatch is a caller error, not corruption — raise
            assert meta["punct_interval"] == cfg.punct_interval, \
                "snapshot was taken at a different punctuation interval"
            return self.run(source, values=restored["values"],
                            skip_intervals=int(meta["intervals_done"]),
                            controller_state=meta.get("controller"),
                            **run_kwargs)
        raise FileNotFoundError(
            f"no valid snapshot under {cfg.ckpt_dir}"
            + (f" (rejected steps: {rejected})" if rejected else ""))

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict:
        """The last run's stats, or a schema-valid zero record before any
        run — ``service.stats["drops"]`` never raises on a fresh service
        (the old ``None`` footgun)."""
        if self.last_run is not None and self.last_run.stats is not None:
            return self.last_run.stats
        return empty_stats()

    def _finish(self, rec: ServiceRun, asm: IntervalAssembler, ready,
                crashed: bool, stranded: int = 0,
                source: Optional[Dict] = None, error=None, plane=None,
                chunks: Optional[List[Dict]] = None, controller=None,
                hung_thread: bool = False, advisory=None):
        """Publish the run's accounting into the telemetry registry, then
        render ``rec.stats`` as the legacy compatibility view over its
        snapshot (DESIGN.md §2.11) — the registry is the source of truth,
        the merged dict a projection of it."""
        tele = rec.telemetry
        interval = self.cfg.punct_interval
        unprocessed = (len(ready) + stranded) * interval + asm.pending
        tele.count("service.arrived", asm.arrived + rec.admission_dropped)
        tele.count("service.processed", len(rec.outputs) * interval)
        tele.count("service.replayed", rec.replayed_intervals * interval)
        tele.count("service.late_rerouted", asm.late_rerouted)
        tele.count("service.drops", asm.watermark_dropped, kind="watermark")
        tele.count("service.drops", rec.admission_dropped, kind="admission")
        tele.count("service.drops", rec.exchange_dropped, kind="exchange")
        tele.count("service.unprocessed", unprocessed)
        tele.gauge("service.watermark", int(asm.watermark))
        tele.gauge("service.crashed", int(crashed))
        asm.publish(tele)

        srcstats = dict(source or {})
        backfill = ((srcstats.get("retries", 0)
                     + srcstats.get("deadline_misses", 0))
                    / max(srcstats.get("pulls", 0), 1))
        tele.count("source.pulls", srcstats.get("pulls", 0))
        tele.count("source.retries", srcstats.get("retries", 0))
        tele.count("source.deadline_misses",
                   srcstats.get("deadline_misses", 0))
        tele.count("source.backoff_s", srcstats.get("backoff_s", 0.0))
        tele.gauge("source.backfill_ratio", backfill)
        tele.gauge("source.alarm_threshold",
                   self.cfg.straggler.max_backfill_ratio)
        tele.gauge("source.alarm",
                   int(backfill > self.cfg.straggler.max_backfill_ratio))

        tele.ensure_records("snapshots")
        for s in rec.snapshots:
            tele.record("snapshots", step=int(s))
        # per-chunk time series (ring-bounded, newest last): the
        # controller's observation window, published for benchmarks and
        # post-mortems alike
        rec.chunk_records = [dict(r) for r in (chunks or [])]
        tele.ensure_records("chunks")
        for r in rec.chunk_records:
            tele.record_doc("chunks", dict(r))
        tele.observe_many("latency.event_s", rec.latency_s())
        tele.observe_many("latency.chunk_s",
                          [r["lat_s"] for r in rec.chunk_records])

        if controller is not None:
            tele.record_doc("controller", dict(
                init_plan=controller.init_plan.as_dict(),
                plan=controller.plan.as_dict(),
                escalations=controller.esc_done))
            tele.ensure_records("decisions")
            for d in controller.trace:
                tele.record_doc("decisions", dict(d))
            if advisory is not None:
                tele.ensure_records("advisory")
        if error is not None:
            tele.record_doc("error", dict(
                type=type(error).__name__, msg=str(error),
                hung_thread=hung_thread, **getattr(error, "info", {})))
        if plane is not None:
            plane.publish(tele)
        if self.engine._sharded is not None:
            tele.count("exchange.dropped", rec.exchange_dropped)
            tele.count("exchange.shipped", rec.exchange_shipped)
            tele.gauge("exchange.capacity", rec.exchange_capacity)
            tele.gauge("exchange.escalations",
                       controller.esc_done if controller is not None else 0)
            tele.gauge("exchange.slack",
                       self.engine._sharded.exchange_slack)
            # skew-aware placement ledger: observed load per ownership
            # shard over the whole run, its imbalance ratio (max/mean),
            # and every live migration the controller applied
            sh = rec.shard_events
            tot = int(sh.sum()) if sh is not None else 0
            tele.record_doc("placement", dict(
                shard_events=([int(v) for v in sh]
                              if sh is not None else []),
                imbalance=(float(int(sh.max()) * sh.size / tot)
                           if tot else 1.0),
                owners=[[int(u), int(o)]
                        for (u, o) in self.engine.owners]))
            tele.ensure_records("migrations")
            for m in rec.migrations:
                tele.record_doc("migrations", dict(m))
        rec.stats = stats_view(tele.snapshot())
        if not crashed:
            self._log_events(tele, rec.stats)

    @staticmethod
    def _log_events(tele: Telemetry, stats: Dict):
        """One structured event per nonzero drop category per run — never
        per interval.  ``tele.event`` rate-limits (limit=1 per registry,
        i.e. per run) and counts every occurrence in the snapshot."""
        drops = stats["drops"]
        if drops["watermark"]:
            tele.event("drops.watermark",
                       "watermark policy dropped %d late events this run",
                       drops["watermark"], logger=log)
        if drops["admission"]:
            tele.event("drops.admission",
                       "admission control dropped %d events at the full "
                       "queue this run", drops["admission"], logger=log)
        if drops["exchange"]:
            tele.event("drops.exchange",
                       "sharded exchange overflow dropped %d ops this run "
                       "(capacity=%d/bucket) — raise exchange_slack",
                       drops["exchange"], stats["exchange"]["capacity"],
                       logger=log)
        if stats["late_rerouted"]:
            tele.event("late.rerouted",
                       "%d late events rerouted into later intervals this "
                       "run", stats["late_rerouted"], logger=log,
                       level=logging.INFO)
        src = stats.get("source") or {}
        if src.get("alarm"):
            tele.event(
                "source.straggler_alarm",
                "source backfill ratio %.2f exceeded the straggler alarm "
                "threshold %.2f this run (%d retries, %d deadline misses "
                "over %d pulls)", src["backfill_ratio"],
                src["alarm_threshold"], src.get("retries", 0),
                src.get("deadline_misses", 0), src.get("pulls", 0),
                logger=log)
