"""Continuous streaming service runtime (DESIGN.md §2.6).

``StreamService`` turns the batch-replay drivers into a steady-state
pipeline over an unbounded arrival source:

    arrivals -> admission (bounded queue) -> IntervalAssembler (watermark)
             -> ready intervals -> chunked fused scan (K intervals per
             dispatch, state carry donated chunk-to-chunk)
             -> commit (post-process + D2H) -> outputs + latency record

* **Double-buffered device feed**: chunks are dispatched and committed in
  order on a dedicated executor thread while the main thread pulls,
  assembles and stages (H2D) the next chunk — XLA releases the GIL
  during execution, so interval *i+1*'s transfer and compute-mode
  pre-processing overlap interval *i*'s state-access scan on every
  backend (``run_stream_chunk`` itself returns unmaterialized device
  arrays; the executor blocks on chunk *i*'s outputs only after chunk
  *i+1* is in flight).
* **Chunked == monolithic**: chunk boundaries are punctuation boundaries
  and the carry is the donated state buffer, so K-chunked execution is
  bit-identical to one ``run_stream`` over the same events, on both the
  single-device and sharded drivers (pinned in tests/test_service.py and
  tests/service_worker.py).
* **Backpressure / admission control**: the ready queue is bounded
  (``queue_intervals``); when the source outruns the engine the service
  either stops pulling (``admission="block"``) or drops whole arrival
  batches with accounting (``admission="drop"``).
* **Punctuation-aligned recovery**: every ``snapshot_every`` intervals
  the service drains the pipeline and writes the state buffer through
  ``ckpt/`` (the checkpoint step number IS the punctuation index).
  Recovery restores the snapshot and replays the deterministic source,
  discarding the first ``intervals_done`` re-assembled intervals — the
  resumed run is bitwise identical to an uninterrupted one.

``StreamService.stats`` is the one merged accounting record: watermark
drops, admission drops and sharded exchange overflow land in a single
structured dict and each category is logged at most once per run.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import logging
import os
import queue
import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import latest_step, load_checkpoint, save_checkpoint
from repro.core.intervals import IntervalAssembler, WatermarkPolicy

log = logging.getLogger(__name__)


def ts_base_for(global_interval: int, interval: int) -> int:
    """int32-safe timestamp base for an unbounded run.

    Engine timestamps are only meaningful *within* one punctuation
    interval's restructure sort (nothing persists them across intervals),
    so the base wraps at an interval-aligned boundary below 2**30 —
    within any chunk the bases stay monotone and the per-op ``ts_base +
    arange(interval)`` stays well inside int32 forever.  Below the wrap
    (~2**30 events) this equals ``global_interval * interval`` exactly,
    which is what the chunked-vs-monolithic bit-identity tests compare.
    """
    wrap = max(1, 2 ** 30 // interval)
    return (global_interval % wrap) * interval


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    punct_interval: int
    chunk_intervals: int = 4        # K — scan window per device dispatch
    queue_intervals: int = 16       # ready-queue bound (admission control)
    admission: str = "block"        # "block" (backpressure) | "drop"
    watermark: WatermarkPolicy = WatermarkPolicy()
    snapshot_every: int = 0         # intervals between snapshots; 0 = off
    ckpt_dir: Optional[str] = None

    def __post_init__(self):
        assert self.punct_interval > 0
        assert self.chunk_intervals > 0
        assert self.admission in ("block", "drop"), self.admission
        assert self.queue_intervals >= self.chunk_intervals, \
            "queue_intervals must cover at least one chunk"
        if self.snapshot_every:
            assert self.snapshot_every % self.chunk_intervals == 0, \
                ("snapshots are taken at chunk boundaries: snapshot_every "
                 "must be a multiple of chunk_intervals")
            assert self.ckpt_dir, "snapshot_every needs a ckpt_dir"
            # admission drops depend on ready-queue occupancy, and replay
            # (skip_intervals) bypasses the queue for the skipped prefix —
            # a dropping queue is therefore not replayable and would break
            # the crash -> restore -> replay bit-identity guarantee
            assert self.admission == "block", \
                "snapshot/recovery requires admission='block'"


@dataclasses.dataclass
class ServiceRun:
    """Mutable record of one service run (kept on ``service.last_run`` so
    a crashed run's committed prefix stays inspectable)."""

    outputs: List = dataclasses.field(default_factory=list)   # per interval
    commits: List[Dict] = dataclasses.field(default_factory=list)
    latencies: List[np.ndarray] = dataclasses.field(default_factory=list)
    snapshots: List[int] = dataclasses.field(default_factory=list)
    admission_dropped: int = 0
    replayed_intervals: int = 0
    exchange_dropped: int = 0
    exchange_shipped: int = 0
    exchange_capacity: int = 0
    t_first_enqueue: Optional[float] = None
    t_last_commit: Optional[float] = None
    final_values: Optional[np.ndarray] = None
    stats: Optional[Dict] = None

    def latency_s(self) -> np.ndarray:
        """Per-event end-to-end latency (enqueue -> interval commit)."""
        if not self.latencies:
            return np.zeros((0,), np.float64)
        return np.concatenate(self.latencies)

    def latency_percentiles(self, qs=(50, 99)) -> Dict[str, float]:
        lat = self.latency_s()
        if lat.size == 0:
            return {f"p{q}": float("nan") for q in qs}
        return {f"p{q}": float(np.percentile(lat, q)) for q in qs}

    def sustained_events_per_s(self) -> float:
        n = sum(len(l) for l in self.latencies)
        if not n or self.t_first_enqueue is None \
                or self.t_last_commit is None:
            return 0.0
        span = self.t_last_commit - self.t_first_enqueue
        return n / span if span > 0 else 0.0


class StreamService:
    """Long-running punctuation pipeline over a ``DualModeEngine``."""

    def __init__(self, engine, cfg: ServiceConfig):
        self.engine = engine
        self.cfg = cfg
        if engine._sharded is not None:
            assert cfg.punct_interval % engine._sharded.n_dev == 0, \
                (f"punct_interval={cfg.punct_interval} must divide evenly "
                 f"across {engine._sharded.n_dev} devices")
        self.last_run: Optional[ServiceRun] = None

    # ------------------------------------------------------------------
    def run(self, source, values=None, *, skip_intervals: int = 0,
            max_intervals: Optional[int] = None,
            crash_after_interval: Optional[int] = None) -> ServiceRun:
        """Drive the service until the source drains (or ``max_intervals``).

        ``skip_intervals`` is the recovery path: the first N re-assembled
        intervals are discarded without execution (the snapshot already
        contains their effects) and execution resumes at global interval
        index N with the restored state — assembly is deterministic, so
        the continuation is bitwise identical to the uninterrupted run.
        ``crash_after_interval`` injects a failure once the interval with
        that global index has committed (tests/CI restart drill).
        """
        cfg, eng = self.cfg, self.engine
        if skip_intervals and cfg.admission != "block":
            raise ValueError(
                "replay (skip_intervals) requires admission='block': a "
                "dropping queue makes the arrival->interval mapping depend "
                "on commit progress, which replay does not reproduce")
        interval, K = cfg.punct_interval, cfg.chunk_intervals
        asm = IntervalAssembler(interval, cfg.watermark)
        ready = collections.deque()
        in_flight = collections.deque()
        rec = ServiceRun()
        self.last_run = rec
        init = eng.init_store.values if values is None else values
        vals = jnp.array(init, copy=True)
        src = iter(source)
        state = dict(exhausted=False, to_skip=int(skip_intervals), err=None)
        g_next = int(skip_intervals)    # global index of next interval
        executed = 0                    # intervals submitted this run
        # staged chunks queued for the executor thread; maxsize=1 plus the
        # executor's depth-2 in_flight window bounds the pipeline
        work_q: queue.Queue = queue.Queue(maxsize=1)

        def drain_asm():
            for ev_iv, info in asm.pop_ready():
                if state["to_skip"] > 0:
                    state["to_skip"] -= 1
                    rec.replayed_intervals += 1
                else:
                    ready.append((ev_iv, info))

        def pull_one() -> bool:
            """Admit one arrival batch; False = backpressure (queue full)."""
            if state["exhausted"]:
                return False
            if len(ready) >= cfg.queue_intervals and cfg.admission == "block":
                return False
            try:
                ev, t = next(src)
            except StopIteration:
                state["exhausted"] = True
                asm.close()
            else:
                if len(ready) >= cfg.queue_intervals:   # admission == "drop"
                    rec.admission_dropped += int(np.asarray(t).shape[0])
                else:
                    now = time.perf_counter()
                    if rec.t_first_enqueue is None:
                        rec.t_first_enqueue = now
                    asm.push(ev, t, enqueue_s=now)
            drain_asm()
            return True

        def commit_oldest():
            g0, kk, res, ebs, infos, xst = in_flight.popleft()
            outs = eng.post_outputs(res, ebs, kk)
            t_commit = time.perf_counter()
            rec.t_last_commit = t_commit
            if xst is not None:
                st = jax.device_get(xst)
                rec.exchange_dropped += int(np.sum(st["dropped"]))
                rec.exchange_shipped += int(np.sum(st["shipped"]))
                rec.exchange_capacity = int(st["capacity"])
            for i in range(kk):
                info = infos[i]
                rec.outputs.append(outs[i])
                rec.latencies.append(t_commit - info.enqueue_s)
                rec.commits.append(dict(
                    interval=g0 + i, commit_s=t_commit,
                    watermark=int(info.watermark), n_late=int(info.n_late)))
            if crash_after_interval is not None \
                    and g0 + kk - 1 >= crash_after_interval:
                raise RuntimeError(
                    f"injected failure after interval {g0 + kk - 1}")

        def dispatch(batched, kk: int, infos):
            nonlocal vals, g_next
            res, ebs, vals, xst = eng.run_stream_chunk(
                vals, batched, ts_base_for(g_next, interval))
            in_flight.append((g_next, kk, res, ebs, infos, xst))
            g_next += kk
            # double buffer depth 2: block on the oldest chunk only once a
            # newer one is in flight (its assembly/H2D already overlapped)
            while len(in_flight) > 1:
                commit_oldest()
            if cfg.snapshot_every and g_next % cfg.snapshot_every == 0:
                # punctuation-aligned snapshot: drain the pipe so the carry
                # is this boundary's state, then publish through ckpt/
                while in_flight:
                    commit_oldest()
                host_vals = np.asarray(jax.device_get(vals))
                save_checkpoint(
                    cfg.ckpt_dir, g_next, dict(values=host_vals),
                    extra_meta=dict(intervals_done=g_next,
                                    punct_interval=interval))
                rec.snapshots.append(g_next)

        def executor():
            """Chunk executor thread: dispatch/commit strictly in order so
            the donated state carry chains exactly as the monolithic scan's
            would.  Running it off the main thread is what makes the feed
            double-buffered on every backend: XLA releases the GIL during
            execution, so the main thread assembles and stages chunk i+1
            while chunk i computes."""
            try:
                while True:
                    item = work_q.get()
                    if item is None:
                        break
                    dispatch(*item)
                while in_flight:
                    commit_oldest()
            except BaseException as e:
                state["err"] = e
                try:                    # unblock the producer
                    while True:
                        work_q.get_nowait()
                except queue.Empty:
                    pass

        worker = threading.Thread(target=executor, daemon=True,
                                  name="stream-service-executor")
        worker.start()

        def submit(kk: int):
            nonlocal executed
            chunk = [ready.popleft() for _ in range(kk)]
            # count at pop time: a chunk stranded by a crash (in work_q,
            # in_flight, or aborted here) is executed-but-uncommitted and
            # must land in the stats as unprocessed, not vanish
            executed += kk
            batched = {k: jnp.asarray(np.stack([c[0][k] for c in chunk]))
                       for k in chunk[0][0]}
            item = (batched, kk, [c[1] for c in chunk])
            while state["err"] is None:
                try:
                    work_q.put(item, timeout=0.05)
                    return
                except queue.Full:
                    continue

        try:
            while state["err"] is None:
                # admission: a "drop" source never waits — one arrival
                # batch is admitted (or dropped at the full queue) per
                # dispatch cycle, modelling an arrival rate the service
                # cannot defer; a "block" source is backpressured: pulled
                # only while the next chunk is still short.
                if cfg.admission == "drop" and not state["exhausted"]:
                    pull_one()
                while not state["exhausted"] and len(ready) < K:
                    if not pull_one():
                        break
                room = (K if max_intervals is None
                        else max(0, int(max_intervals) - executed))
                kk = min(K, len(ready), room)
                if kk == 0:
                    break
                submit(kk)
        finally:
            # always shut the executor down — even when the source raised —
            # so no run leaks a thread blocked on the work queue
            if state["err"] is None:
                work_q.put(None)
            worker.join()
        stranded = max(0, executed - len(rec.outputs))
        if state["err"] is not None:
            self._finish(rec, asm, ready, crashed=True, stranded=stranded)
            raise state["err"]

        rec.final_values = np.asarray(jax.device_get(vals))
        self._finish(rec, asm, ready, crashed=False, stranded=stranded)
        return rec

    def resume(self, source, **run_kwargs) -> ServiceRun:
        """Restore the latest punctuation-aligned snapshot and replay."""
        cfg = self.cfg
        assert cfg.ckpt_dir, "resume needs a ckpt_dir"
        last = latest_step(cfg.ckpt_dir)
        if last is None:
            raise FileNotFoundError(f"no snapshot under {cfg.ckpt_dir}")
        restored = load_checkpoint(
            cfg.ckpt_dir, last,
            dict(values=self.engine.init_store.values))
        with open(os.path.join(cfg.ckpt_dir, f"step_{last:08d}",
                               "manifest.json")) as f:
            meta = json.load(f)["meta"]
        assert meta["punct_interval"] == cfg.punct_interval, \
            "snapshot was taken at a different punctuation interval"
        return self.run(source, values=restored["values"],
                        skip_intervals=int(meta["intervals_done"]),
                        **run_kwargs)

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Optional[Dict]:
        return self.last_run.stats if self.last_run else None

    def _finish(self, rec: ServiceRun, asm: IntervalAssembler, ready,
                crashed: bool, stranded: int = 0):
        interval = self.cfg.punct_interval
        unprocessed = (len(ready) + stranded) * interval + asm.pending
        rec.stats = dict(
            arrived=asm.arrived + rec.admission_dropped,
            processed=len(rec.outputs) * interval,
            replayed=rec.replayed_intervals * interval,
            late_rerouted=asm.late_rerouted,
            drops=dict(watermark=asm.watermark_dropped,
                       admission=rec.admission_dropped,
                       exchange=rec.exchange_dropped),
            unprocessed=unprocessed,
            snapshots=list(rec.snapshots),
            watermark=int(asm.watermark),
            crashed=crashed,
        )
        if self.engine._sharded is not None:
            rec.stats["exchange"] = dict(
                dropped=rec.exchange_dropped,
                shipped=rec.exchange_shipped,
                capacity=rec.exchange_capacity)
        if not crashed:
            self._log_once(rec.stats)

    @staticmethod
    def _log_once(stats: Dict):
        """One line per nonzero drop category per run — never per interval."""
        drops = stats["drops"]
        if drops["watermark"]:
            log.warning("watermark policy dropped %d late events this run",
                        drops["watermark"])
        if drops["admission"]:
            log.warning("admission control dropped %d events at the full "
                        "queue this run", drops["admission"])
        if drops["exchange"]:
            log.warning("sharded exchange overflow dropped %d ops this run "
                        "(capacity=%d/bucket) — raise exchange_slack",
                        drops["exchange"], stats["exchange"]["capacity"])
        if stats["late_rerouted"]:
            log.info("%d late events rerouted into later intervals this run",
                     stats["late_rerouted"])
