"""Adaptive dual-mode control plane (DESIGN.md §2.9).

A deterministic feedback controller embedded in ``StreamService``'s loop:
at each punctuation boundary it reads the per-chunk record window the
service maintains (``stats["chunks"]``) and moves the live plan inside a
small legal lattice of pre-jitted variants —

  ``scheme``  degrade the optimistic scheme to a pessimistic one under a
              sustained conflict storm (tstream → lock), probe back after
              the cool-down
  ``slack``   widen the sharded exchange capacity before (fill crowding)
              or after (observed drops) overflow loses events — this
              subsumes PR 5's one-way ``escalate_overflow`` hack
  ``chunk``   grow/shrink the service chunk size K when fixed per-chunk
              cost dominates (backlog) or per-interval latency degrades
  ``rung``    step the restructure rung when chain dominance leaves the
              autotuned ladder's band

Everything here is a *pure function of the observed record window*: the
controller never reads a clock, an rng, or device values.  Signals split
into a deterministic tier (abort/fail counts, chain stats, exchange
drop/fill counters, queue fill — all replayed bit-identically from the
same events) and a timing tier (chunk wall latency), and the timing tier
is force-disabled whenever snapshots are on, so every decision a
snapshotted run makes is reproducible from the replayed stream alone.
That is what makes crash → restore → replay of an *adaptive* run bitwise
identical to the uninterrupted run: the snapshot manifest carries the
decision trace plus the record window tail, ``resume`` folds the trace
back into the plan, and the first post-restore decision recomputes from
the same records the uninterrupted run saw (tests/test_faults.py,
tests/test_controller_property.py).

Hysteresis: each knob carries the global-interval index of its last
switch and may not move again within ``cooldown`` intervals; storm
triggers additionally require ``sustain`` consecutive storming records.
Decisions append to a monotone trace (non-decreasing ``g``), one dict
per switch: ``{"g", "knob", "old", "new", "reason"}``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

KNOBS = ("slack", "scheme", "chunk", "rung", "reshard")


def norm_owners(owners) -> Tuple[Tuple[int, int], ...]:
    """Canonical ownership-override form: sorted tuple of (uid, owner)."""
    return tuple(sorted((int(u), int(o)) for u, o in owners))


@dataclasses.dataclass(frozen=True)
class Plan:
    """One point of the plan lattice.  ``scheme``/``rung`` name the
    engine variant (construction values = the base ``_fused`` program),
    ``slack`` the sharded exchange slack (0.0 on single-device),
    ``chunk`` the service chunk size K in intervals, and ``owners`` the
    ownership-placement overrides the ``reshard`` knob migrates onto
    (() = pure round-robin striping)."""

    scheme: str
    rung: str
    slack: float
    chunk: int
    owners: Tuple[Tuple[int, int], ...] = ()

    def as_dict(self) -> Dict:
        return dict(scheme=self.scheme, rung=self.rung, slack=self.slack,
                    chunk=self.chunk,
                    owners=[[int(u), int(o)] for u, o in self.owners])

    @staticmethod
    def from_dict(d: Dict) -> "Plan":
        return Plan(scheme=str(d["scheme"]), rung=str(d["rung"]),
                    slack=float(d["slack"]), chunk=int(d["chunk"]),
                    owners=norm_owners(d.get("owners", ())))


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Decision rules + lattice bounds.  A knob whose lattice is empty
    (``degrade_scheme=""``, ``chunk_ladder=()``, ``rung_ladder=()``,
    ``slack_widen=False``) never moves."""

    window: int = 4        # records a decision may read
    sustain: int = 2       # consecutive storming records to call a storm
    cooldown: int = 8      # global intervals a switched knob stays frozen

    # scheme degradation (single-device lattice)
    degrade_scheme: str = ""          # "" disables the knob
    degrade_chain_frac: float = 0.75  # max_chain / events-per-interval
    degrade_fail_frac: float = 0.25   # failed-op fraction of all op slots

    # exchange slack (sharded lattice)
    slack_widen: bool = True
    slack_factor: float = 2.0
    slack_max: float = 64.0
    fill_widen: float = 0.0   # >0: widen when max_fill/capacity crosses
                              # this BEFORE anything drops (predictive)
    max_escalations: int = 0  # 0 = unbounded

    # chunk size K
    chunk_ladder: Tuple[int, ...] = ()  # legal K values; () disables
    backlog_grow: float = 2.0   # grow when qfill >= backlog_grow*K sustained
    grow_lat_s: float = 0.0     # timing tier: grow while chunks run under
    shrink_lat_s: float = 0.0   # timing tier: shrink when lat/interval over

    # restructure rung
    rung_ladder: Tuple[str, ...] = ()  # () disables; [0]=calm, [-1]=storm
    rung_chain_frac: float = 0.0       # chain dominance that steps up

    # elastic resharding (sharded shared_nothing lattice): migrate hot
    # slots when max/mean shard load sustains above the threshold
    reshard_imbalance: float = 0.0  # <=1.0 disables the knob
    reshard_max_moves: int = 16     # hot uids migrated per decision

    # timing tier master switch.  The service forces this False whenever
    # snapshots are on: wall latencies are not replayable signals.
    allow_timing: bool = False


def _shard_imbalance(r: Dict) -> float:
    """max/mean of one record's per-shard load (1.0 = perfectly flat)."""
    xs = r.get("x_shard") or ()
    total = sum(xs)
    if not xs or total <= 0:
        return 1.0
    return max(xs) * len(xs) / total


def _chain_frac(r: Dict) -> float:
    """Chain dominance of one chunk record: longest version chain over
    events per interval (every event touches >= 1 distinct key, so a
    value near 1.0 means one hot key serializes the interval)."""
    ev_per_iv = r["events"] // max(r["k"], 1)
    return r["max_chain"] / max(ev_per_iv, 1)


def _fail_frac(r: Dict) -> float:
    return r["fail"] / max(r["ops"], 1)


def _stormy(r: Dict, cfg: ControllerConfig, base_scheme: str) -> bool:
    """Conflict-storm predicate for one record.  Only records executed
    under the *base* scheme count: the degraded oracle (eval_lock)
    reports the whole interval as one serial chain, so its stats measure
    the plan, not the workload."""
    if r.get("scheme") != base_scheme:
        return False
    return (_chain_frac(r) >= cfg.degrade_chain_frac
            or _fail_frac(r) >= cfg.degrade_fail_frac)


def _ladder_step(ladder: Sequence, cur, up: bool):
    """Next rung above/below ``cur`` on ``ladder`` (None at the ends or
    when ``cur`` left the ladder)."""
    if cur not in ladder:
        return None
    i = ladder.index(cur) + (1 if up else -1)
    return ladder[i] if 0 <= i < len(ladder) else None


def decide(cfg: ControllerConfig, plan: Plan, window: Sequence[Dict],
           g: int, last_switch: Dict[str, int], *, init_plan: Plan,
           sharded: bool, esc_done: int, snap_align: int,
           queue_cap: int, n_owners: int = 0,
           n_slots: int = 0) -> List[Dict]:
    """The decision function: pure in every argument.

    ``window`` is the chunk-record window (oldest first) visible at
    boundary ``g`` — the service guarantees the same window contents on
    replay (records of chunks committed strictly before the previous
    submission).  Returns at most one decision per knob, in fixed knob
    order; the caller folds them into the plan via ``PlanController``.
    """
    decisions: List[Dict] = []
    w = list(window)[-cfg.window:]
    sust = w[-cfg.sustain:] if len(w) >= cfg.sustain else None

    def ready(knob: str) -> bool:
        last = last_switch.get(knob)
        return last is None or g - last >= cfg.cooldown

    def emit(knob, old, new, reason):
        decisions.append(dict(g=int(g), knob=knob, old=old, new=new,
                              reason=reason))

    # -- slack: sharded exchange capacity (one-way widening) --------------
    if (sharded and cfg.slack_widen and ready("slack")
            and plan.slack < cfg.slack_max
            and (cfg.max_escalations <= 0 or esc_done < cfg.max_escalations)):
        drops = any(r["x_drop"] > 0 for r in w)
        crowded = (cfg.fill_widen > 0.0
                   and any(r["x_cap"] > 0
                           and r["x_fill"] >= cfg.fill_widen * r["x_cap"]
                           for r in w))
        if drops or crowded:
            new = min(plan.slack * cfg.slack_factor, cfg.slack_max)
            if new > plan.slack:
                emit("slack", plan.slack, new,
                     "overflow-drops" if drops else "fill-crowding")

    # -- scheme: degrade under a sustained conflict storm, probe back -----
    if not sharded and cfg.degrade_scheme and ready("scheme"):
        if plan.scheme == init_plan.scheme:
            if sust and all(_stormy(r, cfg, init_plan.scheme)
                            for r in sust):
                emit("scheme", plan.scheme, cfg.degrade_scheme,
                     "conflict-storm")
        elif plan.scheme == cfg.degrade_scheme:
            # the degraded oracle cannot observe chain structure, so
            # recovery is a probe: re-enter the base plan once the
            # cool-down expires; a persisting storm re-degrades only
            # after `sustain` fresh base-scheme records
            emit("scheme", plan.scheme, init_plan.scheme, "probe")

    # -- chunk size K ------------------------------------------------------
    if (cfg.chunk_ladder and ready("chunk")
            and (snap_align == 0 or g % snap_align == 0)):
        # legality: K must tile the snapshot period and fit the queue
        ladder = sorted(k for k in set(cfg.chunk_ladder)
                        if 0 < k <= queue_cap
                        and (snap_align == 0 or snap_align % k == 0))
        full = sust and all(r["k"] == plan.chunk for r in sust)
        grow = shrink = False
        if full and all(r["qfill"] >= cfg.backlog_grow * plan.chunk
                        for r in sust):
            grow, reason = True, "backlog"
        elif (cfg.allow_timing and cfg.grow_lat_s > 0.0 and full
              and all(r["lat_s"] < cfg.grow_lat_s for r in sust)):
            grow, reason = True, "amortize-dispatch"
        elif (cfg.allow_timing and cfg.shrink_lat_s > 0.0 and sust
              and all(r["lat_s"] / max(r["k"], 1) > cfg.shrink_lat_s
                      for r in sust)):
            shrink, reason = True, "latency"
        if grow or shrink:
            new = _ladder_step(ladder, plan.chunk, up=grow)
            if new is None and plan.chunk not in ladder:
                # construction K off the ladder: enter at the nearest
                # rung in the direction of travel
                cands = ([k for k in ladder if k > plan.chunk] if grow
                         else [k for k in ladder if k < plan.chunk][::-1])
                new = cands[0] if cands else None
            if new is not None:
                emit("chunk", plan.chunk, new, reason)

    # -- restructure rung --------------------------------------------------
    if (not sharded and cfg.rung_ladder and cfg.rung_chain_frac > 0.0
            and ready("rung") and plan.scheme == init_plan.scheme):
        base_w = [r for r in w if r.get("scheme") == init_plan.scheme]
        bs = base_w[-cfg.sustain:] if len(base_w) >= cfg.sustain else None
        if bs is not None:
            hot = all(_chain_frac(r) >= cfg.rung_chain_frac for r in bs)
            want = cfg.rung_ladder[-1] if hot else cfg.rung_ladder[0]
            if want != plan.rung and plan.rung in cfg.rung_ladder:
                emit("rung", plan.rung, want,
                     "chain-dominance" if hot else "calm")

    # -- reshard: skew-aware placement from the window's load histogram ----
    # Pure over the record window (per-shard totals + the top-M hot-slot
    # counts the service records per chunk), so replay after a restore
    # recomputes the SAME placement from the same records.
    if (sharded and cfg.reshard_imbalance > 1.0 and ready("reshard")
            and n_owners > 1 and n_slots > 0):
        xw = [r for r in w if r.get("x_shard")]
        sx = xw[-cfg.sustain:] if len(xw) >= cfg.sustain else None
        if sx and all(_shard_imbalance(r) >= cfg.reshard_imbalance
                      for r in sx):
            from repro.core.ownership import rebalance_ownership
            shard = [0] * n_owners
            hot_acc: Dict[int, int] = {}
            for r in xw:
                for i, v in enumerate(r["x_shard"]):
                    shard[i] += int(v)
                for u, c in r.get("hot", ()):
                    hot_acc[int(u)] = hot_acc.get(int(u), 0) + int(c)
            new = rebalance_ownership(
                n_slots, n_owners, plan.owners, shard,
                list(hot_acc.items()), max_moves=cfg.reshard_max_moves)
            if new != norm_owners(plan.owners):
                emit("reshard",
                     [[int(u), int(o)] for u, o in plan.owners],
                     [[int(u), int(o)] for u, o in new],
                     f"imbalance-{_shard_imbalance(sx[-1]):.2f}x")

    return decisions


def apply_decision(plan: Plan, d: Dict) -> Plan:
    """Fold one decision into a plan (knob names == Plan field names,
    except ``reshard`` which sets the ``owners`` placement)."""
    assert d["knob"] in KNOBS, d
    if d["knob"] == "reshard":
        return dataclasses.replace(plan, owners=norm_owners(d["new"]))
    return dataclasses.replace(plan, **{d["knob"]: d["new"]})


def replay_plan(init_plan: Plan, trace: Sequence[Dict]) -> Plan:
    """Fold a decision trace: the plan at the trace's end.  Used by the
    snapshot publisher (plan at the punctuation boundary), by ``resume``
    and by the property suite's replay checks."""
    plan = init_plan
    for d in trace:
        plan = apply_decision(plan, d)
    return plan


class PlanController:
    """The mutable shell around :func:`decide`: holds the live plan, the
    monotone decision trace and per-knob cool-down state.  All mutation
    happens on the service's main thread."""

    def __init__(self, cfg: ControllerConfig, init_plan: Plan, *,
                 sharded: bool, snap_align: int, queue_cap: int,
                 n_owners: int = 0, n_slots: int = 0):
        self.cfg = cfg
        self.init_plan = init_plan
        self.plan = init_plan
        self.sharded = bool(sharded)
        self.snap_align = int(snap_align)
        self.queue_cap = int(queue_cap)
        self.n_owners = int(n_owners)   # 0 disables the reshard knob
        self.n_slots = int(n_slots)
        self.trace: List[Dict] = []
        self.last_switch: Dict[str, int] = {}
        self.esc_done = 0

    def _fold(self, d: Dict) -> None:
        assert not self.trace or d["g"] >= self.trace[-1]["g"], \
            "decision trace must be monotone in g"
        self.plan = apply_decision(self.plan, d)
        self.last_switch[d["knob"]] = int(d["g"])
        if d["knob"] == "slack":
            self.esc_done += 1
        self.trace.append(d)

    def restore(self, trace: Sequence[Dict], plan_check: Optional[Dict] = None
                ) -> None:
        """Rebuild controller state from a snapshot's decision trace."""
        assert not self.trace, "restore() only into a fresh controller"
        for d in trace:
            self._fold(dict(d))
        if plan_check is not None:
            assert self.plan.as_dict() == dict(plan_check), \
                (f"replayed trace folds to {self.plan.as_dict()}, snapshot "
                 f"recorded plan {plan_check}")

    def step(self, g: int, window: Sequence[Dict]) -> List[Dict]:
        """Decide at boundary ``g`` from ``window``; fold + return the
        decisions (empty list = plan unchanged)."""
        decisions = decide(
            self.cfg, self.plan, window, g, self.last_switch,
            init_plan=self.init_plan, sharded=self.sharded,
            esc_done=self.esc_done, snap_align=self.snap_align,
            queue_cap=self.queue_cap, n_owners=self.n_owners,
            n_slots=self.n_slots)
        for d in decisions:
            self._fold(d)
        return decisions


class AdvisoryTiming:
    """The sanctioned timing→control bridge (DESIGN.md §2.11).

    Snapshots force ``allow_timing`` off because wall latencies are not
    replayable signals.  This shadow evaluates :func:`decide` with the
    timing tier re-enabled — same plan, same window, same cool-down
    state as the applied controller — and surfaces only the decisions
    the deterministic tier did NOT make, tagged ``advisory=True``.
    Hints are pure observability: the service logs and records them but
    never folds them into the plan, never stores them in snapshots, and
    never lets them touch the decision trace, so replay identity is
    untouched.  A per-knob hint ledger applies the same ``cooldown`` so
    a persistent timing signal hints once per cool-down window, not at
    every boundary.
    """

    def __init__(self, ctl: PlanController):
        self.ctl = ctl
        self.cfg = dataclasses.replace(ctl.cfg, allow_timing=True)
        self.last_hint: Dict[str, int] = {}
        self.hints: List[Dict] = []

    def step(self, g: int, window: Sequence[Dict],
             applied: Sequence[Dict]) -> List[Dict]:
        """Shadow-decide at boundary ``g`` AFTER the applied controller
        stepped; returns the fresh hints (possibly empty)."""
        last = dict(self.ctl.last_switch)
        for knob, hg in self.last_hint.items():
            last[knob] = max(hg, last.get(knob, hg))
        shadow = decide(
            self.cfg, self.ctl.plan, window, g, last,
            init_plan=self.ctl.init_plan, sharded=self.ctl.sharded,
            esc_done=self.ctl.esc_done, snap_align=self.ctl.snap_align,
            queue_cap=self.ctl.queue_cap, n_owners=self.ctl.n_owners,
            n_slots=self.ctl.n_slots)
        applied_knobs = {d["knob"] for d in applied}
        out: List[Dict] = []
        for d in shadow:
            if d["knob"] in applied_knobs:
                continue        # the deterministic tier already moved it
            hint = dict(d, advisory=True)
            self.last_hint[d["knob"]] = int(g)
            self.hints.append(hint)
            out.append(hint)
        return out
