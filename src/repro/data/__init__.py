from .pipeline import (PipelineConfig, StreamingPipeline, SyntheticCorpus,
                       STATS_APP)
