"""Streaming tokenized data pipeline with TStream-managed online statistics.

This is where the paper's engine is a *framework feature*, not a demo: the
ingestion stream maintains concurrent keyed mutable state —

  * per-domain token counts        (READ_MODIFY add — mixture re-weighting)
  * per-domain duplicate counters  (shingle-hash dedup via the hash_probe
                                    kernel's table)

Document-ingest events from all ingest shards are state transactions over
these shared tables; the dual-mode engine evaluates each punctuation batch
on-device with Definition-2 consistency.  No per-shard key partitioning is
required — exactly the paper's operational win over partitioned DSPSs.

Training batches are a pure function of (seed, step): the FT contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.blotter import AppSpec
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.core.types import ASSOC_FUNS, make_store

N_DOMAINS = 16
W = 2  # value lanes: [token_count, doc_count]


def _stats_store(**_):
    return make_store([N_DOMAINS, N_DOMAINS], W)


def _gen(rng, n, **_):
    return dict(domain=rng.integers(0, N_DOMAINS, n).astype(np.int32),
                n_tokens=rng.integers(100, 2000, n).astype(np.float32),
                is_dup=(rng.random(n) < 0.1))


def _pre(ev):
    return ev


def _access(blt, eb):
    # table 0: per-domain token/doc counters
    op = jnp.stack([eb["n_tokens"], jnp.float32(1.0)])
    blt.read_modify(0, eb["domain"], op, "add")
    # table 1: per-domain duplicate counters
    dup = jnp.stack([eb["n_tokens"] * eb["is_dup"],
                     eb["is_dup"].astype(jnp.float32)])
    blt.read_modify(1, eb["domain"], dup, "add")
    blt.read(0, eb["domain"])


def _post(eb, res):
    return dict(domain_tokens=res.post[0, 0], accepted=~eb["is_dup"])


STATS_APP = AppSpec(
    name="ingest_stats", funs=ASSOC_FUNS, max_ops=4, width=W,
    make_store=_stats_store, gen_events=_gen, pre_process=_pre,
    state_access=_access, post_process=_post,
)


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab: int = 256
    seq_len: int = 128
    batch: int = 8
    seed: int = 0
    punct_interval: int = 64


class SyntheticCorpus:
    """Deterministic multi-domain corpus (zipf unigrams per domain)."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed

    def doc(self, domain: int, idx: int, length: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, domain, idx]))
        base = (domain * 997) % self.vocab
        toks = rng.zipf(1.5, size=length) % self.vocab
        return ((toks + base) % self.vocab).astype(np.int32)


class StreamingPipeline:
    """Packs documents into fixed-length training sequences and keeps the
    TStream stats engine updated per ingest batch."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg.vocab, cfg.seed)
        store = _stats_store()
        self.engine = DualModeEngine(STATS_APP, store,
                                     EngineConfig(scheme="tstream"))
        self.stats_values = store.values
        self._ts = 0

    def ingest(self, rng: np.random.Generator, n_docs: int) -> Dict:
        """One punctuation interval of ingest events -> engine step."""
        events = {k: jnp.asarray(v) for k, v in _gen(rng, n_docs).items()}
        out, self.stats_values, _ = self.engine.step(
            self.stats_values, events, self._ts)
        self._ts += n_docs
        return out

    def mixture_weights(self) -> np.ndarray:
        """Current inverse-duplication mixture weights from shared state."""
        vals = np.asarray(self.stats_values)
        toks = vals[:N_DOMAINS, 0] + 1.0
        dups = vals[N_DOMAINS : 2 * N_DOMAINS, 0]
        w = toks / (toks + 2.0 * dups)
        return w / w.sum()

    def batch_for_step(self, step: int) -> Dict[str, jnp.ndarray]:
        """Deterministic (seed, step) -> batch; FT replay contract."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, 7, step]))
        seqs = np.empty((cfg.batch, cfg.seq_len + 1), np.int32)
        for b in range(cfg.batch):
            dom = int(rng.integers(0, N_DOMAINS))
            doc = self.corpus.doc(dom, int(rng.integers(0, 1 << 20)),
                                  cfg.seq_len + 1)
            seqs[b] = doc
        return dict(tokens=jnp.asarray(seqs[:, :-1]),
                    labels=jnp.asarray(seqs[:, 1:]))
