"""Production mesh construction.

Single pod : (data=16, model=16)          — 256 chips (TPU v5e pod slice)
Multi pod  : (pod=2, data=16, model=16)   — 512 chips; the ``pod`` axis
extends data parallelism across pods (gradient all-reduce crosses the DCN
once per step; everything else stays intra-pod).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real host devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Mesh axes carrying (FSDP) data parallelism."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
