"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the host devices (CPU here; the same code path drives a
TPU slice — jax.distributed.initialize + the production mesh).  Integrates
the full substrate: TStream-managed data pipeline, AdamW+WSD, checkpoint/
restart, deterministic replay.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import PipelineConfig, StreamingPipeline
from repro.models import init_params, loss_fn
from repro.optim import AdamWConfig, adamw_init, adamw_update, wsd_schedule
from repro.runtime import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    pipe = StreamingPipeline(PipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, batch=args.batch))
    opt_cfg = AdamWConfig(lr=args.lr, state_dtype=jnp.float32)

    params = init_params(cfg, jax.random.key(0))
    opt_state = adamw_init(params, opt_cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat="dots"))(params)
        lr = wsd_schedule(opt_state["step"], warmup=10,
                          stable=int(args.steps * 0.7),
                          decay=max(args.steps // 5, 1))
        p2, s2 = adamw_update(params, grads, opt_state, opt_cfg,
                              lr_scale=lr)
        return p2, s2, loss

    def make_batch(step, rng):
        return pipe.batch_for_step(step)

    loop = TrainLoop(
        TrainLoopConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                        max_steps=args.steps),
        jax.jit(train_step, donate_argnums=(0, 1)), make_batch,
        params, opt_state)
    if args.resume and loop.try_resume():
        print(f"[train] resumed from step {loop.start_step}")

    t0 = time.time()
    loop.run()
    dt = time.time() - t0
    n = len(loop.losses)
    print(f"[train] {args.arch}: {n} steps in {dt:.1f}s "
          f"({n / max(dt, 1e-9):.2f} steps/s)")
    print(f"[train] loss {loop.losses[0]:.4f} -> {loop.losses[-1]:.4f}")
    assert np.isfinite(loop.losses[-1])
    return loop.losses


if __name__ == "__main__":
    main()
