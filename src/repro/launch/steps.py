"""Step builders + abstract input specs for every (arch × shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation); the dry-run lowers/compiles against them.
``train_step`` / ``prefill_step`` / ``decode_step_fn`` are the jitted
entry points with explicit in/out shardings and donated buffers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import (ArchConfig, ShapeCfg, decode_step, forward,
                          init_cache, init_params, loss_fn)
from repro.optim import AdamWConfig, adamw_init, adamw_update, wsd_schedule

from .mesh import dp_axes
from .sharding import batch_specs, cache_specs, param_shardings, param_specs

N_PATCHES = 256  # vision stub: patches per sample in vlm cells


@dataclasses.dataclass(frozen=True)
class StepConfig:
    remat: str = "full"
    seq_shard_acts: bool = True       # Megatron-style sequence sharding of
                                      # the layer carry (train/prefill only)
    expert_parallel: bool = True      # shard_map EP MoE (vs pjit ragged_dot)
    serving_head_pad: bool = True     # decode: pad/replicate kv heads so
                                      # the cache shards on the model axis
    kv_chunk: int = 1024              # flash-attention KV streaming chunk
    optimizer: AdamWConfig = AdamWConfig(state_dtype=jnp.bfloat16)


def _configure_ep(cfg: ArchConfig, mesh, step_cfg: "StepConfig",
                  tokens_per_device: int = 1 << 30):
    """EP pays off only when each device has enough tokens to fill its
    all-to-all capacity buckets; decode (a handful of tokens per device)
    stays on the pjit path (measured in EXPERIMENTS.md §Perf cell 1)."""
    from repro.models import layers, moe_ep
    layers.set_kv_chunk(step_cfg.kv_chunk)
    if step_cfg.expert_parallel and cfg.is_moe \
            and cfg.n_experts % mesh.shape["model"] == 0 \
            and tokens_per_device >= mesh.shape["model"]:
        moe_ep.set_ep_mesh(mesh, dp_axes(mesh))
    else:
        moe_ep.set_ep_mesh(None, dp_axes(mesh))


def batch_struct(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    sd = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return dict(tokens=sd((b, 1), i32))
    if cfg.frontend == "audio":
        batch = dict(frames=sd((b, s, cfg.d_model), bf16))
        if shape.kind == "train":
            batch["labels"] = sd((b, s), i32)
        return batch
    batch = dict(tokens=sd((b, s), i32))
    if shape.kind == "train":
        batch["labels"] = sd((b, s), i32)
    if cfg.frontend == "vision":
        batch["patch_embeds"] = sd((b, N_PATCHES, cfg.d_model), bf16)
        batch["patch_pos"] = sd((b, N_PATCHES), i32)
    if cfg.mrope:
        batch["pos3"] = sd((b, 3, s), i32)
    return batch


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda k: init_params(cfg, k, dtype),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_opt_state(cfg: ArchConfig, opt: AdamWConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(lambda: adamw_init(params, opt))


def abstract_cache(cfg: ArchConfig, shape: ShapeCfg):
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))


def _constrain_maker(mesh, cfg: ArchConfig, step_cfg: StepConfig, seq_len):
    """Layer-carry sharding constraint: sequence over 'model' (Megatron SP)."""
    if not step_cfg.seq_shard_acts:
        return None
    msize = mesh.shape["model"]
    if seq_len % msize != 0 or seq_len < msize:
        return None
    dp = dp_axes(mesh)
    spec = P(dp, "model", None)

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def build_train_step(cfg: ArchConfig, shape: ShapeCfg, mesh,
                     step_cfg: StepConfig = StepConfig()):
    _configure_ep(cfg, mesh, step_cfg)
    """Returns (jitted step, (params_struct, opt_struct, batch_struct))."""
    constrain = _constrain_maker(mesh, cfg, step_cfg, shape.seq_len)
    opt = step_cfg.optimizer

    def train_step(params, opt_state, batch):
        def lf(p):
            return loss_fn(cfg, p, batch, remat=step_cfg.remat,
                           constrain=constrain)

        loss, grads = jax.value_and_grad(lf)(params)
        lr = wsd_schedule(opt_state["step"], warmup=2000, stable=50_000,
                          decay=5_000)
        new_params, new_state = adamw_update(params, grads, opt_state, opt,
                                             lr_scale=lr)
        return new_params, new_state, loss

    params_s = abstract_params(cfg)
    opt_s = abstract_opt_state(cfg, opt)
    batch_s = batch_struct(cfg, shape)
    pspec = param_shardings(params_s, mesh)
    ospec = dict(
        m=param_shardings(opt_s["m"], mesh),
        v=param_shardings(opt_s["v"], mesh),
        step=NamedSharding(mesh, P()),
    )
    bspec = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        batch_specs(batch_s, mesh, shape.global_batch))
    jitted = jax.jit(
        train_step,
        in_shardings=(pspec, ospec, bspec),
        out_shardings=(pspec, ospec, NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )
    return jitted, (params_s, opt_s, batch_s)


def build_prefill_step(cfg: ArchConfig, shape: ShapeCfg, mesh,
                       step_cfg: StepConfig = StepConfig()):
    _configure_ep(cfg, mesh, step_cfg)
    constrain = _constrain_maker(mesh, cfg, step_cfg, shape.seq_len)

    def prefill(params, batch):
        return forward(cfg, params, batch, remat="none", constrain=constrain)

    params_s = abstract_params(cfg)
    batch_s = batch_struct(cfg, shape)
    pspec = param_shardings(params_s, mesh)
    bspec = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        batch_specs(batch_s, mesh, shape.global_batch))
    out_spec = NamedSharding(
        mesh, _logits_spec(cfg, shape, mesh))
    jitted = jax.jit(prefill, in_shardings=(pspec, bspec),
                     out_shardings=out_spec)
    return jitted, (params_s, batch_s)


def build_decode_step(cfg: ArchConfig, shape: ShapeCfg, mesh,
                      step_cfg: StepConfig = StepConfig()):
    """serve_step: one new token against a seq_len-deep KV cache."""
    _configure_ep(cfg, mesh, step_cfg,
                  tokens_per_device=max(shape.global_batch
                                        // _dp_size(mesh), 1))
    if step_cfg.serving_head_pad:
        from repro.models.serving import serving_padded
        cfg = serving_padded(cfg, mesh.shape["model"])

    def serve(params, caches, tokens, pos):
        return decode_step(cfg, params, caches, tokens, pos)

    params_s = abstract_params(cfg)
    cache_s = abstract_cache(cfg, shape)
    batch_s = batch_struct(cfg, shape)
    pspec = param_shardings(params_s, mesh)
    cspec = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        cache_specs(cache_s, mesh, shape.global_batch))
    tspec = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        batch_specs(dict(tokens=batch_s["tokens"]), mesh,
                    shape.global_batch))["tokens"]
    lspec = NamedSharding(mesh, _logits_spec(cfg, shape, mesh, decode=True))
    jitted = jax.jit(
        serve,
        in_shardings=(pspec, cspec, tspec, NamedSharding(mesh, P())),
        out_shardings=(lspec, cspec),
        donate_argnums=(1,),
    )
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted, (params_s, cache_s, batch_s["tokens"], pos_s)


def _dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _logits_spec(cfg: ArchConfig, shape: ShapeCfg, mesh,
                 decode: bool = False) -> P:
    """Logits [B, S, V] sharding, sanitized for odd batch/vocab sizes."""
    from .sharding import sanitize_spec
    dp = dp_axes(mesh)
    s = 1 if decode else shape.seq_len
    return sanitize_spec(P(dp, None, "model"),
                         (shape.global_batch, s, cfg.vocab), mesh)


def build_step(cfg: ArchConfig, shape: ShapeCfg, mesh,
               step_cfg: StepConfig = StepConfig()):
    if shape.kind == "train":
        fn, specs = build_train_step(cfg, shape, mesh, step_cfg)
    elif shape.kind == "prefill":
        fn, specs = build_prefill_step(cfg, shape, mesh, step_cfg)
    else:
        fn, specs = build_decode_step(cfg, shape, mesh, step_cfg)
    return fn, specs
