"""Sharding rules: parameter/activation/cache PartitionSpecs.

Scheme (DESIGN.md §6): TP on the ``model`` axis (attention heads / ffn
hidden / MoE expert dim), FSDP (ZeRO) on the data axes for the other big
dim.  Norms and tiny vectors replicate.  Stacked layer axes are always
unsharded (they are scanned over).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any


def _rule(path: Tuple[str, ...], ndim: int, dp, ep=None) -> P:
    """PartitionSpec for one parameter leaf, pre-stack-axis.

    ``ep``: mesh axes for the expert dimension of routed-expert weights
    (moe_ep.ep_axes) — expert weights live fully sharded by expert, so the
    shard_map EP region needs no weight collectives at all."""
    name = path[-1]
    inside_stack = "stacks" in path
    # hybrid groups have two stacked axes, plain stacks one
    lead = 0
    if inside_stack:
        lead = 2 if "hybrid_group" in path else 1
    core = ndim - lead

    def spec(*dims):
        assert len(dims) == core, (path, ndim, dims)
        return P(*([None] * lead), *dims)

    # --- embeddings / head -----------------------------------------------
    if name == "embed":
        return P("model", dp)
    if name == "lm_head":
        return P(dp, "model")
    # --- norms / scalars / biases-on-heads ---------------------------------
    if name in ("final_norm", "ln", "ln1", "ln2", "q_norm", "kv_norm",
                "out_norm", "norm1", "norm2"):
        return spec(*([None] * core))
    if name in ("A_log", "D", "dt_bias", "conv_b"):
        return spec(*([None] * (core - 1)), "model")
    if name in ("bq", "bk", "bv"):
        return spec("model", None)
    # --- attention ---------------------------------------------------------
    if name in ("wq", "wk", "wv"):            # [D, H, hd]
        return spec(dp, "model", None)
    if name == "wo":                           # [H, hd, D]
        return spec("model", None, dp)
    if name == "wdq":                          # [D, q_lora]
        return spec(dp, "model")
    if name == "wuq":                          # [q_lora, H, dims]
        return spec(None, "model", None)
    if name == "wdkv":                         # [D, kv_lora]
        return spec(dp, None)
    if name == "wkr":                          # [D, rope]
        return spec(dp, None)
    if name in ("wuk", "wuv"):                 # [kv_lora, H, dim]
        return spec(None, "model", None)
    # --- ffn / moe ----------------------------------------------------------
    if name == "router":                       # [D, E]
        return spec(dp, None)
    if name in ("wu", "wg"):
        if core == 3:                          # [E, D, F]
            # EP active: fully sharded by expert (no gathers in shard_map);
            # pjit fallback: expert dim on model + FSDP over dp.
            return spec(ep, None, None) if ep else spec("model", dp, None)
        return spec(dp, "model")               # [D, F]
    if name == "wd":
        if core == 3:                          # [E, F, D]
            return spec(ep, None, None) if ep else spec("model", None, dp)
        return spec("model", dp)               # [F, D]
    if name in ("shared_wu", "shared_wg"):
        return spec(dp, "model")
    if name == "shared_wd":
        return spec("model", dp)
    # --- mamba ---------------------------------------------------------------
    if name == "in_proj":                      # [D, C]
        return spec(dp, "model")
    if name == "conv_w":                       # [4, C]
        return spec(None, "model")
    if name == "out_proj":                     # [di, D]
        return spec("model", dp)
    if name == "proj":                         # mtp [2D, D]
        return spec(dp, "model")
    # fallback: replicate
    return spec(*([None] * core))


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop mesh axes from dims they don't divide (NamedSharding requires
    exact divisibility for jit argument shardings)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for size, entry in zip(shape, dims):
        if entry is not None and size % _axis_size(mesh, entry) != 0:
            # try shrinking a tuple entry to its largest dividing prefix
            if isinstance(entry, (tuple, list)):
                pref = list(entry)
                while pref and size % _axis_size(mesh, tuple(pref)) != 0:
                    pref.pop()
                entry = tuple(pref) if pref else None
            else:
                entry = None
        out.append(entry)
    return P(*out)


def param_specs(params: PyTree, mesh) -> PyTree:
    from .mesh import dp_axes
    from repro.models.moe_ep import ep_axes, get_ep_mesh
    dp = dp_axes(mesh)

    def one(path, leaf):
        names = tuple(getattr(k, "key", getattr(k, "name", str(k)))
                      for k in path)
        ep = None
        if names[-1] in ("wu", "wg", "wd") and leaf.ndim >= 3 \
                and get_ep_mesh() is not None:
            ep = ep_axes(mesh, leaf.shape[-3])
        return sanitize_spec(_rule(names, leaf.ndim, dp, ep), leaf.shape,
                             mesh)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params: PyTree, mesh) -> PyTree:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  param_specs(params, mesh))


def batch_specs(batch: PyTree, mesh, global_batch: int) -> PyTree:
    """Shard the batch axis over the data axes when divisible, else
    replicate (long_500k decode has batch 1)."""
    from .mesh import dp_axes
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    lead = dp if global_batch % dp_size == 0 else None

    def one(leaf):
        return sanitize_spec(P(lead, *([None] * (leaf.ndim - 1))),
                             leaf.shape, mesh)

    return jax.tree_util.tree_map(one, batch)


def cache_specs(caches: PyTree, mesh, global_batch: int) -> PyTree:
    """KV/SSM cache sharding: batch over data axes, kv heads / latent over
    model when divisible; stacked layer axes unsharded."""
    from .mesh import dp_axes
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bshard = dp if global_batch % dp_size == 0 else None
    msize = mesh.shape["model"]

    def one(path, leaf):
        names = tuple(getattr(k, "key", getattr(k, "name", str(k)))
                      for k in path)
        lead = 2 if "hybrid_group" in names else 1
        core = leaf.ndim - lead
        if names[-1] in ("k", "v"):            # [B, S, hkv, hd]
            hkv = leaf.shape[-2]
            hspec = "model" if hkv % msize == 0 else None
            return P(*([None] * lead), bshard, None, hspec, None)
        if names[-1] == "ckv":                 # [B, S, r]
            return P(*([None] * lead), bshard, None, "model"
                     if leaf.shape[-1] % msize == 0 else None)
        if names[-1] == "kr":                  # [B, S, rope]
            return P(*([None] * lead), bshard, None, None)
        if names[-1] == "conv":                # [B, w, C]
            return P(*([None] * lead), bshard, None, "model"
                     if leaf.shape[-1] % msize == 0 else None)
        if names[-1] == "ssm":                 # [B, H, P, N]
            h = leaf.shape[lead + 1]
            return P(*([None] * lead), bshard,
                     "model" if h % msize == 0 else None, None, None)
        return P(*([None] * leaf.ndim))

    def sane(path, leaf):
        return sanitize_spec(one(path, leaf), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(sane, caches)
