"""Serving launcher: batched prefill+decode with transactional session
state (``python -m repro.launch.serve --arch <id>-smoke``).

Every request's quota/accounting updates run as TStream state transactions
against shared session tables — concurrent request handlers never partition
or lock the session store (the paper's concurrent-state-access feature in
the serving plane).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.blotter import AppSpec
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.core.types import CORE_FUNS, make_store
from repro.models import decode_step, forward, init_cache, init_params

N_SESSIONS = 1024


def _session_store(**_):
    return make_store([N_SESSIONS], 2)  # lanes: [tokens_used, requests]


def _access(blt, eb):
    # debit the session's token quota; reject when exhausted (F_TAKE)
    blt.read_modify(0, eb["session"],
                    jnp.stack([eb["n_tokens"], -1.0]), "take")


QUOTA_APP = AppSpec(
    name="serve_quota", funs=CORE_FUNS, max_ops=1, width=2,
    make_store=_session_store, gen_events=lambda rng, n: {},
    pre_process=lambda ev: ev, state_access=_access,
    post_process=lambda eb, res: dict(admitted=res.success[0]),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-34b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    params = init_params(cfg, jax.random.key(0))
    max_seq = args.prompt_len + args.gen_len

    # transactional session accounting
    store = _session_store()
    quota = DualModeEngine(QUOTA_APP, store, EngineConfig())
    values = store.values.at[:, 0].set(1000.0)  # initial quota
    rng = np.random.default_rng(0)
    events = dict(
        session=jnp.asarray(rng.integers(0, N_SESSIONS, args.batch),
                            jnp.int32),
        n_tokens=jnp.full((args.batch,), float(max_seq), jnp.float32),
    )
    out, values, _ = quota.step(values, events, 0)
    print(f"[serve] admitted {int(np.sum(np.asarray(out['admitted'])))}"
          f"/{args.batch} requests (quota txns)")

    toks = jnp.asarray(rng.integers(0, cfg.vocab,
                                    (args.batch, args.prompt_len)),
                       jnp.int32)
    caches = init_cache(cfg, args.batch, max_seq)

    t0 = time.time()
    # prefill token-by-token through the decode path (simple, exact)
    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    tok = toks[:, :1]
    for i in range(args.prompt_len):
        logits, caches = step(params, caches, toks[:, i : i + 1],
                              jnp.int32(i))
    generated = []
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for i in range(args.gen_len):
        generated.append(np.asarray(tok[:, 0]))
        logits, caches = step(params, caches, tok,
                              jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen_len)
    print(f"[serve] {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on host)")
    gen = np.stack(generated, 1)
    assert gen.shape == (args.batch, args.gen_len)
    print(f"[serve] sample continuation: {gen[0][:8].tolist()}")


if __name__ == "__main__":
    main()
