import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run sweep driver: every (arch × shape × mesh) cell, resumable.

Each cell runs in-process sequentially; results are cached as JSON so the
sweep can restart.  Run:  PYTHONPATH=src python -m repro.launch.sweep
"""
import argparse
import json
import sys
import traceback

from repro.configs import ARCHS
from repro.models import SHAPES

# cheapest-first so early failures surface fast
ARCH_ORDER = [
    "minicpm-2b", "hubert-xlarge", "mamba2-2.7b", "zamba2-2.7b",
    "moonshot-v1-16b-a3b", "nemotron-4-15b", "granite-34b",
    "qwen2-vl-72b", "qwen1.5-110b", "deepseek-v3-671b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--only-multi-pod", action="store_true")
    ap.add_argument("--only-single-pod", action="store_true")
    ap.add_argument("--archs", default="")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    from repro.launch.dryrun import run_cell

    archs = args.archs.split(",") if args.archs else ARCH_ORDER
    meshes = [False, True]
    if args.only_multi_pod:
        meshes = [True]
    if args.only_single_pod:
        meshes = [False]

    for multi_pod in meshes:
        for arch in archs:
            for shape in SHAPE_ORDER:
                tag = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[sweep] skip cached {tag}", file=sys.stderr)
                    continue
                print(f"[sweep] running {tag}", file=sys.stderr, flush=True)
                try:
                    res = run_cell(arch, shape, multi_pod, verbose=False)
                except Exception as e:  # record failures, keep sweeping
                    res = dict(arch=arch, shape=shape, multi_pod=multi_pod,
                               error=f"{type(e).__name__}: {e}",
                               traceback=traceback.format_exc()[-2000:])
                    print(f"[sweep] FAILED {tag}: {e}", file=sys.stderr,
                          flush=True)
                with open(path, "w") as f:
                    json.dump(res, f, indent=2, default=float)
    print("[sweep] done", file=sys.stderr)


if __name__ == "__main__":
    main()
