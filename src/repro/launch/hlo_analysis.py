"""Trip-count-weighted HLO analysis.

XLA's ``compiled.cost_analysis()`` visits each instruction **once** — a
``lax.scan`` over 61 layers contributes its body FLOPs once, not 61×
(verified empirically).  For scanned-layer models that undercounts by ~L.
This module parses the post-SPMD HLO text, builds the computation call
graph, extracts while-loop trip counts from condition computations, and
accumulates, weighted by the product of enclosing trip counts:

  * dot/conv FLOPs (2 · result_elems · contraction_size)
  * collective bytes per kind + ring wire-bytes per device
  * bytes written (weighted instruction result sizes — an HBM-traffic
    proxy for the memory roofline term)
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
_CALLED_RE = re.compile(
    r"(condition|body|to_apply|calls|branch_computations)="
    r"(\{[^}]*\}|%[\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_WRITE = ("parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy-done", "after-all")


def _shape_info(shape_str: str) -> Tuple[int, List[List[int]]]:
    total = 0
    dims_out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        dims_out.append(ds)
    return total, dims_out


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    bytes_written: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    wire_bytes: float = 0.0
    children: List[str] = dataclasses.field(default_factory=list)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """name -> body lines.  Headers look like
    ``%region_0.2 (args...) -> shape {`` or ``ENTRY %main.4 (...) ... {``."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    buf: List[str] = []
    hdr = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = hdr.match(line.strip())
            if m and line.endswith("{"):
                cur = m.group(1)
                buf = []
        else:
            if line.strip() == "}":
                comps[cur] = buf
                cur = None
            else:
                buf.append(line)
    return comps


def _group_size(line: str, n_devices: int) -> int:
    g = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if g:
        return max(len(g.group(1).split(",")), 1)
    g2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if g2:
        return max(int(g2.group(2)), 1)
    return n_devices


def _parse_comp(lines: List[str], n_devices: int) -> CompStats:
    st = CompStats()
    shapes: Dict[str, List[List[int]]] = {}
    # first pass: instruction name -> result dims (for dot operand lookup)
    for line in lines:
        m = _INSTR_RE.match(line)
        if m:
            _, dims = _shape_info(m.group(2))
            shapes[m.group(1)] = dims

    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        res_bytes, res_dims = _shape_info(shape_str)
        if op == "dynamic-update-slice" and res_dims and res_dims[0]:
            # in-place under while-loop aliasing: only the update is written
            args = line[line.index("(", line.index("= ")) + 1:]
            ops_names = re.findall(r"%([\w\.\-]+)", args)
            upd = shapes.get(ops_names[1]) if len(ops_names) > 1 else None
            if upd and upd[0]:
                res_elems = 1
                for d in res_dims[0]:
                    res_elems *= d
                bpe = res_bytes / max(res_elems, 1)
                n = 1
                for d in upd[0]:
                    n *= d
                res_bytes = n * bpe
        if op not in _SKIP_WRITE:
            st.bytes_written += res_bytes

        if op in ("dot", "convolution"):
            res_elems = 1
            for d in (res_dims[0] if res_dims else []):
                res_elems *= d
            k = 1
            cm = _CONTRACT_RE.search(line)
            if cm and cm.group(1):
                # lhs operand name = first %name inside the parens
                args = line[line.index("(", line.index(op)) + 1:]
                ops_names = re.findall(r"%([\w\.\-]+)", args)
                lhs_dims = shapes.get(ops_names[0], [[]])[0] \
                    if ops_names and shapes.get(ops_names[0]) else []
                for c in (int(x) for x in cm.group(1).split(",") if x):
                    if c < len(lhs_dims):
                        k *= lhs_dims[c]
            elif op == "convolution":
                wm = re.search(r"window=\{size=([\dx]+)", line)
                if wm:
                    for w in wm.group(1).split("x"):
                        k *= int(w)
            st.dot_flops += 2.0 * res_elems * max(k, 1)

        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in _COLL_KINDS:
            b = res_bytes
            g = _group_size(line, n_devices)
            frac = (g - 1) / g
            st.coll_bytes[base_op] += b
            if base_op == "all-reduce":
                st.wire_bytes += 2 * b * frac
            elif base_op == "all-gather":
                st.wire_bytes += b * frac
            elif base_op == "reduce-scatter":
                st.wire_bytes += b * (g - 1)
            elif base_op == "all-to-all":
                st.wire_bytes += b * frac
            else:
                st.wire_bytes += b

        if " while(" in line:
            body = re.search(r"body=%?([\w\.\-]+)", line)
            cond = re.search(r"condition=%?([\w\.\-]+)", line)
            if body and cond:
                st.whiles.append((body.group(1), cond.group(1)))
            continue
        for key, val in _CALLED_RE.findall(line):
            for cname in re.findall(r"%?([\w\.\-]+)", val):
                st.children.append(cname)
    return st


def _trip_count(cond_lines: List[str]) -> int:
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def analyze_hlo(hlo: str, n_devices: int) -> Dict[str, float]:
    comps = _split_computations(hlo)
    stats = {name: _parse_comp(lines, n_devices)
             for name, lines in comps.items()}

    memo: Dict[str, Tuple[float, float, Dict[str, float], float]] = {}

    def total(name: str, depth=0):
        """(flops, bytes_written, coll_bytes, wire_bytes) for a computation.

        FLOPs/collectives accumulate through every edge (fusion calls +
        while bodies); bytes_written only through *control* edges (while
        bodies/conds + entry): instructions inside fusion computations stay
        in registers/VMEM and never touch HBM — only fusion results do.
        """
        if name in memo:
            return memo[name]
        if name not in stats or depth > 64:
            return (0.0, 0.0, {}, 0.0)
        st = stats[name]
        flops = st.dot_flops
        written = st.bytes_written
        coll = dict(st.coll_bytes)
        wire = st.wire_bytes

        def add(child, mult, control):
            nonlocal flops, written, wire
            cf, cw, cc, cwire = total(child, depth + 1)
            flops += cf * mult
            if control:
                written += cw * mult
            wire += cwire * mult
            for kk, vv in cc.items():
                coll[kk] = coll.get(kk, 0.0) + vv * mult

        for child in st.children:
            add(child, 1, control=False)
        for body, cond in st.whiles:
            trips = _trip_count(comps.get(cond, []))
            add(body, trips, control=True)
            add(cond, trips, control=True)
        memo[name] = (flops, written, coll, wire)
        return memo[name]

    entry = None
    m = re.search(r"ENTRY\s+%([\w\.\-]+)", hlo)
    if m:
        entry = m.group(1)
    if entry is None or entry not in stats:
        entry = max(stats, key=lambda n: stats[n].dot_flops, default=None)
    flops, written, coll, wire = total(entry) if entry else (0, 0, {}, 0)
    return dict(dot_flops=flops, bytes_written=written,
                coll_bytes=coll, wire_bytes_per_device=wire)


def breakdown(hlo: str, n_devices: int, top: int = 15):
    """Top contributors to bytes_written / wire bytes, trip-weighted —
    the §Perf profiling view (what to optimize next)."""
    comps = _split_computations(hlo)
    stats = {name: _parse_comp(lines, n_devices)
             for name, lines in comps.items()}
    trip: Dict[str, int] = {}
    parents: Dict[str, List[str]] = defaultdict(list)
    for name, st in stats.items():
        for body, cond in st.whiles:
            trip[body] = _trip_count(comps.get(cond, []))
            parents[body].append(name)

    def weight(name, depth=0) -> int:
        if depth > 16:
            return 1
        w = trip.get(name, 1)
        ps = parents.get(name, [])
        return w * (weight(ps[0], depth + 1) if ps else 1)

    rows = []
    for name, lines in comps.items():
        w = weight(name)
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, shape_str, op = m.groups()
            if op in _SKIP_WRITE:
                continue
            b, _ = _shape_info(shape_str)
            meta = re.search(r'op_name="([^"]+)"', line)
            rows.append((b * w, op, name[:40], iname[:40],
                         (meta.group(1)[-80:] if meta else "")))
    rows.sort(reverse=True)
    return rows[:top]
