import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the SPMD
partitioner must accept every sharding, the compiled module's
memory_analysis shows the per-device footprint, and cost_analysis +
HLO-collective parsing feed the roofline (EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch granite-34b --shape train_4k
  python -m repro.launch.dryrun --arch deepseek-v3-671b --shape decode_32k \
      --multi-pod --out results/
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from collections import defaultdict

import jax

from repro.configs import get_arch
from repro.models import SHAPE_BY_NAME, cell_is_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import StepConfig, build_step

# ---------------------------------------------------------------------------
# HLO collective parsing (collective bytes are NOT in cost_analysis)
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, n_devices: int):
    """Per-collective-kind byte totals + ring-algorithm wire-bytes estimate.

    The SPMD-partitioned HLO is a *per-device* program, so instruction
    result shapes are per-device buffer sizes.  Ring estimates per device:
      all-reduce       2·b·(g-1)/g          (b = operand == result bytes)
      all-gather       b_res·(g-1)/g        (b_res = gathered result)
      reduce-scatter   b_res·(g-1)          (b_res = scattered result)
      all-to-all       b·(g-1)/g
      collective-permute  b
    """
    per_kind = defaultdict(int)
    wire_per_device = 0.0
    count = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        if nbytes == 0:
            continue
        per_kind[kind] += nbytes
        count[kind] += 1
        g = _GROUPS_RE.search(line)
        if g:
            gsize = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_V2_RE.search(line)
            gsize = int(g2.group(2)) if g2 else n_devices
        gsize = max(gsize, 2)
        frac = (gsize - 1) / gsize
        if kind == "all-reduce":
            wire_per_device += 2 * nbytes * frac
        elif kind == "all-gather":
            wire_per_device += nbytes * frac
        elif kind == "reduce-scatter":
            wire_per_device += nbytes * (gsize - 1)
        elif kind == "all-to-all":
            wire_per_device += nbytes * frac
        else:  # collective-permute
            wire_per_device += nbytes
    return dict(per_kind=dict(per_kind), counts=dict(count),
                wire_per_device=wire_per_device)


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, remat="full",
             seq_shard=True, opt_bf16=True, kv_chunk=1024,
             expert_parallel=True, serving_head_pad=True, verbose=True):
    import jax.numpy as jnp
    from repro.optim import AdamWConfig

    cfg = get_arch(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    if not ok:
        return dict(arch=arch, shape=shape_name, multi_pod=multi_pod,
                    skipped=True, reason=why)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    step_cfg = StepConfig(
        remat=remat, seq_shard_acts=seq_shard, kv_chunk=kv_chunk,
        expert_parallel=expert_parallel, serving_head_pad=serving_head_pad,
        optimizer=AdamWConfig(
            state_dtype=jnp.bfloat16 if opt_bf16 else jnp.float32))

    t0 = time.time()
    with mesh:
        fn, specs = build_step(cfg, shape, mesh, step_cfg)
        lowered = fn.lower(*specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    from repro.launch.hlo_analysis import analyze_hlo
    weighted = analyze_hlo(hlo, n_dev)

    result = dict(
        arch=arch, shape=shape_name, multi_pod=multi_pod, skipped=False,
        n_devices=n_dev,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        # naive (loop bodies counted once) — kept for reference
        xla_flops=cost.get("flops", 0.0),
        xla_bytes_accessed=cost.get("bytes accessed", 0.0),
        # trip-count-weighted (per-device program; see hlo_analysis.py)
        hlo_flops=weighted["dot_flops"],
        hlo_bytes_written=weighted["bytes_written"],
        collective_bytes=weighted["coll_bytes"],
        wire_bytes_per_device=weighted["wire_bytes_per_device"],
        mem=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            peak_bytes=getattr(mem, "peak_memory_in_bytes",
                               mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes),
        ),
        model_params=cfg.param_count(),
        active_params=cfg.active_param_count(),
    )
    if verbose:
        print(json.dumps(result, indent=2, default=float))
        print(f"[dryrun] {arch} × {shape_name} × "
              f"{'multi-pod(2,16,16)' if multi_pod else 'single-pod(16,16)'} "
              f"COMPILED in {t_compile:.0f}s", file=sys.stderr)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--opt-fp32", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    result = run_cell(args.arch, args.shape, args.multi_pod,
                      remat=args.remat, seq_shard=not args.no_seq_shard,
                      opt_bf16=not args.opt_fp32)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = f"{args.arch}__{args.shape}__{'mp' if args.multi_pod else 'sp'}"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2, default=float)


if __name__ == "__main__":
    main()
