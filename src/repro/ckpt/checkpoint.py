"""Sharded checkpointing with mesh-independent restore (elastic restart).

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf
Leaves are addressed by their pytree key-path, so the manifest is
self-describing and restore works into any pytree with the same paths —
including a *different mesh* (``reshard``): values are loaded host-side and
re-placed under the target sharding.  This is the elastic-scaling path:
save on (16,16), resume on (2,16,16) or a shrunken mesh.

For real multi-host deployment each host would write only the shards it
owns (addressable_shards) — the manifest format already carries the
global shape, so the single-host writer here is the degenerate case.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    extra_meta: Optional[dict] = None) -> str:
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = dict(step=step, leaves={}, meta=extra_meta or {})
    for path, leaf in leaves:
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype == "bfloat16":  # numpy can't serialize ml_dtypes natively
            arr = arr.view(np.uint16)
        fname = re.sub(r"[^\w\-]", "_", key) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = dict(file=fname, dtype=dtype,
                                       shape=list(arr.shape))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # atomic publish: a crashed writer never yields a half checkpoint
    if os.path.exists(out):
        import shutil
        shutil.rmtree(out)
    os.rename(tmp, out)
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, target: PyTree,
                    shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``shardings``, device_put each leaf to its
    (possibly different-mesh) sharding — the reshard path."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), shard in zip(flat, shard_flat):
        key = _path_str(path)
        ent = manifest["leaves"].get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(src, ent["file"]))
        if ent["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {expect}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in out])


def reshard(ckpt_dir: str, step: int, target: PyTree, mesh,
            spec_fn) -> PyTree:
    """Load a checkpoint into a new mesh: ``spec_fn(target, mesh)`` returns
    the shardings pytree for the target on that mesh."""
    return load_checkpoint(ckpt_dir, step, target,
                           shardings=spec_fn(target, mesh))
