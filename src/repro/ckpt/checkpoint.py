"""Sharded checkpointing with mesh-independent restore (elastic restart).

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf
Leaves are addressed by their pytree key-path, so the manifest is
self-describing and restore works into any pytree with the same paths —
including a *different mesh* (``reshard``): values are loaded host-side and
re-placed under the target sharding.  This is the elastic-scaling path:
save on (16,16), resume on (2,16,16) or a shrunken mesh.

Durability + validity (DESIGN.md §2.7): every leaf file and the manifest
are fsync'd before the atomic rename and the manifest records each
leaf's byte size and CRC32, so

* a crashed writer leaves only a ``.tmp`` directory (or a manifest-less
  ``step_*`` debris dir) — both invisible to :func:`latest_step`;
* a torn or bit-rotted *published* snapshot is detected by
  :func:`verify_checkpoint` and skipped by :func:`latest_valid_step`,
  which is what lets a service resume fall back to the newest snapshot
  that actually verifies instead of dying on a corrupt latest.

For real multi-host deployment each host would write only the shards it
owns (addressable_shards) — the manifest format already carries the
global shape, so the single-host writer here is the degenerate case.
"""
from __future__ import annotations

import contextlib
import json
import os
import re
import shutil
import zlib
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _crc32_file(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            block = f.read(1 << 20)
            if not block:
                return crc
            crc = zlib.crc32(block, crc)


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:                      # platform without dir fds
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _span(tracer, name: str, **args):
    """Telemetry span when a tracer is attached (DESIGN.md §2.11), a
    no-op context otherwise — ckpt/ stays importable without the
    runtime telemetry module."""
    if tracer is None:
        return contextlib.nullcontext()
    return tracer.span(name, cat="ckpt", **args)


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    extra_meta: Optional[dict] = None,
                    tracer=None) -> str:
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = dict(step=step, leaves={}, meta=extra_meta or {})
    with _span(tracer, "snapshot.write", step=step, leaves=len(leaves)):
        for path, leaf in leaves:
            key = _path_str(path)
            arr = np.asarray(jax.device_get(leaf))
            dtype = str(arr.dtype)
            if dtype == "bfloat16":  # numpy can't serialize ml_dtypes natively
                arr = arr.view(np.uint16)
            fname = re.sub(r"[^\w\-]", "_", key) + ".npy"
            fpath = os.path.join(tmp, fname)
            with open(fpath, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][key] = dict(
                file=fname, dtype=dtype, shape=list(arr.shape),
                bytes=os.path.getsize(fpath), crc32=_crc32_file(fpath))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
    # atomic publish: a crashed writer never yields a half checkpoint —
    # every byte is durable before the rename makes the step visible
    with _span(tracer, "snapshot.rename", step=step):
        if os.path.exists(out):
            shutil.rmtree(out)
        os.rename(tmp, out)
        _fsync_dir(ckpt_dir)
    return out


def _read_manifest(ckpt_dir: str, step: int) -> Optional[dict]:
    """The step's manifest, or None if missing/unparseable (torn write)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def read_manifest_meta(ckpt_dir: str, step: int) -> Optional[dict]:
    """The ``extra_meta`` dict a snapshot was published with (or None for
    a missing/torn manifest).  This is the service's replay record: for
    adaptive runs it carries the controller decision trace + record
    window alongside ``intervals_done`` (DESIGN.md §2.9), so ``resume``
    can rebuild the plan without loading any leaf.

    Elastic runs additionally record ``ownership`` (owner count + the
    override list live at publish time, DESIGN.md §2.10).  It is
    informational: snapshot *values* are always written in canonical
    single-device layout, so restore re-derives the placement by
    replaying the decision trace and rebinds the engine to it — a
    snapshot taken under any placement restores onto any other."""
    manifest = _read_manifest(ckpt_dir, step)
    if manifest is None:
        return None
    return dict(manifest.get("meta") or {})


def checkpoint_steps(ckpt_dir: str) -> List[int]:
    """Published steps with a *readable* manifest, descending.

    A ``step_*`` directory without a parseable ``manifest.json`` is a
    crashed writer's debris and never shadows a good snapshot.
    """
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if m and _read_manifest(ckpt_dir, int(m.group(1))) is not None:
            steps.append(int(m.group(1)))
    return sorted(steps, reverse=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = checkpoint_steps(ckpt_dir)
    return steps[0] if steps else None


def verify_checkpoint(ckpt_dir: str, step: int) -> Tuple[bool, str]:
    """Cheap integrity check: manifest readable, every leaf present with
    the recorded byte size and CRC32.  Returns ``(ok, why)``; manifests
    written before checksums existed verify on presence alone."""
    manifest = _read_manifest(ckpt_dir, step)
    if manifest is None:
        return False, "manifest missing or unreadable"
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    for key, ent in manifest.get("leaves", {}).items():
        path = os.path.join(src, ent["file"])
        if not os.path.isfile(path):
            return False, f"leaf {key!r} missing"
        if "bytes" in ent and os.path.getsize(path) != ent["bytes"]:
            return False, (f"leaf {key!r} truncated: "
                           f"{os.path.getsize(path)} != {ent['bytes']}B")
        if "crc32" in ent and _crc32_file(path) != ent["crc32"]:
            return False, f"leaf {key!r} checksum mismatch"
    return True, "ok"


def latest_valid_step(ckpt_dir: str) -> Optional[int]:
    """Newest step that passes :func:`verify_checkpoint` — the recovery
    fallback order: a torn/corrupted latest never masks an older good
    snapshot."""
    for step in checkpoint_steps(ckpt_dir):
        if verify_checkpoint(ckpt_dir, step)[0]:
            return step
    return None


def prune_checkpoints(ckpt_dir: str, keep_last: int) -> List[int]:
    """Retention after atomic publish: keep the newest ``keep_last``
    ``step_*`` directories (by step number, readable or not — corrupt
    dirs age out too) and sweep stale ``.tmp`` writer debris.  Returns
    the removed steps."""
    if keep_last <= 0 or not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if m:
            steps.append(int(m.group(1)))
    steps.sort()
    removed = []
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
        removed.append(s)
    newest = steps[-1] if steps else None
    for d in os.listdir(ckpt_dir):
        m = re.match(r"^step_(\d+)\.tmp$", d)
        if m and newest is not None and int(m.group(1)) < newest:
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    return removed


def load_checkpoint(ckpt_dir: str, step: int, target: PyTree,
                    shardings: Optional[PyTree] = None,
                    verify: bool = False) -> PyTree:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``shardings``, device_put each leaf to its
    (possibly different-mesh) sharding — the reshard path.  With
    ``verify``, integrity-check the snapshot first and raise
    ``ValueError`` instead of loading damaged bytes."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    if verify:
        ok, why = verify_checkpoint(ckpt_dir, step)
        if not ok:
            raise ValueError(f"checkpoint step {step} fails verification: "
                             f"{why}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), shard in zip(flat, shard_flat):
        key = _path_str(path)
        ent = manifest["leaves"].get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(src, ent["file"]))
        if ent["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {expect}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in out])


def reshard(ckpt_dir: str, step: int, target: PyTree, mesh,
            spec_fn) -> PyTree:
    """Load a checkpoint into a new mesh: ``spec_fn(target, mesh)`` returns
    the shardings pytree for the target on that mesh."""
    return load_checkpoint(ckpt_dir, step, target,
                           shardings=spec_fn(target, mesh))
