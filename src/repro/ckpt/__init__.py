from .checkpoint import (checkpoint_steps, latest_step, latest_valid_step,
                         load_checkpoint, prune_checkpoints,
                         read_manifest_meta, reshard, save_checkpoint,
                         verify_checkpoint)
