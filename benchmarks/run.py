"""Benchmark driver: one module per paper figure + the roofline table.

Prints ``name,us_per_call,derived`` CSV rows (one per measurement), saves
the full JSON to results/bench/, and mirrors each module's rows to a
machine-readable ``BENCH_<name>.json`` at the repo root (perf trajectory
for successive PRs — DESIGN.md §8.3).
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-size workloads (slower)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (bit-rot canary)")
    ap.add_argument("--only", default="",
                    help="comma list: fig8,fig9,fig10,fig11,fig12,fig13,"
                         "fig14,roofline,fused_stream,sharded_stream,"
                         "restructure,service,adaptive,reshard")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from . import (adaptive_storm, fig8_throughput, fig9_breakdown,
                   fig10_multipartition, fig11_workload, fig12_interval,
                   fig13_latency, fig14_numa, fused_stream,
                   reshard_storm, restructure_bench, roofline,
                   service_latency, sharded_stream)
    modules = dict(fig8=fig8_throughput, fig9=fig9_breakdown,
                   fig10=fig10_multipartition, fig11=fig11_workload,
                   fig12=fig12_interval, fig13=fig13_latency,
                   fig14=fig14_numa, roofline=roofline,
                   fused_stream=fused_stream,
                   sharded_stream=sharded_stream,
                   restructure=restructure_bench,
                   service=service_latency,
                   adaptive=adaptive_storm,
                   reshard=reshard_storm)
    only = set(args.only.split(",")) if args.only else set(modules)

    os.makedirs("results/bench", exist_ok=True)
    all_rows = []
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if name not in only:
            continue
        kwargs = dict(quick=quick)
        if "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = args.smoke
        try:
            rows = mod.run(**kwargs)
        except Exception as e:
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            continue
        all_rows.extend(rows)
        for r in rows:
            us = r.get("wall_s",
                       r.get("median_wall_s",
                             r.get("measured_1dev_s",
                                   r.get("total_s",
                                         r.get("p99_latency_s", 0.0))))) * 1e6
            key = "/".join(str(r[k]) for k in
                           ("fig", "app", "scheme", "layout", "driver",
                            "arch", "shape", "width", "interval",
                            "mp_ratio", "mp_len", "read_ratio", "theta",
                            "mesh", "n_dev", "fused", "scenario", "plan",
                            "phase")
                           if k in r)
            derived = r.get("events_per_s",
                            r.get("roofline_frac",
                                  r.get("wire_bytes_per_device", "")))
            print(f"{key},{us:.1f},{derived}", flush=True)
        with open(f"results/bench/{name}.json", "w") as f:
            json.dump(rows, f, indent=2, default=str)
        with open(f"BENCH_{name}.json", "w") as f:
            json.dump(rows, f, indent=2, default=str)
    with open("results/bench/all.json", "w") as f:
        json.dump(all_rows, f, indent=2, default=str)


if __name__ == "__main__":
    main()
