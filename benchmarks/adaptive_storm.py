"""Adaptive control plane benchmark (DESIGN.md §2.9) — thin module shim.

The measurement lives in ``service_latency.run_adaptive`` (it shares the
service A/B machinery); registering it as its own module gives it its
own ``BENCH_adaptive.json`` trajectory file.  Rows carry ``plan``
(adaptive vs each static plan) and ``phase`` (per storm phase, plus an
aggregate ``"all"`` row), so adaptive/static comparisons interleave per
phase of the workload storm.
"""
from __future__ import annotations

from .service_latency import run_adaptive as run  # noqa: F401
