import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Fig. 14 worker (subprocess: needs 8 placeholder devices).

NUMA-aware configurations -> chain-shard layouts on a (socket=2, core=4)
mesh, on the **fused sharded streaming path** (DESIGN.md §2.5): for each
layout the whole stream runs as one owner-routed sharded program,
verified bit-for-bit against the single-device fused driver, with
exchange drop accounting surfaced (never silent).  The historical
replicate-everything per-batch ``evaluate_sharded`` is kept as the
baseline rows (verified against the sequential oracle), so the exchange
win is measured, not assumed.  Prints JSON per layout.
"""
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps import GS                                       # noqa: E402
from repro.core.blotter import build_opbatch                    # noqa: E402
from repro.core.engines import evaluate                         # noqa: E402
from repro.core.scheduler import DualModeEngine, EngineConfig   # noqa: E402
from repro.core.sharded import LAYOUTS, evaluate_sharded        # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo               # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("socket", "core"))
    rng = np.random.default_rng(14)
    store = GS.make_store()

    # ---- per-batch baseline (replicate-everything), oracle-verified -----
    events = {k: jnp.asarray(v) for k, v in GS.gen_events(rng, 512).items()}
    ops, _ = build_opbatch(GS, store, events, jnp.int32(0))
    _, oracle_vals, _ = evaluate(store, ops, GS.funs, "lock")
    oracle = np.asarray(oracle_vals)[:-1]

    # ---- fused sharded streaming, bit-checked vs single-device fused ----
    n_events, interval = 2048, 512
    stream = GS.gen_events(np.random.default_rng(15), n_events)
    ref = DualModeEngine(GS, store, EngineConfig())
    outs_ref, vals_ref = ref.run_stream(store.values, stream, interval,
                                        fused=True)

    out = {}
    for layout in LAYOUTS:
        with mesh:
            fn = jax.jit(lambda o: evaluate_sharded(store, o, GS.funs,
                                                    mesh, layout))
            lowered = fn.lower(ops)
            compiled = lowered.compile()
            res = analyze_hlo(compiled.as_text(), mesh.size)
            vals = np.asarray(jax.block_until_ready(fn(ops)))
            t0 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(fn(ops))
            secs = (time.perf_counter() - t0) / 3
        ok = bool(np.allclose(vals, oracle, rtol=1e-4, atol=1e-4))

        eng = DualModeEngine(GS, store, EngineConfig(), mesh=mesh,
                            layout=layout, exchange_slack=4.0)
        outs_s, vals_s = eng.run_stream(store.values, stream, interval)
        jax.block_until_ready(vals_s)
        t0 = time.perf_counter()
        for _ in range(3):
            outs_s, vals_s = eng.run_stream(store.values, stream, interval)
            jax.block_until_ready(vals_s)
        stream_secs = (time.perf_counter() - t0) / 3
        st = eng.last_exchange_stats
        bit_ok = bool(np.array_equal(np.asarray(vals_s),
                                     np.asarray(vals_ref)))
        for a, b in zip(outs_s, outs_ref):
            for k in a:
                bit_ok &= bool(np.array_equal(np.asarray(a[k]),
                                              np.asarray(b[k])))

        out[layout] = dict(
            correct=ok,
            wall_s=secs,
            coll_bytes=res["coll_bytes"],
            wire_bytes_per_device=res["wire_bytes_per_device"],
            fused_bit_identical=bit_ok,
            fused_wall_s=stream_secs,
            fused_events_per_s=n_events / stream_secs,
            fused_dropped=int(np.sum(st["dropped"])),
            fused_exchange_capacity=int(st["capacity"]),
        )
    print(json.dumps(out))


if __name__ == "__main__":
    main()
