import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Fig. 14 worker (subprocess: needs 8 placeholder devices).

Compiles the TStream engine under the three chain-shard layouts on a
(socket=2, core=4) mesh, verifies results against the oracle, and prints
per-layout collective bytes + measured wall time as JSON.
"""
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps import GS                                    # noqa: E402
from repro.core.blotter import build_opbatch                 # noqa: E402
from repro.core.engines import evaluate                      # noqa: E402
from repro.core.sharded import LAYOUTS, evaluate_sharded     # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo            # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("socket", "core"))
    rng = np.random.default_rng(14)
    store = GS.make_store()
    events = {k: jnp.asarray(v) for k, v in GS.gen_events(rng, 512).items()}
    ops, _ = build_opbatch(GS, store, events, jnp.int32(0))

    _, oracle_vals, _ = evaluate(store, ops, GS.funs, "lock")
    oracle = np.asarray(oracle_vals)[:-1]

    out = {}
    for layout in LAYOUTS:
        with mesh:
            fn = jax.jit(lambda o: evaluate_sharded(store, o, GS.funs,
                                                    mesh, layout))
            lowered = fn.lower(ops)
            compiled = lowered.compile()
            res = analyze_hlo(compiled.as_text(), mesh.size)
            vals = np.asarray(jax.block_until_ready(fn(ops)))
            import time
            t0 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(fn(ops))
            secs = (time.perf_counter() - t0) / 3
        ok = bool(np.allclose(vals, oracle, rtol=1e-4, atol=1e-4))
        out[layout] = dict(
            correct=ok,
            wall_s=secs,
            coll_bytes=res["coll_bytes"],
            wire_bytes_per_device=res["wire_bytes_per_device"],
        )
    print(json.dumps(out))


if __name__ == "__main__":
    main()
