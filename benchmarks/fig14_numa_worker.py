import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Fig. 14 worker (subprocess: needs 8 placeholder devices).

NUMA-aware configurations -> chain-shard layouts on a (socket=2, core=4)
mesh, on the **fused sharded streaming path** (DESIGN.md §2.5): for each
layout the whole stream runs as one owner-routed sharded program,
verified bit-for-bit against the single-device fused driver, with
exchange drop accounting surfaced (never silent).  The historical
replicate-everything per-batch ``evaluate_sharded`` is kept as the
baseline rows (verified against the sequential oracle), so the exchange
win is measured, not assumed.  Prints JSON per layout.
"""
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps import GS                                       # noqa: E402
from repro.core.blotter import build_opbatch                    # noqa: E402
from repro.core.engines import evaluate                         # noqa: E402
from repro.core.scheduler import DualModeEngine, EngineConfig   # noqa: E402
from repro.core.sharded import LAYOUTS, evaluate_sharded        # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo               # noqa: E402


# ---------------------------------------------------------------------------
# Skew-storm mode (``reshard`` argv): elastic resharding A/B (DESIGN.md §2.10)
# ---------------------------------------------------------------------------
# One seeded storm -- calm, a mild *aligned* ramp (the whole Zipf head
# collides on one ownership residue class), the theta=2.5 peak, calm --
# replayed under three provisioning policies:
#
#   static-slack8   worst-case capacity: never drops, big exchange shapes
#   static-slack2   lean capacity, no migration: overflow-drops in the storm
#   elastic-slack2  lean capacity + skew-aware migration: the ramp trips the
#                   controller before the peak lands, so it keeps slack-2
#                   shapes AND zero drops
#
# Per-phase aggregates exclude the first chunk and migration chunks (both
# pay an XLA compile; steady-state throughput is the claim — the one-time
# migration cost is reported separately on each row as ``migrations`` /
# ``apply_s``).

RESHARD_SIZES = dict(
    # interval, phase lengths (intervals), ramp theta, trigger, moves
    full=dict(interval=256, calm=4, ramp=6, peak=8, ramp_theta=0.2,
              imbalance=1.4, moves=64, lean=2.0),
    smoke=dict(interval=64, calm=2, ramp=4, peak=4, ramp_theta=0.6,
               imbalance=1.4, moves=24, lean=8.0),
)


def _storm_source(app, spec):
    from repro.core.intervals import PhasedReplaySource
    iv = spec["interval"]
    return PhasedReplaySource(
        app.gen_events,
        [(spec["calm"] * iv, {}),
         (spec["ramp"] * iv, dict(theta=spec["ramp_theta"], align_mod=8)),
         (spec["peak"] * iv, dict(theta=2.5, align_mod=8)),
         (spec["calm"] * iv, {})],
        seed=11, arrival_batch=128, jitter=4)


PHASE_NAMES = ("calm", "ramp", "peak", "cooldown")


def _reshard_run(app, store, mesh, spec, slack, elastic):
    from repro.core.intervals import WatermarkPolicy
    from repro.runtime.controller import ControllerConfig
    from repro.runtime.service import ServiceConfig, StreamService

    ctl = None
    if elastic:
        ctl = ControllerConfig(window=4, sustain=2, cooldown=4,
                               slack_widen=False,
                               reshard_imbalance=spec["imbalance"],
                               reshard_max_moves=spec["moves"])
    eng = DualModeEngine(app, store, EngineConfig(), mesh=mesh,
                         exchange_slack=slack)
    cfg = ServiceConfig(punct_interval=spec["interval"], chunk_intervals=2,
                        watermark=WatermarkPolicy(allowed_lateness=4),
                        chunk_record_ring=64, controller=ctl)
    src = _storm_source(app, spec)
    rec = StreamService(eng, cfg).run(src)
    trace_out = os.environ.get("RESHARD_TRACE_OUT")
    if elastic and trace_out:
        with open(trace_out, "w") as f:
            for d in rec.decisions:
                f.write(json.dumps(d) + "\n")

    place = rec.stats.get("placement") or {}
    migs = place.get("migrations", [])
    mig_g = {m["g"] for m in migs}
    phases = {}
    for c in rec.chunk_records:
        ph = src.phase_of_interval(c["g0"], spec["interval"])
        d = phases.setdefault(ph, dict(events=0, lat_s=0.0, drops=0,
                                       chunks=0))
        d["drops"] += int(c.get("x_drop", 0))
        # steady state only: skip the compile chunk + migration chunks
        if c["i"] == 0 or c["g0"] in mig_g:
            continue
        d["events"] += int(c["events"])
        d["lat_s"] += float(c["lat_s"])
        d["chunks"] += 1
    plan = (f"elastic-slack{slack:g}" if elastic
            else f"static-slack{slack:g}")
    shared = dict(plan=plan, slack=slack, elastic=elastic,
                  capacity=int(rec.stats["exchange"]["capacity"]),
                  migrations=len(migs),
                  moved_rows=int(place.get("moved_rows", 0)),
                  apply_s=float(sum(m["apply_s"] for m in migs)),
                  imbalance=place.get("imbalance"))
    rows = []
    for ph, d in sorted(phases.items()):
        rows.append(dict(shared, phase=PHASE_NAMES[ph],
                         events_per_s=(d["events"] / d["lat_s"]
                                       if d["lat_s"] else 0.0),
                         wall_s=d["lat_s"], chunks=d["chunks"],
                         drops=d["drops"]))
    rows.append(dict(shared, phase="all",
                     events_per_s=rec.sustained_events_per_s(),
                     wall_s=float(sum(c["lat_s"]
                                      for c in rec.chunk_records)),
                     chunks=len(rec.chunk_records),
                     drops=int(rec.stats["drops"]["exchange"])))
    return rows


def main_reshard(size):
    from repro.apps import GS
    spec = RESHARD_SIZES["smoke" if size == "smoke" else "full"]
    mesh = jax.make_mesh((8,), ("dev",))
    store = GS.make_store()
    lean = spec["lean"]
    rows = []
    rows += _reshard_run(GS, store, mesh, spec, 8.0, elastic=False)
    if lean != 8.0:
        rows += _reshard_run(GS, store, mesh, spec, lean, elastic=False)
    rows += _reshard_run(GS, store, mesh, spec, lean, elastic=True)
    print(json.dumps(rows))


def main():
    mesh = jax.make_mesh((2, 4), ("socket", "core"))
    rng = np.random.default_rng(14)
    store = GS.make_store()

    # ---- per-batch baseline (replicate-everything), oracle-verified -----
    events = {k: jnp.asarray(v) for k, v in GS.gen_events(rng, 512).items()}
    ops, _ = build_opbatch(GS, store, events, jnp.int32(0))
    _, oracle_vals, _ = evaluate(store, ops, GS.funs, "lock")
    oracle = np.asarray(oracle_vals)[:-1]

    # ---- fused sharded streaming, bit-checked vs single-device fused ----
    n_events, interval = 2048, 512
    stream = GS.gen_events(np.random.default_rng(15), n_events)
    ref = DualModeEngine(GS, store, EngineConfig())
    outs_ref, vals_ref = ref.run_stream(store.values, stream, interval,
                                        fused=True)

    out = {}
    for layout in LAYOUTS:
        with mesh:
            fn = jax.jit(lambda o: evaluate_sharded(store, o, GS.funs,
                                                    mesh, layout))
            lowered = fn.lower(ops)
            compiled = lowered.compile()
            res = analyze_hlo(compiled.as_text(), mesh.size)
            vals = np.asarray(jax.block_until_ready(fn(ops)))
            t0 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(fn(ops))
            secs = (time.perf_counter() - t0) / 3
        ok = bool(np.allclose(vals, oracle, rtol=1e-4, atol=1e-4))

        eng = DualModeEngine(GS, store, EngineConfig(), mesh=mesh,
                            layout=layout, exchange_slack=4.0)
        outs_s, vals_s = eng.run_stream(store.values, stream, interval)
        jax.block_until_ready(vals_s)
        t0 = time.perf_counter()
        for _ in range(3):
            outs_s, vals_s = eng.run_stream(store.values, stream, interval)
            jax.block_until_ready(vals_s)
        stream_secs = (time.perf_counter() - t0) / 3
        st = eng.last_exchange_stats
        bit_ok = bool(np.array_equal(np.asarray(vals_s),
                                     np.asarray(vals_ref)))
        for a, b in zip(outs_s, outs_ref):
            for k in a:
                bit_ok &= bool(np.array_equal(np.asarray(a[k]),
                                              np.asarray(b[k])))

        out[layout] = dict(
            correct=ok,
            wall_s=secs,
            coll_bytes=res["coll_bytes"],
            wire_bytes_per_device=res["wire_bytes_per_device"],
            fused_bit_identical=bit_ok,
            fused_wall_s=stream_secs,
            fused_events_per_s=n_events / stream_secs,
            fused_dropped=int(np.sum(st["dropped"])),
            fused_exchange_capacity=int(st["capacity"]),
        )
    print(json.dumps(out))


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "reshard":
        main_reshard(sys.argv[2] if len(sys.argv) > 2 else "quick")
    else:
        main()
