"""Fig. 12 analogue: punctuation-interval sweep — throughput & latency.

End-to-end latency per event (paper §VI-E definition): time from entering
the system to result.  With batch-synchronous intervals, an event waits for
the interval to fill (position wait, uniform over the interval at a given
arrival rate) plus the interval's processing time; 99th percentile ≈ fill
time + batch wall time.  All components measured on the real engine.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS
from repro.core.scheduler import DualModeEngine, EngineConfig

from .common import engine_stats, modeled_time, stream_wall_time_pair

WIDTH = 40
STREAM_INTERVALS = 8   # intervals per measured end-to-end stream


def run(quick: bool = True):
    rows = []
    intervals = [100, 250, 500, 1000] if quick else [50, 100, 250, 500,
                                                     1000, 2000]
    for name in ["gs", "tp"] if quick else list(ALL_APPS):
        app = ALL_APPS[name]
        for interval in intervals:
            rng = np.random.default_rng(14)
            store = app.make_store()
            events = {k: jnp.asarray(v)
                      for k, v in app.gen_events(rng, interval).items()}
            stats, secs, _ = engine_stats(app, store, events, "tstream")
            stats_l, secs_l, _ = engine_stats(app, store, events, "lock")
            t_op = secs_l / max(float(stats_l.rounds), 1.0)
            t_batch = modeled_time(stats, "tstream", WIDTH, interval, t_op)
            tput = interval / t_batch
            # p99 latency: arrive early in the interval -> wait ~full fill
            fill = interval / max(tput, 1e-9)
            p99 = 0.99 * fill + t_batch
            # end-to-end stream wall time: fused scan vs per-interval loop
            # (the paper's per-interval overhead lever, DESIGN.md §2.4)
            n_events = interval * STREAM_INTERVALS
            stream = app.gen_events(np.random.default_rng(14), n_events)
            eng = DualModeEngine(app, store, EngineConfig(scheme="tstream"))
            (secs_u, _), (secs_f, _) = stream_wall_time_pair(
                eng, store.values, stream, interval, iters=3)
            rows.append(dict(fig="fig12", app=name, interval=interval,
                             events_per_s=tput, p99_latency_s=p99,
                             measured_batch_s=secs,
                             stream_fused_s=secs_f,
                             stream_unfused_s=secs_u,
                             stream_fused_events_per_s=n_events / secs_f,
                             stream_unfused_events_per_s=n_events / secs_u,
                             fused_speedup=secs_u / secs_f))
    return rows
