"""Fig. 12 analogue: punctuation-interval sweep — throughput & latency.

End-to-end latency per event (paper §VI-E definition): time from entering
the system to result.  With batch-synchronous intervals, an event waits for
the interval to fill (position wait, uniform over the interval at a given
arrival rate) plus the interval's processing time; 99th percentile ≈ fill
time + batch wall time.  All components measured on the real engine.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS

from .common import engine_stats, modeled_time, throughput_model

WIDTH = 40


def run(quick: bool = True):
    rows = []
    intervals = [100, 250, 500, 1000] if quick else [50, 100, 250, 500,
                                                     1000, 2000]
    for name in ["gs", "tp"] if quick else list(ALL_APPS):
        app = ALL_APPS[name]
        for interval in intervals:
            rng = np.random.default_rng(14)
            store = app.make_store()
            events = {k: jnp.asarray(v)
                      for k, v in app.gen_events(rng, interval).items()}
            stats, secs, _ = engine_stats(app, store, events, "tstream")
            stats_l, secs_l, _ = engine_stats(app, store, events, "lock")
            t_op = secs_l / max(float(stats_l.rounds), 1.0)
            t_batch = modeled_time(stats, "tstream", WIDTH, interval, t_op)
            tput = interval / t_batch
            # p99 latency: arrive early in the interval -> wait ~full fill
            fill = interval / max(tput, 1e-9)
            p99 = 0.99 * fill + t_batch
            rows.append(dict(fig="fig12", app=name, interval=interval,
                             events_per_s=tput, p99_latency_s=p99,
                             measured_batch_s=secs))
    return rows
