"""Measured service latency — supersedes the modeled Fig. 13.

The old fig13 rows *model* p99 latency from the executor cost model
(``p99 ≈ 0.99·fill + batch``); this module **measures** per-event
end-to-end latency through the continuous service runtime
(DESIGN.md §2.6): enqueue timestamp at arrival admission → interval-commit
timestamp after post-processing + D2H.  Rows report p50/p99 per
(app, scheme, interval) plus the sustained service throughput next to the
batch fused driver's throughput on the same events (the acceptance bar:
steady state within 10% of the batch driver at interval 512).  Service
and batch runs are **interleaved** and summarized by their best
iteration, the same A/B protocol as ``stream_wall_time_pair``
(DESIGN.md §8.3) — machine-load drift lands on both sides equally.  The
superseded modeled fig13 rows are re-emitted side-by-side
(``driver="modeled"``).  Lands in ``BENCH_service.json`` via
``benchmarks/run.py``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.apps import ALL_APPS
from repro.core.intervals import ReplaySource, WatermarkPolicy
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.runtime.service import ServiceConfig, StreamService
from repro.runtime.telemetry import counter_value, histogram_from


def _telemetry_row(rec):
    """Latency percentiles + drop counters read from the run's telemetry
    snapshot (DESIGN.md §2.11) — the schema is the source of truth; the
    registry's deterministic log-bucketed histogram replaces re-deriving
    percentiles from the raw per-event array."""
    snap = rec.telemetry.snapshot()
    h = histogram_from(snap, "latency.event_s")
    return dict(
        p50_latency_s=h.percentile(50), p99_latency_s=h.percentile(99),
        late_rerouted=int(counter_value(snap, "service.late_rerouted")),
        drops=dict(
            watermark=int(counter_value(snap, "service.drops",
                                        kind="watermark")),
            admission=int(counter_value(snap, "service.drops",
                                        kind="admission")),
            exchange=int(counter_value(snap, "service.drops",
                                       kind="exchange"))),
        telemetry_schema=(snap["schema"], snap["schema_version"]))


def _cases(quick: bool, smoke: bool):
    # (app, scheme, interval, n_intervals, chunk)
    if smoke:   # CI bit-rot canary
        return [("gs", "tstream", 64, 8, 4)]
    if quick:
        return [
            # acceptance case: enough intervals that the pipeline fill /
            # drain edges amortize out of the steady-state measurement
            ("gs", "tstream", 512, 64, 8),
            ("gs", "tstream", 128, 64, 8),
            ("tp", "tstream", 512, 32, 8),
            ("sl", "tstream", 256, 24, 8),   # gated lockstep path
            ("gs", "mvlk", 256, 24, 8),
        ]
    return [(a, s, i, 48, 8) for a in ALL_APPS for s in ("tstream", "mvlk")
            for i in (128, 512, 1024)]


def run(quick: bool = True, smoke: bool = False):
    rows = []
    iters = 2 if smoke else 7
    for app_name, scheme, interval, n_intervals, chunk in _cases(quick,
                                                                 smoke):
        app = ALL_APPS[app_name]
        n_events = interval * n_intervals
        jitter = max(1, interval // 8)
        mk = lambda: ReplaySource(app.gen_events, n_events, seed=23,
                                  arrival_batch=interval, jitter=jitter)
        store = app.make_store()
        eng = DualModeEngine(app, store, EngineConfig(scheme=scheme))
        svc = StreamService(eng, ServiceConfig(
            punct_interval=interval, chunk_intervals=chunk,
            queue_intervals=2 * chunk,
            watermark=WatermarkPolicy(allowed_lateness=jitter)))
        batch_events = mk().in_order_events

        def batch_once():
            t0 = time.perf_counter()
            outs, vals = eng.run_stream(store.values, batch_events, interval,
                                        fused=True)
            jax.block_until_ready(vals)
            return time.perf_counter() - t0

        svc.run(mk())                   # warm the chunk compilations
        batch_once()                    # warm the monolithic compilation
        best_rec, best_eps, batch_best_s = None, 0.0, float("inf")
        for _ in range(iters):          # interleaved A/B
            rec = svc.run(mk())
            eps = rec.sustained_events_per_s()
            if eps > best_eps:
                best_rec, best_eps = rec, eps
            batch_best_s = min(batch_best_s, batch_once())
        batch_eps = n_events / batch_best_s
        rows.append(dict(
            fig="service", driver="service", app=app_name, scheme=scheme,
            interval=interval, n_events=n_events, chunk_intervals=chunk,
            events_per_s=best_eps, batch_events_per_s=batch_eps,
            service_vs_batch=best_eps / batch_eps,
            **_telemetry_row(best_rec),
        ))
    if not smoke:
        # the superseded modeled rows, side-by-side for comparison
        from .fig13_latency import run as modeled_run
        for r in modeled_run(quick=quick):
            rows.append(dict(r, fig="service", driver="modeled",
                             interval=500))
    return rows


# ---------------------------------------------------------------------------
# adaptive control plane: workload-storm A/B (DESIGN.md §2.9)
# ---------------------------------------------------------------------------
def _storm_phases(interval: int, per: int):
    """Mid-run key-skew flip + multi-partition burst + conflict storm,
    bracketed by calm phases — the drill the controller is built for."""
    return [
        (per * interval, dict(theta=0.2)),
        (per * interval, dict(theta=2.5)),                       # skew flip
        (per * interval, dict(theta=0.2, n_partitions=16,
                              mp_ratio=0.9, mp_len=8)),          # MP burst
        (per * interval, dict(theta=0.2)),
    ]


def _phase_rows(rec, src, interval, base):
    """Per-phase p99 + throughput from one run's commit records — the
    interleaved A/B rows (adaptive vs static plans, per storm phase).
    A phase's span runs from the last commit *before* it (stream start
    for phase 0) to its own last commit, so a phase processed as one big
    chunk still gets a finite rate — the same accounting for every plan,
    so the A/B comparison stays fair."""
    from collections import defaultdict
    per = defaultdict(list)
    for idx, c in enumerate(rec.commits):
        per[src.phase_of_interval(c["interval"], interval)].append(idx)
    rows = []
    prev_t = rec.t_first_enqueue
    for p in sorted(per):
        idxs = per[p]
        lat = np.concatenate([rec.latencies[i] for i in idxs])
        t_last = max(rec.commits[i]["commit_s"] for i in idxs)
        span = t_last - prev_t
        prev_t = t_last
        rows.append(dict(base, phase=p, n_events=lat.size,
                         p99_latency_s=float(np.percentile(lat, 99)),
                         events_per_s=(len(idxs) * interval / span
                                       if span > 0 else 0.0)))
    return rows


def run_adaptive(quick: bool = True, smoke: bool = False):
    """Adaptive controller vs static plans through a workload storm, and
    the gs@128 chunk-size adaptation case.  Lands in
    ``BENCH_adaptive.json``; every row carries ``plan`` + ``phase`` so
    adaptive and static rows interleave per phase."""
    from repro.core.intervals import PhasedReplaySource
    from repro.runtime.controller import ControllerConfig

    rows = []
    app = ALL_APPS["gs"]
    iters = 2 if smoke else 4

    def measure(name, interval, phases, plans, batch_ref=False,
                arrival_batch=None, queue=48):
        src_fn = lambda: PhasedReplaySource(
            app.gen_events, phases, seed=23,
            arrival_batch=arrival_batch or 4 * interval,
            jitter=max(1, interval // 8))
        n_events = sum(n for n, _ in phases)
        store = app.make_store()
        eng = DualModeEngine(app, store, EngineConfig(scheme="tstream"))
        batch_eps = 0.0
        if batch_ref:
            ev = src_fn().in_order_events
            t_best = float("inf")
            for _ in range(iters + 1):
                t0 = time.perf_counter()
                _, vals = eng.run_stream(store.values, ev, interval,
                                         fused=True)
                jax.block_until_ready(vals)
                t_best = min(t_best, time.perf_counter() - t0)
            batch_eps = n_events / t_best
        svcs = {
            pname: StreamService(eng, ServiceConfig(
                punct_interval=interval, chunk_intervals=chunk,
                queue_intervals=queue, controller=ctl,
                watermark=WatermarkPolicy(
                    allowed_lateness=max(1, interval // 8))))
            for pname, (chunk, ctl) in plans.items()}
        for svc in svcs.values():               # warm every compilation
            svc.run(src_fn())
        best = {}
        for _ in range(iters):                  # interleaved A/B
            for pname, svc in svcs.items():
                rec = svc.run(src_fn())
                eps = rec.sustained_events_per_s()
                if pname not in best or eps > best[pname][1]:
                    best[pname] = (rec, eps)
        for pname, (rec, eps) in best.items():
            tr = _telemetry_row(rec)
            base = dict(fig="adaptive", scenario=name, app="gs",
                        scheme="tstream", interval=interval, plan=pname)
            row = dict(base, phase="all", n_events=n_events,
                       p50_latency_s=tr["p50_latency_s"],
                       p99_latency_s=tr["p99_latency_s"],
                       events_per_s=eps,
                       decisions=[dict(d) for d in rec.decisions],
                       final_chunk=(rec.stats["controller"]["plan"]["chunk"]
                                    if "controller" in rec.stats
                                    else rec.stats["chunks"][-1]["k"]))
            if batch_ref:
                row.update(batch_events_per_s=batch_eps,
                           service_vs_batch=eps / batch_eps)
            rows.append(row)
            rows.extend(_phase_rows(rec, src_fn(), interval, base))

    # a benchmark controller wants adaptation *speed* over hysteresis
    # margin (the property suite pins the hysteresis contract): one
    # backlogged record is enough evidence to climb the K ladder
    k_ctl = lambda ladder: ControllerConfig(
        window=2, sustain=1, cooldown=1, degrade_scheme="",
        chunk_ladder=ladder, backlog_grow=1.25)

    if smoke:
        measure("storm", 32, _storm_phases(32, 4),
                {"adaptive": (2, k_ctl((2, 4, 8))), "static-K2": (2, None)})
        return rows

    # the workload storm: adaptive K vs the static endpoints of its ladder
    per = 16 if quick else 32
    measure("storm", 64, _storm_phases(64, per),
            {"adaptive": (2, k_ctl((2, 4, 8, 16))),
             "static-K2": (2, None), "static-K16": (16, None)})

    # the gs@128 case (BENCH_service.json: 0.49x of batch at K=8): grow K
    # under backlog to amortize per-dispatch cost back toward batch rate
    # a big arrival batch keeps the backlog signal (qfill at submit)
    # visibly above the ladder rung so growth does not stall mid-ladder;
    # the run must outlast the ladder ramp (each rung needs ~2 chunks of
    # fresh records at the new K before the next climb) by enough that
    # the steady state at the top rung dominates the measurement
    n_iv = 128 if quick else 192
    measure("gs128", 128, [(128 * n_iv, dict(theta=0.6))],
            {"adaptive": (8, k_ctl((8, 16, 32))),
             "static-K8": (8, None), "static-K32": (32, None)},
            batch_ref=True, arrival_batch=16 * 128)
    return rows
