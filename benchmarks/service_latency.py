"""Measured service latency — supersedes the modeled Fig. 13.

The old fig13 rows *model* p99 latency from the executor cost model
(``p99 ≈ 0.99·fill + batch``); this module **measures** per-event
end-to-end latency through the continuous service runtime
(DESIGN.md §2.6): enqueue timestamp at arrival admission → interval-commit
timestamp after post-processing + D2H.  Rows report p50/p99 per
(app, scheme, interval) plus the sustained service throughput next to the
batch fused driver's throughput on the same events (the acceptance bar:
steady state within 10% of the batch driver at interval 512).  Service
and batch runs are **interleaved** and summarized by their best
iteration, the same A/B protocol as ``stream_wall_time_pair``
(DESIGN.md §8.3) — machine-load drift lands on both sides equally.  The
superseded modeled fig13 rows are re-emitted side-by-side
(``driver="modeled"``).  Lands in ``BENCH_service.json`` via
``benchmarks/run.py``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.apps import ALL_APPS
from repro.core.intervals import ReplaySource, WatermarkPolicy
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.runtime.service import ServiceConfig, StreamService


def _cases(quick: bool, smoke: bool):
    # (app, scheme, interval, n_intervals, chunk)
    if smoke:   # CI bit-rot canary
        return [("gs", "tstream", 64, 8, 4)]
    if quick:
        return [
            # acceptance case: enough intervals that the pipeline fill /
            # drain edges amortize out of the steady-state measurement
            ("gs", "tstream", 512, 64, 8),
            ("gs", "tstream", 128, 64, 8),
            ("tp", "tstream", 512, 32, 8),
            ("sl", "tstream", 256, 24, 8),   # gated lockstep path
            ("gs", "mvlk", 256, 24, 8),
        ]
    return [(a, s, i, 48, 8) for a in ALL_APPS for s in ("tstream", "mvlk")
            for i in (128, 512, 1024)]


def run(quick: bool = True, smoke: bool = False):
    rows = []
    iters = 2 if smoke else 7
    for app_name, scheme, interval, n_intervals, chunk in _cases(quick,
                                                                 smoke):
        app = ALL_APPS[app_name]
        n_events = interval * n_intervals
        jitter = max(1, interval // 8)
        mk = lambda: ReplaySource(app.gen_events, n_events, seed=23,
                                  arrival_batch=interval, jitter=jitter)
        store = app.make_store()
        eng = DualModeEngine(app, store, EngineConfig(scheme=scheme))
        svc = StreamService(eng, ServiceConfig(
            punct_interval=interval, chunk_intervals=chunk,
            queue_intervals=2 * chunk,
            watermark=WatermarkPolicy(allowed_lateness=jitter)))
        batch_events = mk().in_order_events

        def batch_once():
            t0 = time.perf_counter()
            outs, vals = eng.run_stream(store.values, batch_events, interval,
                                        fused=True)
            jax.block_until_ready(vals)
            return time.perf_counter() - t0

        svc.run(mk())                   # warm the chunk compilations
        batch_once()                    # warm the monolithic compilation
        best_rec, best_eps, batch_best_s = None, 0.0, float("inf")
        for _ in range(iters):          # interleaved A/B
            rec = svc.run(mk())
            eps = rec.sustained_events_per_s()
            if eps > best_eps:
                best_rec, best_eps = rec, eps
            batch_best_s = min(batch_best_s, batch_once())
        pct = best_rec.latency_percentiles((50, 99))
        batch_eps = n_events / batch_best_s
        rows.append(dict(
            fig="service", driver="service", app=app_name, scheme=scheme,
            interval=interval, n_events=n_events, chunk_intervals=chunk,
            p50_latency_s=pct["p50"], p99_latency_s=pct["p99"],
            events_per_s=best_eps, batch_events_per_s=batch_eps,
            service_vs_batch=best_eps / batch_eps,
            late_rerouted=best_rec.stats["late_rerouted"],
            drops=best_rec.stats["drops"],
        ))
    if not smoke:
        # the superseded modeled rows, side-by-side for comparison
        from .fig13_latency import run as modeled_run
        for r in modeled_run(quick=quick):
            rows.append(dict(r, fig="service", driver="modeled",
                             interval=500))
    return rows
