"""Fig. 8 analogue: throughput per application × scheme × executor width."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS

from .common import throughput_model

SCHEMES = ["tstream", "lock", "mvlk", "pat", "nolock"]
WIDTHS = [1, 2, 4, 8, 16, 32, 40]


def run(quick: bool = True):
    n_events = 500 if quick else 2000
    rows = []
    for name, app in ALL_APPS.items():
        rng = np.random.default_rng(8)
        store = app.make_store()
        events = {k: jnp.asarray(v)
                  for k, v in app.gen_events(rng, n_events).items()}
        res = throughput_model(app, store, events, SCHEMES, WIDTHS)
        for scheme, d in res.items():
            for w, tput in d["by_width"].items():
                rows.append(dict(fig="fig8", app=name, scheme=scheme,
                                 width=w, events_per_s=tput,
                                 measured_1dev_s=d["measured_1dev_s"],
                                 rounds=d["rounds"]))
    return rows
