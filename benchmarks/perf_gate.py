"""Perf gate: fresh smoke bench vs the committed baseline (DESIGN.md §8.3).

Runs the same tiny smoke cells as CI's bench-smoke job (``fused_stream``
and ``restructure`` with ``smoke=True`` — seconds, not minutes) in
process, plus the elastic-resharding storm's smoke cells (peak-phase and
aggregate rows per plan, so a migration-path slowdown shows up in the
gate), matches rows against ``benchmarks/baselines/perf_gate_smoke.json``
by their identifying fields, and reports per-row deltas on the min-wall
estimator.

A row REGRESSES when it is both >``--tolerance`` (default 25%) slower
than baseline AND the absolute delta clears ``--abs-floor-us`` (default
200µs) — the smoke cells are sub-millisecond and jitter by tens of
percent under external load, so a relative threshold alone would cry
wolf.  New rows and rows missing from the fresh run are
reported, never failed.  A baseline recorded on a different
``device_kind`` downgrades every verdict to informational: cross-machine
deltas measure the machines, not the change.

Exit status is 0 unless ``--strict`` is passed AND comparable regressions
exist — CI wires this as a non-blocking report job
(``continue-on-error``), so a regression annotates the PR without
blocking it.  Refresh the committed baseline with ``--update-baseline``
after an intentional perf change (on the CI machine class).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "perf_gate_smoke.json")

# identifying fields (everything measured — wall_s etc. — is excluded);
# together these are unique across the smoke modules' rows (``plan`` and
# ``phase`` identify the elastic-resharding storm cells)
KEY_FIELDS = ("fig", "kind", "app", "scheme", "layout", "interval",
              "n", "n_slots", "n_route", "shape", "fused", "plan",
              "phase")
METRIC = "wall_s"


def row_key(row: dict) -> str:
    return "/".join(f"{k}={row[k]}" for k in KEY_FIELDS if k in row)


def run_smoke(passes: int = 2) -> List[dict]:
    """The bench-smoke cells, in process (fresh side of the A/B).

    Runs the whole suite ``passes`` times and keeps the per-row minimum
    — the smoke cells are sub-millisecond, where a single min-of-3 still
    jitters by tens of percent under external load."""
    from . import fused_stream, reshard_storm, restructure_bench
    best: Dict[str, dict] = {}
    for _ in range(max(1, passes)):
        rows = []
        rows += fused_stream.run(quick=True, smoke=True)
        rows += restructure_bench.run(quick=True, smoke=True)
        for r in rows:
            if METRIC not in r:
                continue
            k = row_key(r)
            if k not in best or r[METRIC] < best[k][METRIC]:
                best[k] = r
    # elastic-resharding storm cells: seconds-scale service runs (one
    # pass — the min-of-N treatment is for the sub-millisecond cells);
    # keep the peak-phase and aggregate rows per plan
    for r in reshard_storm.run(quick=True, smoke=True):
        if METRIC in r and r.get("phase") in ("peak", "all") \
                and r[METRIC] > 0:
            best[row_key(r)] = r
    return list(best.values())


def device_kind() -> str:
    try:
        import jax
        return str(jax.devices()[0].device_kind)
    except Exception:
        return "unknown"


def cost_report() -> List[dict]:
    """Per-chunk-shape HLO cost attribution + roofline annotation for the
    smoke service cell (DESIGN.md §2.11): runs one telemetry-enabled
    service pass with ``hlo_attribution`` on and reads the achieved
    flops/bytes/bound fractions off the ``chunk.execute`` spans — the
    same numbers the execute spans carry in a production trace."""
    import json as _json
    import tempfile

    from repro.apps import ALL_APPS
    from repro.core.intervals import ReplaySource, WatermarkPolicy
    from repro.core.scheduler import DualModeEngine, EngineConfig
    from repro.runtime.service import ServiceConfig, StreamService
    from repro.runtime.telemetry import TelemetryConfig

    app = ALL_APPS["gs"]
    interval, n_iv, chunk = 64, 8, 4
    src = ReplaySource(app.gen_events, interval * n_iv, seed=23,
                       arrival_batch=interval, jitter=max(1, interval // 8))
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig(scheme="tstream"))
    with tempfile.TemporaryDirectory() as tmp:
        trace = os.path.join(tmp, "trace.json")
        svc = StreamService(eng, ServiceConfig(
            punct_interval=interval, chunk_intervals=chunk,
            watermark=WatermarkPolicy(allowed_lateness=interval // 8),
            telemetry=TelemetryConfig(trace_path=trace,
                                      hlo_attribution=True)))
        svc.run(src)
        with open(trace) as f:
            text = f.read().strip()
        if not text.endswith("]"):
            text += "]"
        events = _json.loads(text)
    rows = []
    for ev in events:
        a = ev.get("args", {})
        if ev.get("name") == "chunk.execute" and "flops" in a:
            rows.append(dict(
                fig="perf_gate_cost", app="gs", scheme="tstream",
                interval=interval, k=a.get("k"),
                flops=a["flops"], bytes_written=a["bytes_written"],
                gflops_s=a["gflops_s"], gbytes_s=a["gbytes_s"],
                frac_compute=a["frac_compute"],
                frac_memory=a["frac_memory"], bound=a["bound"]))
    return rows


def compare(base: dict, fresh_rows: List[dict], *, tolerance: float,
            abs_floor_s: float) -> Tuple[List[dict], bool]:
    """Per-row verdicts + whether the comparison is device-comparable."""
    comparable = base.get("meta", {}).get("device_kind") == device_kind()
    base_by_key = {row_key(r): r for r in base.get("rows", [])}
    fresh_by_key = {row_key(r): r for r in fresh_rows}
    verdicts = []
    for key, fr in fresh_by_key.items():
        br = base_by_key.get(key)
        if br is None:
            verdicts.append(dict(key=key, verdict="new",
                                 fresh_s=fr[METRIC]))
            continue
        b, f = float(br[METRIC]), float(fr[METRIC])
        ratio = f / b if b > 0 else float("inf")
        regressed = (ratio > 1.0 + tolerance) and (f - b > abs_floor_s)
        improved = (ratio < 1.0 - tolerance) and (b - f > abs_floor_s)
        verdicts.append(dict(
            key=key, base_s=b, fresh_s=f, ratio=ratio,
            verdict=("regressed" if regressed else
                     "improved" if improved else "ok")))
    for key in base_by_key.keys() - fresh_by_key.keys():
        verdicts.append(dict(key=key, verdict="missing",
                             base_s=base_by_key[key][METRIC]))
    return verdicts, comparable


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--baseline", default=BASELINE)
    p.add_argument("--tolerance", type=float, default=0.25,
                   help="relative slowdown that counts as a regression")
    p.add_argument("--abs-floor-us", type=float, default=200.0,
                   help="absolute slowdown floor (noise guard)")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on comparable regressions")
    p.add_argument("--update-baseline", action="store_true",
                   help="record the fresh run as the new baseline")
    p.add_argument("--out", default=None,
                   help="write the verdict report JSON here")
    p.add_argument("--cost", action="store_true",
                   help="append per-chunk HLO flops/bytes cost attribution "
                        "+ roofline annotation (telemetry execute spans)")
    args = p.parse_args(argv)

    costs = []
    if args.cost:
        costs = cost_report()
        print("perf-gate cost attribution (chunk.execute spans):")
        for c in costs:
            print(f"  k={c['k']}: {c['flops']:.2e} flops, "
                  f"{c['bytes_written']:.2e} B written, "
                  f"{c['gflops_s']:.2f} GF/s, {c['gbytes_s']:.2f} GB/s, "
                  f"bound={c['bound']} "
                  f"(compute {c['frac_compute']:.4f} / "
                  f"memory {c['frac_memory']:.4f} of peak)")
        if not costs:
            print("  (no attributed execute spans — attribution failed?)")

    fresh = run_smoke()
    if args.update_baseline:
        payload = dict(meta=dict(device_kind=device_kind(),
                                 metric=METRIC, key_fields=KEY_FIELDS),
                       rows=fresh)
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"perf-gate: baseline updated ({len(fresh)} rows, "
              f"device_kind={payload['meta']['device_kind']!r}) -> "
              f"{args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"perf-gate: no baseline at {args.baseline} — run with "
              f"--update-baseline to record one (reporting fresh only)")
        for r in fresh:
            print(f"  {row_key(r)}: {r[METRIC] * 1e6:.1f}us")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)
    verdicts, comparable = compare(
        base, fresh, tolerance=args.tolerance,
        abs_floor_s=args.abs_floor_us * 1e-6)
    n_reg = sum(v["verdict"] == "regressed" for v in verdicts)
    if not comparable:
        print(f"perf-gate: baseline device_kind="
              f"{base.get('meta', {}).get('device_kind')!r} != current "
              f"{device_kind()!r} — verdicts are informational only")
    for v in sorted(verdicts, key=lambda v: v["key"]):
        if v["verdict"] in ("new", "missing"):
            print(f"  [{v['verdict'].upper():9s}] {v['key']}")
        else:
            print(f"  [{v['verdict'].upper():9s}] {v['key']}: "
                  f"{v['base_s'] * 1e6:.1f}us -> {v['fresh_s'] * 1e6:.1f}us "
                  f"({v['ratio']:.2f}x)")
    summary = dict(
        comparable=comparable, regressed=n_reg,
        improved=sum(v["verdict"] == "improved" for v in verdicts),
        ok=sum(v["verdict"] == "ok" for v in verdicts),
        tolerance=args.tolerance, device_kind=device_kind())
    print(f"perf-gate: {json.dumps(summary)}")
    if args.out:
        report = dict(summary=summary, verdicts=verdicts)
        if args.cost:
            report["cost_attribution"] = costs
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    if args.strict and comparable and n_reg:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
