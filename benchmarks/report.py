"""Emit EXPERIMENTS.md tables from dry-run/bench JSONs.

  PYTHONPATH=src python -m benchmarks.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, load_cells, model_flops,
                       roofline_row)


def dryrun_table(dryrun_dir: str, multi_pod: bool) -> str:
    lines = ["| arch | shape | compile s | FLOPs/dev | bytes/dev | wire/dev "
             "| GB/dev | fits 16G |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in load_cells(dryrun_dir):
        if rec.get("multi_pod") != multi_pod:
            continue
        if rec.get("skipped"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | skip | — | — "
                         f"| — | — | ({rec['reason']}) |")
            continue
        if rec.get("error"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | "
                         f"| | {rec['error'][:60]} |")
            continue
        gb = (rec["mem"]["argument_bytes"] + rec["mem"]["temp_bytes"]) / 2**30
        fits = "yes" if gb <= 16 else f"no ({gb:.0f} GB)"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['compile_s']:.0f} "
            f"| {rec['hlo_flops']:.2e} | {rec['hlo_bytes_written']:.2e} "
            f"| {rec['wire_bytes_per_device']:.2e} | {gb:.1f} | {fits} |")
    return "\n".join(lines)


def roofline_table_md(dryrun_dir: str) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | 6ND/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in load_cells(dryrun_dir):
        if rec.get("multi_pod"):
            continue
        r = roofline_row(rec)
        if not r:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['bottleneck']}** | {r['useful_ratio']:.3f} "
            f"| {r['roofline_frac']:.4f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    print("## Single-pod (16×16) dry-run\n")
    print(dryrun_table(args.dir, False))
    print("\n## Multi-pod (2×16×16) dry-run\n")
    print(dryrun_table(args.dir, True))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table_md(args.dir))


if __name__ == "__main__":
    main()
