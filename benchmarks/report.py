"""Emit EXPERIMENTS.md tables from dry-run/bench JSONs.

  PYTHONPATH=src python -m benchmarks.report [--dir results/dryrun]

With ``--trace trace.json`` (a Perfetto/Chrome-trace file written by a
telemetry-enabled service run) it additionally prints the per-stage span
summary table; ``--telemetry snapshot.json`` (a versioned registry
snapshot, DESIGN.md §2.11) prints latency p50/p99 straight from the
histogram registry.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, load_cells, model_flops,
                       roofline_row)


def dryrun_table(dryrun_dir: str, multi_pod: bool) -> str:
    lines = ["| arch | shape | compile s | FLOPs/dev | bytes/dev | wire/dev "
             "| GB/dev | fits 16G |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in load_cells(dryrun_dir):
        if rec.get("multi_pod") != multi_pod:
            continue
        if rec.get("skipped"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | skip | — | — "
                         f"| — | — | ({rec['reason']}) |")
            continue
        if rec.get("error"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | "
                         f"| | {rec['error'][:60]} |")
            continue
        gb = (rec["mem"]["argument_bytes"] + rec["mem"]["temp_bytes"]) / 2**30
        fits = "yes" if gb <= 16 else f"no ({gb:.0f} GB)"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['compile_s']:.0f} "
            f"| {rec['hlo_flops']:.2e} | {rec['hlo_bytes_written']:.2e} "
            f"| {rec['wire_bytes_per_device']:.2e} | {gb:.1f} | {fits} |")
    return "\n".join(lines)


def roofline_table_md(dryrun_dir: str) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | 6ND/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in load_cells(dryrun_dir):
        if rec.get("multi_pod"):
            continue
        r = roofline_row(rec)
        if not r:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['bottleneck']}** | {r['useful_ratio']:.3f} "
            f"| {r['roofline_frac']:.4f} |")
    return "\n".join(lines)


def trace_table_md(trace_path: str) -> str:
    """Span durations by pipeline stage from a Perfetto trace — count,
    total/mean wall time and p50/p99 per stage, sorted by total."""
    from repro.runtime.telemetry import stage_summary
    lines = ["| stage | spans | total ms | mean ms | p50 ms | p99 ms |",
             "|---|---|---|---|---|---|"]
    for r in stage_summary(trace_path):
        lines.append(
            f"| {r['stage']} | {r['count']} | {r['total_ms']:.2f} "
            f"| {r['mean_ms']:.3f} | {r['p50_ms']:.3f} "
            f"| {r['p99_ms']:.3f} |")
    return "\n".join(lines)


def telemetry_table_md(snapshot_path: str) -> str:
    """Latency p50/p99 read from the histogram registry of a saved
    telemetry snapshot (the versioned schema, not raw stats dicts)."""
    from repro.runtime.telemetry import load_snapshot
    from repro.runtime.telemetry import Histogram
    snap = load_snapshot(snapshot_path)
    lines = ["| histogram | n | mean ms | p50 ms | p99 ms | max ms |",
             "|---|---|---|---|---|---|"]
    for name, d in sorted(snap.get("histograms", {}).items()):
        h = Histogram.from_dict(d)
        if not h.count:
            continue
        lines.append(
            f"| {name} | {h.count} | {h.mean_s * 1e3:.3f} "
            f"| {h.percentile(50) * 1e3:.3f} "
            f"| {h.percentile(99) * 1e3:.3f} | {h.vmax * 1e3:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--trace", default="",
                    help="Perfetto trace from a telemetry-enabled run: "
                         "print the per-stage span summary table")
    ap.add_argument("--telemetry", default="",
                    help="telemetry snapshot JSON: print histogram-registry "
                         "p50/p99 table")
    args = ap.parse_args()
    if args.trace:
        print("## Pipeline stages (trace)\n")
        print(trace_table_md(args.trace))
    if args.telemetry:
        print("\n## Latency histograms (registry)\n"
              if args.trace else "## Latency histograms (registry)\n")
        print(telemetry_table_md(args.telemetry))
    if args.trace or args.telemetry:
        return
    print("## Single-pod (16×16) dry-run\n")
    print(dryrun_table(args.dir, False))
    print("\n## Multi-pod (2×16×16) dry-run\n")
    print(dryrun_table(args.dir, True))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table_md(args.dir))


if __name__ == "__main__":
    main()
