"""Restructure backbone microbenchmark: counting partition vs packed sort.

Two row families, machine-readable into ``BENCH_restructure.json`` via
``benchmarks/run.py`` (DESIGN.md §2.1):

* ``plan`` rows — wall time of the full values-independent restructure
  plan (chain order, inverse map, segment geometry, commit gather map)
  under each forced backbone ("partition" / "packed" / "lexsort") across
  an N × n_slots grid, plus the rung the auto ladder resolves for that
  cell.  This measures the crossover the ladder encodes: the counting
  partition wins for compact key spaces at large N; the comparison sort
  wins for large sparse stores on CPU XLA.
* ``exchange`` rows — the owner-routed exchange bucketing: the
  counting-partition pass (what ``bucket_by_owner`` dispatches to inside
  its measured win regime) against the sort-based plan it replaced
  (``packed_stable_sort`` + a separate ``segment_sum`` for the
  capacities), at n_route = 8 destinations.
* ``fused`` rows — the megakernel rung A/B: the staged
  ``plan → coefs → execute`` pipeline (full chain geometry + the
  materialized [N, W] coefficient arrays) against the fused
  ``fused_chain_eval`` pipeline (geometry-free light plan, coefficients
  expanded from the two-column LUT form in place), interleaved at the
  plan-grid shapes that sit inside the megakernel's slot band.  The
  measured crossover is what ``kernels.autotune.MEGA_BOUNDS`` encodes.

The minimum over iterations is the headline estimator (external load only
adds time — same rationale as ``timeit``; DESIGN.md §8.3).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ownership import bucket_by_owner
from repro.core.restructure import (commit_from_histogram, commit_index,
                                    megakernel_engaged, packed_sort_fits,
                                    restructure, restructure_path)
from repro.core.types import OpBatch


def _wall_min_interleaved(calls: dict, iters: int) -> dict:
    """Min wall seconds per labelled thunk, measured **interleaved** so
    machine-load drift lands on every contender equally (the same A/B
    protocol as ``common.stream_wall_time_pair``)."""
    for fn in calls.values():          # warm all compiles before timing any
        jax.block_until_ready(fn())
    ts = {k: [] for k in calls}
    for _ in range(iters):
        for k, fn in calls.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[k].append(time.perf_counter() - t0)
    return {k: float(np.min(v)) for k, v in ts.items()}


def _mk_ops(rng, n: int, n_slots: int, theta: float = 0.6,
            max_ops: int = 8) -> OpBatch:
    """Zipf-skewed uid stream in row-major (ts, slot) layout."""
    ranks = np.arange(1, n_slots + 1, dtype=np.float64)
    p = ranks ** -theta
    p /= p.sum()
    uid = rng.choice(n_slots, size=n, p=p).astype(np.int32)
    idx = np.arange(n, dtype=np.int32)
    return OpBatch(
        uid=jnp.asarray(uid),
        ts=jnp.asarray(idx // max_ops), txn=jnp.asarray(idx // max_ops),
        slot=jnp.asarray(idx % max_ops),
        kind=jnp.zeros((n,), jnp.int32), fun=jnp.zeros((n,), jnp.int32),
        gate=jnp.full((n,), -1, jnp.int32),
        operand=jnp.asarray(rng.uniform(size=(n, 4)).astype(np.float32)),
        valid=jnp.asarray(rng.uniform(size=n) > 0.05))


@partial(jax.jit, static_argnames=("pad_uid", "method"))
def _plan(ops, pad_uid: int, method: str):
    """The full values-independent restructure plan one backbone feeds."""
    sops, ch = restructure(ops, pad_uid, rowmajor_ts=True, light=True,
                           method=method)
    if ch.counts is not None:
        cp, cok = commit_from_histogram(ch.counts, ch.starts)
    else:
        cp, cok = commit_index(sops.uid, pad_uid + 1)
    return (sops.uid, sops.operand, ch.order, ch.inv, ch.seg_start,
            ch.seg_id, ch.pos, cp, cok)


# both production exchange backbones, forced through bucket_by_owner's
# ``counting`` switch so the bench A/Bs exactly what ships
_bucket = jax.jit(bucket_by_owner,
                  static_argnames=("n_route", "cap", "counting"))


def _grids(quick: bool, smoke: bool):
    if smoke:
        return [(4096, (8, 1024))], [4096], 3
    if quick:
        return ([(32768, (8, 201, 10000)),
                 (131072, (8, 201, 10000)),
                 (524288, (8, 201, 10000))],
                [40960, 163840, 655360, 1310720], 7)
    return ([(n, (8, 64, 201, 1024, 10000))
             for n in (32768, 131072, 524288, 1048576)],
            [40960, 163840, 655360, 1310720, 2621440], 11)


def _fused_rows(rng, plan_grid, iters):
    """Megakernel-rung A/B: the full staged chain-evaluation pipeline vs
    the fused one, both on the same partition backbone (use_pallas=False:
    on hosts the fused win is structural — no chain geometry, no [N, W]
    coefficient arrays — and the XLA ref is what the rung dispatches)."""
    from repro.core.engines import (simple_affine_luts, tstream_scan_coefs,
                                    tstream_scan_execute, tstream_scan_plan)
    from repro.core.types import F_ADD, F_NOP, F_PUT, F_READ, make_store
    from repro.kernels.autotune import mega_bounds
    from repro.kernels.megakernel import fused_chain_eval

    funs = (F_NOP, F_READ, F_PUT, F_ADD)
    a_lut, b_lut = simple_affine_luts(funs)
    band = mega_bounds()
    rows = []
    for n, slots_list in plan_grid:
        for s in slots_list:
            if s + 1 > band["max_buckets"]:
                continue
            store = make_store([s], 4)
            pad_uid = store.pad_uid
            ops = _mk_ops(rng, n, s)
            ops = dataclasses.replace(ops, fun=jnp.asarray(
                rng.integers(0, len(funs), n).astype(np.int32)))
            values = store.values

            @jax.jit
            def staged(values, ops):
                pres = restructure(ops, pad_uid, rowmajor_ts=True,
                                   light=True, method="partition")
                plan = tstream_scan_plan(store, ops, funs,
                                         prestructured=pres)
                plan = tstream_scan_coefs(plan, use_pallas=False)
                return tstream_scan_execute(values, plan, pad_uid,
                                            raw=True)

            @jax.jit
            def fused(values, ops):
                sops, ch = restructure(ops, pad_uid, rowmajor_ts=True,
                                       light=True, method="partition",
                                       geometry=False)
                return fused_chain_eval(values, sops, ch, pad_uid,
                                        a_lut=a_lut, b_lut=b_lut,
                                        use_pallas=False)

            cell = _wall_min_interleaved(
                dict(staged=lambda: staged(values, ops),
                     fused=lambda: fused(values, ops)), iters=iters)
            engaged = megakernel_engaged(n, s + 1, method="auto",
                                         has_max=False, funs_simple=True)
            rows.append(dict(
                fig="restructure", kind="fused", scheme="staged",
                n=n, n_slots=s, shape=f"N{n}-S{s}",
                wall_s=cell["staged"], events_per_s=n / cell["staged"]))
            rows.append(dict(
                fig="restructure", kind="fused", scheme="megakernel",
                n=n, n_slots=s, shape=f"N{n}-S{s}",
                auto_engaged=bool(engaged),
                wall_s=cell["fused"], events_per_s=n / cell["fused"],
                mega_speedup_vs_staged=cell["staged"] / cell["fused"]))
    return rows


def run(quick: bool = True, smoke: bool = False):
    rng = np.random.default_rng(23)
    plan_grid, ex_ns, iters = _grids(quick, smoke)
    rows = []

    for n, slots_list in plan_grid:
        for s in slots_list:
            ops = _mk_ops(rng, n, s)
            auto = restructure_path(n, s, rowmajor_ts=True)
            methods = ["partition"]
            if packed_sort_fits(n, s, bits=32):
                methods.append("packed")
            else:
                # the 32-bit packed ceiling (u64 needs x64): lexsort is the
                # comparator the ladder actually falls back to here
                methods.append("lexsort")
            if n <= 131072 and "lexsort" not in methods:
                methods.append("lexsort")
            cell = _wall_min_interleaved(
                {m: (lambda m=m: _plan(ops, s, m)) for m in methods},
                iters=iters)
            sort_ref = cell.get("packed", cell.get("lexsort"))
            for i, m in enumerate(methods):
                rows.append(dict(
                    fig="restructure", kind="plan", scheme=m,
                    n=n, n_slots=s, shape=f"N{n}-S{s}", auto_path=auto,
                    wall_s=cell[m], events_per_s=n / cell[m],
                    **({"partition_speedup_vs_sort":
                        sort_ref / cell["partition"]} if i == 0 else {})))

    rows.extend(_fused_rows(rng, plan_grid, iters))

    n_route = 8
    for n in ex_ns:
        dst = jnp.asarray(rng.integers(0, n_route + 1, n).astype(np.int32))
        cap = max(1, min(2 * (n // n_route), n))
        cell = _wall_min_interleaved(
            dict(counting=lambda: _bucket(dst, n_route, cap, counting=True),
                 sort=lambda: _bucket(dst, n_route, cap, counting=False)),
            iters=iters)
        wc, ws = cell["counting"], cell["sort"]
        rows.append(dict(
            fig="restructure", kind="exchange", scheme="partition",
            n=n, n_route=n_route, cap=cap, shape=f"N{n}-R{n_route}",
            wall_s=wc, events_per_s=n / wc,
            partition_speedup_vs_packed=ws / wc))
        rows.append(dict(
            fig="restructure", kind="exchange", scheme="packed",
            n=n, n_route=n_route, cap=cap, shape=f"N{n}-R{n_route}",
            wall_s=ws, events_per_s=n / ws))
    return rows
