"""Fig. 10 analogue: PAT vs TStream under multi-partition transactions (GS).

Two views: the modeled single-device PAT-vs-TStream comparison (paper
figure), plus **measured** fused sharded streaming rows across the same
mp_ratio/mp_len grid on an 8-device shared-nothing mesh (subprocess
worker; exchange drops accounted per row)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS

from .common import throughput_model

WIDTH = 40


def _sharded_rows(quick: bool):
    worker = os.path.join(os.path.dirname(__file__), "fig10_worker.py")
    cmd = [sys.executable, worker] + ([] if quick else ["--full"])
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        return [dict(fig="fig10", error=proc.stderr[-500:])]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(quick: bool = True):
    n_events = 300 if quick else 1000
    app = ALL_APPS["gs"]
    rows = []
    n_partitions = 16
    for mp_ratio in [0.0, 0.25, 0.5, 0.75, 1.0]:
        rng = np.random.default_rng(10)
        store = app.make_store()
        events = {k: jnp.asarray(v) for k, v in app.gen_events(
            rng, n_events, n_partitions=n_partitions, mp_ratio=mp_ratio,
            mp_len=6).items()}
        res = throughput_model(app, store, events, ["tstream", "pat"],
                               [WIDTH], n_partitions=n_partitions)
        for scheme, d in res.items():
            rows.append(dict(fig="fig10a", app="gs", scheme=scheme,
                             mp_ratio=mp_ratio,
                             events_per_s=d["by_width"][WIDTH],
                             rounds=d["rounds"]))
    for mp_len in [2, 4, 6, 8, 10]:
        rng = np.random.default_rng(11)
        store = app.make_store()
        events = {k: jnp.asarray(v) for k, v in app.gen_events(
            rng, n_events, n_partitions=n_partitions, mp_ratio=0.5,
            mp_len=mp_len).items()}
        res = throughput_model(app, store, events, ["tstream", "pat"],
                               [WIDTH], n_partitions=n_partitions)
        for scheme, d in res.items():
            rows.append(dict(fig="fig10b", app="gs", scheme=scheme,
                             mp_len=mp_len,
                             events_per_s=d["by_width"][WIDTH],
                             rounds=d["rounds"]))
    rows.extend(_sharded_rows(quick))
    return rows
