"""Fig. 10 analogue: PAT vs TStream under multi-partition transactions (GS)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS

from .common import throughput_model

WIDTH = 40


def run(quick: bool = True):
    n_events = 300 if quick else 1000
    app = ALL_APPS["gs"]
    rows = []
    n_partitions = 16
    for mp_ratio in [0.0, 0.25, 0.5, 0.75, 1.0]:
        rng = np.random.default_rng(10)
        store = app.make_store()
        events = {k: jnp.asarray(v) for k, v in app.gen_events(
            rng, n_events, n_partitions=n_partitions, mp_ratio=mp_ratio,
            mp_len=6).items()}
        res = throughput_model(app, store, events, ["tstream", "pat"],
                               [WIDTH], n_partitions=n_partitions)
        for scheme, d in res.items():
            rows.append(dict(fig="fig10a", app="gs", scheme=scheme,
                             mp_ratio=mp_ratio,
                             events_per_s=d["by_width"][WIDTH],
                             rounds=d["rounds"]))
    for mp_len in [2, 4, 6, 8, 10]:
        rng = np.random.default_rng(11)
        store = app.make_store()
        events = {k: jnp.asarray(v) for k, v in app.gen_events(
            rng, n_events, n_partitions=n_partitions, mp_ratio=0.5,
            mp_len=mp_len).items()}
        res = throughput_model(app, store, events, ["tstream", "pat"],
                               [WIDTH], n_partitions=n_partitions)
        for scheme, d in res.items():
            rows.append(dict(fig="fig10b", app="gs", scheme=scheme,
                             mp_len=mp_len,
                             events_per_s=d["by_width"][WIDTH],
                             rounds=d["rounds"]))
    return rows
