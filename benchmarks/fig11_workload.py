"""Fig. 11 analogue: read-ratio and key-skew sensitivity (GS)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS

from .common import throughput_model

WIDTH = 40
SCHEMES = ["tstream", "lock", "mvlk", "pat"]


def run(quick: bool = True):
    n_events = 300 if quick else 1000
    app = ALL_APPS["gs"]
    rows = []
    for read_ratio in [0.0, 0.25, 0.5, 0.75, 1.0]:
        rng = np.random.default_rng(12)
        store = app.make_store()
        events = {k: jnp.asarray(v) for k, v in app.gen_events(
            rng, n_events, theta=0.0, read_ratio=read_ratio).items()}
        res = throughput_model(app, store, events, SCHEMES, [WIDTH])
        for scheme, d in res.items():
            rows.append(dict(fig="fig11a", app="gs", scheme=scheme,
                             read_ratio=read_ratio,
                             events_per_s=d["by_width"][WIDTH]))
    for theta in [0.0, 0.4, 0.8, 1.2]:
        rng = np.random.default_rng(13)
        store = app.make_store()
        events = {k: jnp.asarray(v) for k, v in app.gen_events(
            rng, n_events, theta=theta, read_ratio=0.0).items()}
        res = throughput_model(app, store, events, SCHEMES, [WIDTH])
        for scheme, d in res.items():
            rows.append(dict(fig="fig11b", app="gs", scheme=scheme,
                             theta=theta,
                             events_per_s=d["by_width"][WIDTH],
                             max_chain=d["max_chain"]))
    return rows
