"""§Roofline: three-term roofline per (arch × shape × mesh) from the
dry-run JSONs (results/dryrun/*.json).

  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_bytes_per_device / HBM_bw
  collective = wire_bytes_per_device / link_bw

Peaks come from a per-``device_kind`` table (``DEVICE_PEAKS``) resolved
against the running backend by default — the old hardcoded TPU-v5e
constants silently mispriced every other host, including the CPU CI
boxes.  Any entry can be overridden from the CLI
(``--peak-flops/--hbm-bw/--link-bw``) or per call via ``device_peaks``.

HLO_FLOPs/bytes are trip-count-weighted per-device figures (see
launch/hlo_analysis.py — XLA's cost_analysis counts loop bodies once).
MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for train; 2·N·D_tokens
for prefill/decode forward passes.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

# peak (FLOP/s, HBM bytes/s, per-link bytes/s) by device kind.  Keys are
# matched case-insensitively by prefix (``"tpu v5"`` covers
# ``"TPU v5e"``/``"TPU v5p"`` unless a longer key matches first), with
# "cpu" as the fallback row for hosts.  Sources: public TPU spec sheets;
# the cpu row is a deliberately modest desktop-class estimate (AVX2 f32,
# dual-channel DDR4, inter-socket UPI) so host rooflines stay meaningful
# rather than absurdly compute-bound.
DEVICE_PEAKS: Dict[str, Dict[str, float]] = {
    "tpu v4":  dict(peak_flops=275e12, hbm_bw=1228e9, link_bw=50e9),
    "tpu v5e": dict(peak_flops=197e12, hbm_bw=819e9,  link_bw=50e9),
    "tpu v5p": dict(peak_flops=459e12, hbm_bw=2765e9, link_bw=100e9),
    "tpu v6":  dict(peak_flops=918e12, hbm_bw=1640e9, link_bw=100e9),
    "cpu":     dict(peak_flops=1e12,   hbm_bw=40e9,   link_bw=20e9),
}

# legacy module constants (== the "tpu v5e" row, what the old hardcoded
# numbers were) kept for direct importers
PEAK_FLOPS = DEVICE_PEAKS["tpu v5e"]["peak_flops"]
HBM_BW = DEVICE_PEAKS["tpu v5e"]["hbm_bw"]
LINK_BW = DEVICE_PEAKS["tpu v5e"]["link_bw"]

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}


def device_peaks(device_kind: Optional[str] = None,
                 override: Optional[Dict[str, float]] = None
                 ) -> Dict[str, float]:
    """Resolve the peak row for ``device_kind`` (default: the running
    backend's ``jax.devices()[0].device_kind``), longest prefix match,
    "cpu" fallback; ``override`` keys replace resolved entries."""
    if device_kind is None:
        try:
            import jax
            device_kind = jax.devices()[0].device_kind
        except Exception:
            device_kind = "cpu"
    kind = str(device_kind).lower()
    row = None
    for key in sorted(DEVICE_PEAKS, key=len, reverse=True):
        if kind.startswith(key) or key.startswith(kind):
            row = dict(DEVICE_PEAKS[key])
            break
    if row is None:
        row = dict(DEVICE_PEAKS["cpu"])
    if override:
        row.update({k: float(v) for k, v in override.items()
                    if v is not None})
    return row


def model_flops(rec: dict) -> float:
    """Global model FLOPs for the cell (6ND train, 2ND forward)."""
    tokens = SHAPE_TOKENS[rec["shape"]]
    n = rec["active_params"]
    mult = 6.0 if rec["shape"] == "train_4k" else 2.0
    return mult * n * tokens


def load_cells(dryrun_dir: str = "results/dryrun") -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(rec: dict,
                 peaks: Optional[Dict[str, float]] = None
                 ) -> Optional[dict]:
    if rec.get("skipped") or rec.get("error"):
        return None
    if peaks is None:
        # dry-run records carry the arch they were analyzed for; fall
        # back to the running backend only when they don't
        peaks = device_peaks(rec.get("device_kind") or rec.get("arch"))
    ndev = rec["n_devices"]
    t_comp = rec["hlo_flops"] / peaks["peak_flops"]
    t_mem = rec["hlo_bytes_written"] / peaks["hbm_bw"]
    t_coll = rec["wire_bytes_per_device"] / peaks["link_bw"]
    terms = dict(compute=t_comp, memory=t_mem, collective=t_coll)
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful = mf / max(rec["hlo_flops"] * ndev, 1.0)
    # roofline fraction: useful-compute time / bound (the score axis)
    bound = max(terms.values())
    frac = (mf / ndev / peaks["peak_flops"]) / max(bound, 1e-12)
    return dict(
        arch=rec["arch"], shape=rec["shape"],
        mesh="2x16x16" if rec["multi_pod"] else "16x16",
        compute_s=t_comp, memory_s=t_mem, collective_s=t_coll,
        bottleneck=bottleneck,
        model_flops=mf, useful_ratio=useful,
        roofline_frac=frac,
        mem_gb_per_dev=(rec["mem"]["argument_bytes"]
                        + rec["mem"]["temp_bytes"]) / 2 ** 30,
    )


def table(dryrun_dir: str = "results/dryrun", multi_pod: bool = False,
          peaks: Optional[Dict[str, float]] = None):
    rows = []
    for rec in load_cells(dryrun_dir):
        if rec.get("multi_pod") != multi_pod:
            continue
        r = roofline_row(rec, peaks=peaks)
        if r:
            rows.append(r)
    return rows


def run(quick: bool = True):
    rows = []
    for r in table(multi_pod=False):
        rows.append(dict(fig="roofline", **r))
    return rows


def main(argv=None):
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--dryrun-dir", default="results/dryrun")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--device-kind", default=None,
                   help="peak table row to price against (default: the "
                        "record's arch, else the running backend)")
    p.add_argument("--peak-flops", type=float, default=None)
    p.add_argument("--hbm-bw", type=float, default=None)
    p.add_argument("--link-bw", type=float, default=None)
    args = p.parse_args(argv)
    override = dict(peak_flops=args.peak_flops, hbm_bw=args.hbm_bw,
                    link_bw=args.link_bw)
    peaks = None
    if args.device_kind or any(v is not None for v in override.values()):
        peaks = device_peaks(args.device_kind, override=override)
    rows = table(args.dryrun_dir, multi_pod=args.multi_pod, peaks=peaks)
    print(json.dumps(rows, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
