"""§Roofline: three-term roofline per (arch × shape × mesh) from the
dry-run JSONs (results/dryrun/*.json).

  compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective = wire_bytes_per_device / link_bw          (~50 GB/s ICI)

HLO_FLOPs/bytes are trip-count-weighted per-device figures (see
launch/hlo_analysis.py — XLA's cost_analysis counts loop bodies once).
MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for train; 2·N·D_tokens
for prefill/decode forward passes.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}


def model_flops(rec: dict) -> float:
    """Global model FLOPs for the cell (6ND train, 2ND forward)."""
    tokens = SHAPE_TOKENS[rec["shape"]]
    n = rec["active_params"]
    mult = 6.0 if rec["shape"] == "train_4k" else 2.0
    return mult * n * tokens


def load_cells(dryrun_dir: str = "results/dryrun") -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(rec: dict) -> Optional[dict]:
    if rec.get("skipped") or rec.get("error"):
        return None
    ndev = rec["n_devices"]
    t_comp = rec["hlo_flops"] / PEAK_FLOPS
    t_mem = rec["hlo_bytes_written"] / HBM_BW
    t_coll = rec["wire_bytes_per_device"] / LINK_BW
    terms = dict(compute=t_comp, memory=t_mem, collective=t_coll)
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful = mf / max(rec["hlo_flops"] * ndev, 1.0)
    # roofline fraction: useful-compute time / bound (the score axis)
    bound = max(terms.values())
    frac = (mf / ndev / PEAK_FLOPS) / max(bound, 1e-12)
    return dict(
        arch=rec["arch"], shape=rec["shape"],
        mesh="2x16x16" if rec["multi_pod"] else "16x16",
        compute_s=t_comp, memory_s=t_mem, collective_s=t_coll,
        bottleneck=bottleneck,
        model_flops=mf, useful_ratio=useful,
        roofline_frac=frac,
        mem_gb_per_dev=(rec["mem"]["argument_bytes"]
                        + rec["mem"]["temp_bytes"]) / 2 ** 30,
    )


def table(dryrun_dir: str = "results/dryrun", multi_pod: bool = False):
    rows = []
    for rec in load_cells(dryrun_dir):
        if rec.get("multi_pod") != multi_pod:
            continue
        r = roofline_row(rec)
        if r:
            rows.append(r)
    return rows


def run(quick: bool = True):
    rows = []
    for r in table(multi_pod=False):
        rows.append(dict(fig="roofline", **r))
    return rows
