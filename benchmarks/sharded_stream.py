"""Sharded fused streaming throughput (DESIGN.md §2.5).

events/sec per chain-shard layout × device count for the owner-routed
fused sharded ``run_stream``, against the single-device fused driver and
the replicate-everything per-batch ``evaluate_sharded`` loop it replaces,
plus per-layout collective bytes and exchange padding/drop accounting.
Runs in a subprocess (needs an 8-device placeholder mesh); rows land in
``BENCH_sharded_stream.json`` via ``benchmarks/run.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys


def run(quick: bool = True, smoke: bool = False):
    worker = os.path.join(os.path.dirname(__file__),
                          "sharded_stream_worker.py")
    cmd = [sys.executable, worker]
    if smoke:
        cmd.append("--smoke")
    elif not quick:
        cmd.append("--full")
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        return [dict(fig="sharded_stream", error=proc.stderr[-800:])]
    return json.loads(proc.stdout.strip().splitlines()[-1])
