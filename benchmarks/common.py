"""Benchmark utilities: wall-clock timing + the executor cost model.

Two complementary views reproduce the paper's multicore figures on TPU-
style hardware (DESIGN.md §8.1):

1. **Measured**: actual jitted wall time of each engine on the real
   workload (CPU here; the schedules' *structure* — sequential scan vs
   parallel segmented scan — dominates the comparison).

2. **Modeled width scaling** (the paper's x-axis is cores): Brent's law
   over the *measured schedule structure*:  T(width) ≈ (depth + work/width)
   · t_op + sync.  depth/work come from the engine's EngineStats on the
   actual workload — the model is data-driven, not fabricated; t_op is
   calibrated from the measured sequential (LOCK) wall time.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blotter import build_opbatch
from repro.core.engines import evaluate


def wall_time(fn: Callable, *args, iters: int = 5) -> float:
    """Median wall seconds of a jitted call (post-warmup)."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def stream_wall_time_pair(engine, values, event_stream, interval: int, *,
                          iters: int = 9):
    """((min, median) unfused, (min, median) fused) wall seconds, measured
    **interleaved** so drift in machine load lands on both drivers equally
    — an A/B wall-clock comparison, not two separate absolute
    measurements.  The *minimum* is the headline estimator: external load
    only ever adds time, so min estimates the intrinsic cost (the same
    rationale as ``timeit``; DESIGN.md §8.3).  The median is reported
    alongside for context.
    """
    for fused in (False, True):  # warm both compiles before timing either
        jax.block_until_ready(
            engine.run_stream(values, event_stream, interval, fused=fused))
    tu, tf = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(
            engine.run_stream(values, event_stream, interval, fused=False))
        tu.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(
            engine.run_stream(values, event_stream, interval, fused=True))
        tf.append(time.perf_counter() - t0)
    return ((float(np.min(tu)), float(np.median(tu))),
            (float(np.min(tf)), float(np.median(tf))))


def engine_stats(app, store, events, scheme: str, **kw):
    """Run one interval, return (stats, wall_seconds, results)."""
    ops, _ = build_opbatch(app, store, events, jnp.int32(0))

    def run(values, ops):
        import dataclasses
        st = dataclasses.replace(store, values=values)
        res, vals, stats = evaluate(st, ops, app.funs, scheme,
                                    associative_only=app.associative_only,
                                    has_gates=app.has_gates, **kw)
        return res, vals, stats

    jitted = jax.jit(run)
    secs = wall_time(jitted, store.values, ops)
    res, vals, stats = jitted(store.values, ops)
    return jax.device_get(stats), secs, res


SYNC_OPS = 50.0          # barrier/mode-switch cost in op-units per interval
SORT_FACTOR = 0.15       # sort work per op relative to a state access


def modeled_time(stats, scheme: str, width: int, n_events: int,
                 t_op: float) -> float:
    """Brent's-law executor model over the measured schedule structure."""
    n_ops = float(stats.n_ops)
    depth = float(stats.rounds)
    if scheme in ("tstream", "tstream_scan", "tstream_lockstep", "mvlk"):
        work = n_ops * (1.0 + SORT_FACTOR * np.log2(max(n_ops, 2)) / 10)
    else:
        work = n_ops
    if scheme == "lock":
        # coarse-grained: one txn at a time holds the lock pipeline
        t = depth + 0.25 * work / width
    elif scheme == "nolock":
        t = work / width
    else:
        t = depth + work / width
    t = t + SYNC_OPS
    return t * t_op


def throughput_model(app, store, events, schemes, widths, **kw) -> Dict:
    """events/sec per (scheme, width), calibrated on LOCK's measured time."""
    n_events = len(next(iter(events.values())))
    stats_l, secs_l, _ = engine_stats(app, store, events, "lock")
    t_op = secs_l / max(float(stats_l.rounds), 1.0)
    out = {}
    for scheme in schemes:
        stats, secs, _ = engine_stats(app, store, events, scheme, **kw)
        out[scheme] = dict(
            measured_1dev_s=secs,
            rounds=float(stats.rounds),
            n_chains=float(stats.n_chains),
            max_chain=float(stats.max_chain),
            by_width={w: n_events / modeled_time(stats, scheme, w, n_events,
                                                 t_op)
                      for w in widths},
        )
    return out
