"""Sharded streaming benchmark worker (subprocess: 8 placeholder devices).

Measures end-to-end ``run_stream`` events/sec for the owner-routed fused
sharded driver against (a) the single-device fused driver and (b) the
replicate-everything per-batch ``evaluate_sharded`` loop — the path the
exchange replaces — across layouts and device counts, plus per-layout
collective bytes from the compiled HLO.  Prints JSON rows on the last
line; ``benchmarks/sharded_stream.py`` relays them into
``BENCH_sharded_stream.json``.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS                                # noqa: E402
from repro.core.blotter import build_opbatch                   # noqa: E402
from repro.core.scheduler import DualModeEngine, EngineConfig  # noqa: E402
from repro.core.sharded import evaluate_sharded                # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo              # noqa: E402


def _time(fn, iters):
    fn()  # warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)), float(np.median(ts))


def stream_fused_sharded(app, store, stream, interval, mesh, layout, slack):
    eng = DualModeEngine(app, store, EngineConfig(), mesh=mesh,
                        layout=layout, exchange_slack=slack)

    def go():
        outs, vals = eng.run_stream(store.values, stream, interval)
        jax.block_until_ready(vals)
    return eng, go


def stream_per_batch(app, store, stream, interval, mesh, layout):
    """The replicate-everything baseline as a stream driver: one jitted
    build + one jitted evaluate_sharded dispatch per interval, state
    carried through the host loop (exactly the pre-exchange cost model:
    O(n_dev*N) replicated op bytes, a restructure sort and an ownership
    permutation per call)."""
    n = len(next(iter(stream.values())))
    n_intervals = n // interval
    batches = [{k: jnp.asarray(np.asarray(v)[i * interval:(i + 1) * interval])
                for k, v in stream.items()} for i in range(n_intervals)]

    @jax.jit
    def build(values, events, ts0):
        st = dataclasses.replace(store, values=values)
        ops, _ = build_opbatch(app, st, events, ts0)
        return ops

    def evl(values, ops):
        st = dataclasses.replace(store, values=values)
        out = evaluate_sharded(st, ops, app.funs, mesh, layout)
        return jnp.concatenate([out, jnp.zeros((1, app.width))])
    evl = jax.jit(evl)

    def go():
        values = store.values
        for i, ev in enumerate(batches):
            ops = build(values, ev, jnp.int32(i * interval))
            values = evl(values, ops)
        jax.block_until_ready(values)
    return go


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        n_events, interval, iters = 256, 64, 2
        meshes = [(jax.make_mesh((8,), ("dev",)), 8, "1x8")]
    elif args.full:
        n_events, interval, iters = 8192, 512, 7
        meshes = [(jax.make_mesh((d,), ("dev",)), d, f"1x{d}")
                  for d in (2, 4, 8)]
    else:
        n_events, interval, iters = 2048, 512, 3
        meshes = [(jax.make_mesh((d,), ("dev",)), d, f"1x{d}")
                  for d in (2, 8)]
    mesh2 = jax.make_mesh((2, 4), ("socket", "core"))

    app = ALL_APPS["gs"]
    rng = np.random.default_rng(17)
    stream = app.gen_events(rng, n_events)
    store = app.make_store()
    rows = []

    # single-device fused reference (the bit-identity baseline)
    ref = DualModeEngine(app, store, EngineConfig())

    def ref_go():
        outs, vals = ref.run_stream(store.values, stream, interval,
                                    fused=True)
        jax.block_until_ready(vals)
    w_min, w_med = _time(ref_go, iters)
    rows.append(dict(fig="sharded_stream", app="gs", layout="single_device",
                     driver="fused", mesh="1x1", n_dev=1, interval=interval,
                     n_events=n_events, wall_s=w_min, median_wall_s=w_med,
                     events_per_s=n_events / w_min))

    cases = [("shared_nothing", mesh, n_dev, name)
             for mesh, n_dev, name in meshes]
    if not args.smoke:
        cases += [("shared_per_socket", mesh2, 8, "2x4"),
                  ("shared_everything", meshes[-1][0], meshes[-1][1],
                   meshes[-1][2])]

    for layout, mesh, n_dev, mesh_name in cases:
        eng, go = stream_fused_sharded(app, store, stream, interval, mesh,
                                       layout, slack=4.0)
        w_min, w_med = _time(go, iters)
        st = eng.last_exchange_stats
        # per-layout collective bytes from the compiled whole-stream HLO
        batched = {k: jnp.asarray(np.asarray(v)[: (n_events // interval)
                                                * interval].reshape(
            (n_events // interval, interval) + np.asarray(v).shape[1:]))
            for k, v in stream.items()}
        lowered = eng._sharded._impl.lower(
            jnp.array(store.values, copy=True), batched, jnp.int32(0))
        hlo = analyze_hlo(lowered.compile().as_text(), mesh.size)
        rows.append(dict(
            fig="sharded_stream", app="gs", layout=layout,
            driver="fused_sharded", mesh=mesh_name, n_dev=n_dev,
            interval=interval, n_events=n_events, wall_s=w_min,
            median_wall_s=w_med, events_per_s=n_events / w_min,
            dropped=int(np.sum(st["dropped"])),
            exchange_capacity=int(st["capacity"]),
            exchanged_rows_per_device=int(st["exchanged_rows_per_device"]),
            coll_bytes=hlo["coll_bytes"],
            wire_bytes_per_device=hlo["wire_bytes_per_device"]))

        go_pb = stream_per_batch(app, store, stream, interval, mesh, layout)
        w_min, w_med = _time(go_pb, iters)
        rows.append(dict(
            fig="sharded_stream", app="gs", layout=layout,
            driver="per_batch", mesh=mesh_name, n_dev=n_dev,
            interval=interval, n_events=n_events, wall_s=w_min,
            median_wall_s=w_med, events_per_s=n_events / w_min))

    # acceptance summary: fused sharded vs per-batch on shared_nothing@8dev
    f8 = [r for r in rows if r["driver"] == "fused_sharded"
          and r["layout"] == "shared_nothing" and r["n_dev"] == 8]
    p8 = [r for r in rows if r["driver"] == "per_batch"
          and r["layout"] == "shared_nothing" and r["n_dev"] == 8]
    if f8 and p8:
        rows.append(dict(
            fig="sharded_stream", app="gs", layout="shared_nothing",
            driver="summary", mesh="1x8", n_dev=8, interval=interval,
            n_events=n_events,
            fused_sharded_speedup_vs_per_batch=(
                f8[0]["events_per_s"] / p8[0]["events_per_s"]),
            events_per_s=f8[0]["events_per_s"]))
    print(json.dumps(rows))


if __name__ == "__main__":
    main()
