import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Fig. 10 sharded worker (subprocess: 8 placeholder devices).

Multi-partition transactions on the fused sharded streaming path: GS
streams across mp_ratio / mp_len on a shared-nothing 8-device mesh, with
measured events/sec and exchange drop accounting.  Multi-partition
transactions are exactly the workload where owner routing fans one
transaction's ops out to several shards, so exchange padding pressure
rises with mp_ratio — the drop counters make that visible rather than
silent.  One engine is compiled once and reused across the grid (all
streams share shapes).  Prints JSON rows.
"""
import json
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps import GS                                       # noqa: E402
from repro.core.scheduler import DualModeEngine, EngineConfig   # noqa: E402


def main():
    quick = "--full" not in sys.argv
    n_events = 1024 if quick else 4096
    interval = 256
    n_partitions = 16
    mesh = jax.make_mesh((8,), ("dev",))
    store = GS.make_store()
    eng = DualModeEngine(GS, store, EngineConfig(), mesh=mesh,
                        layout="shared_nothing", exchange_slack=4.0)
    ref = DualModeEngine(GS, store, EngineConfig())

    rows = []

    def measure(tag, **gen_kw):
        rng = np.random.default_rng(10)
        stream = GS.gen_events(rng, n_events, n_partitions=n_partitions,
                               **gen_kw)
        _, vals_ref = ref.run_stream(store.values, stream, interval,
                                     fused=True)
        outs, vals = eng.run_stream(store.values, stream, interval)
        jax.block_until_ready(vals)
        t0 = time.perf_counter()
        for _ in range(3):
            outs, vals = eng.run_stream(store.values, stream, interval)
            jax.block_until_ready(vals)
        secs = (time.perf_counter() - t0) / 3
        st = eng.last_exchange_stats
        rows.append(dict(
            fig=tag, app="gs", scheme="tstream_sharded",
            layout="shared_nothing", mesh="1x8",
            events_per_s=n_events / secs, wall_s=secs,
            dropped=int(np.sum(st["dropped"])),
            exchange_capacity=int(st["capacity"]),
            bit_identical=bool(np.array_equal(np.asarray(vals),
                                              np.asarray(vals_ref))),
            **gen_kw))

    for mp_ratio in [0.0, 0.25, 0.5, 0.75, 1.0]:
        measure("fig10a", mp_ratio=mp_ratio, mp_len=6)
    for mp_len in [2, 4, 6, 8, 10]:
        measure("fig10b", mp_ratio=0.5, mp_len=mp_len)
    print(json.dumps(rows))


if __name__ == "__main__":
    main()
