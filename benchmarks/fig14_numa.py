"""Fig. 14 analogue: NUMA-aware configurations -> chain-shard layouts.

Runs in a subprocess (the layouts need an 8-device placeholder mesh while
the rest of the suite sees the real single device)."""
from __future__ import annotations

import json
import os
import subprocess
import sys


def run_reshard(quick: bool = True, smoke: bool = False):
    """Skew-storm A/B (DESIGN.md §2.10): static provisioning vs elastic
    resharding through a calm -> aligned-Zipf ramp -> theta=2.5 peak ->
    calm storm.  Rows interleave the static and elastic plans per storm
    phase; the elastic peak row carries its speedup over the never-drops
    static-slack8 baseline."""
    worker = os.path.join(os.path.dirname(__file__), "fig14_numa_worker.py")
    size = "smoke" if smoke else ("quick" if quick else "full")
    proc = subprocess.run([sys.executable, worker, "reshard", size],
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        return [dict(fig="reshard", error=proc.stderr[-500:])]
    raw = json.loads(proc.stdout.strip().splitlines()[-1])
    base = {r["phase"]: r for r in raw if r["plan"] == "static-slack8"}
    rows = []
    # interleave: phase-major, static rows before the elastic row
    order = {p: i for i, p in enumerate(
        ("calm", "ramp", "peak", "cooldown", "all"))}
    for r in sorted(raw, key=lambda r: (order.get(r["phase"], 99),
                                        r["elastic"], -r["slack"])):
        r = dict(r, fig="reshard", app="gs", kind="reshard", size=size)
        b = base.get(r["phase"])
        if r["elastic"] and b and b["events_per_s"] > 0:
            r["speedup_vs_static"] = r["events_per_s"] / b["events_per_s"]
        rows.append(r)
    return rows


def run(quick: bool = True):
    worker = os.path.join(os.path.dirname(__file__), "fig14_numa_worker.py")
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=900)
    if proc.returncode != 0:
        return [dict(fig="fig14", error=proc.stderr[-500:])]
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for layout, d in data.items():
        rows.append(dict(fig="fig14", app="gs", layout=layout,
                         correct=d["correct"], wall_s=d["wall_s"],
                         wire_bytes_per_device=d["wire_bytes_per_device"],
                         fused_bit_identical=d["fused_bit_identical"],
                         fused_wall_s=d["fused_wall_s"],
                         fused_events_per_s=d["fused_events_per_s"],
                         fused_dropped=d["fused_dropped"],
                         fused_exchange_capacity=d["fused_exchange_capacity"]))
    return rows
