"""Fig. 14 analogue: NUMA-aware configurations -> chain-shard layouts.

Runs in a subprocess (the layouts need an 8-device placeholder mesh while
the rest of the suite sees the real single device)."""
from __future__ import annotations

import json
import os
import subprocess
import sys


def run(quick: bool = True):
    worker = os.path.join(os.path.dirname(__file__), "fig14_numa_worker.py")
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=900)
    if proc.returncode != 0:
        return [dict(fig="fig14", error=proc.stderr[-500:])]
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for layout, d in data.items():
        rows.append(dict(fig="fig14", app="gs", layout=layout,
                         correct=d["correct"], wall_s=d["wall_s"],
                         wire_bytes_per_device=d["wire_bytes_per_device"],
                         fused_bit_identical=d["fused_bit_identical"],
                         fused_wall_s=d["fused_wall_s"],
                         fused_events_per_s=d["fused_events_per_s"],
                         fused_dropped=d["fused_dropped"],
                         fused_exchange_capacity=d["fused_exchange_capacity"]))
    return rows
