"""Elastic resharding benchmark (DESIGN.md §2.10) — thin module shim.

The measurement lives in ``fig14_numa.run_reshard`` (it shares the
8-device subprocess worker); registering it as its own module gives it
its own ``BENCH_reshard.json`` trajectory file.  Rows carry ``plan``
(static-slack8 / static-slack2 / elastic-slack2) and ``phase`` (calm,
ramp, peak, cooldown, plus an aggregate ``"all"`` row) interleaved
phase-major, so the static/elastic A/B reads off adjacent rows; the
elastic peak row carries ``speedup_vs_static`` against the worst-case
provisioned static-slack8 baseline.
"""
from __future__ import annotations

from .fig14_numa import run_reshard as run  # noqa: F401
