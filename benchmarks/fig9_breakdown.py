"""Fig. 9 analogue: transaction-processing time breakdown (SL).

Components measured on-device: restructure (sort/segment = the paper's
'Lock'-insertion analogue), evaluation (Useful), and the residual
(Sync/Others: mode-switch barriers become phase boundaries; their cost is
the difference between the full step and its parts)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS
from repro.core.blotter import build_opbatch
from repro.core.engines import evaluate
from repro.core.restructure import restructure

from .common import wall_time


def run(quick: bool = True):
    n_events = 500 if quick else 2000
    app = ALL_APPS["sl"]
    rng = np.random.default_rng(9)
    store = app.make_store()
    events = {k: jnp.asarray(v)
              for k, v in app.gen_events(rng, n_events).items()}
    ops, _ = build_opbatch(app, store, events, jnp.int32(0))

    t_restruct = wall_time(jax.jit(
        lambda o: restructure(o, store.pad_uid)[1].seg_id), ops)

    rows = []
    for scheme in ["tstream", "lock", "mvlk", "pat"]:
        def full(values, o):
            st = dataclasses.replace(store, values=values)
            return evaluate(st, o, app.funs, scheme,
                            associative_only=app.associative_only,
                            has_gates=app.has_gates)[1]
        t_full = wall_time(jax.jit(full), store.values, ops)
        restruct = t_restruct if scheme.startswith(("tstream", "mvlk", "pat")) \
            else 0.0
        rows.append(dict(fig="fig9", app="sl", scheme=scheme,
                         total_s=t_full, restructure_s=restruct,
                         useful_s=max(t_full - restruct, 0.0)))
    return rows
