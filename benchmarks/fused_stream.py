"""Fused vs unfused streaming: quantify the per-interval host overhead.

The fused driver (DESIGN.md §2.4) runs the whole stream as one jitted
``lax.scan``; the unfused driver pays one jit dispatch + store rebuild +
host↔device round-trip per punctuation interval.  Rows are machine-
readable — one per (app, scheme, interval, fused flag) — and land in
``BENCH_fused_stream.json`` at the repo root via ``benchmarks/run.py`` so
successive PRs have a perf trajectory.
"""
from __future__ import annotations

import numpy as np

from repro.apps import ALL_APPS
from repro.core.scheduler import DualModeEngine, EngineConfig

from .common import stream_wall_time_pair


def _cases(quick: bool, smoke: bool):
    if smoke:   # CI bit-rot canary: seconds, not minutes
        return [("gs", "tstream", 64, 4)]
    if quick:   # the app x interval grid (all four apps; both hot paths)
        return [
            ("gs", "tstream", 512, 32),   # acceptance case
            ("gs", "tstream", 128, 64),
            ("tp", "tstream", 512, 32),
            ("tp", "tstream", 128, 64),
            ("sl", "tstream", 256, 16),   # gated lockstep path
            ("sl", "tstream", 128, 32),
            ("ob", "tstream", 128, 16),   # non-associative lockstep path
            ("gs", "mvlk", 256, 8),
        ]
    return [(a, s, i, 32) for a in ALL_APPS for s in ("tstream", "mvlk")
            for i in (128, 512, 1024)]


def run(quick: bool = True, smoke: bool = False):
    rows = []
    for app_name, scheme, interval, n_intervals in _cases(quick, smoke):
        app = ALL_APPS[app_name]
        rng = np.random.default_rng(17)
        n_events = interval * n_intervals
        stream = app.gen_events(rng, n_events)
        store = app.make_store()
        eng = DualModeEngine(app, store, EngineConfig(scheme=scheme))
        (u_min, u_med), (f_min, f_med) = stream_wall_time_pair(
            eng, store.values, stream, interval,
            iters=3 if smoke else 15)
        for fused, w_min, w_med in ((False, u_min, u_med),
                                    (True, f_min, f_med)):
            rows.append(dict(
                fig="fused_stream", app=app_name, scheme=scheme,
                interval=interval, n_events=n_events, fused=fused,
                wall_s=w_min, median_wall_s=w_med,
                events_per_s=n_events / w_min,
            ))
        rows[-1]["fused_speedup_vs_unfused"] = u_min / f_min
    return rows
