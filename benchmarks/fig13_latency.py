"""Fig. 13 analogue: 99th-pct end-to-end latency per scheme (interval 500)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS

from .common import engine_stats, modeled_time

WIDTH = 40
INTERVAL = 500
SCHEMES = ["tstream", "lock", "mvlk", "pat"]


def run(quick: bool = True):
    rows = []
    for name in (["gs", "sl"] if quick else list(ALL_APPS)):
        app = ALL_APPS[name]
        rng = np.random.default_rng(15)
        store = app.make_store()
        events = {k: jnp.asarray(v)
                  for k, v in app.gen_events(rng, INTERVAL).items()}
        stats_l, secs_l, _ = engine_stats(app, store, events, "lock")
        t_op = secs_l / max(float(stats_l.rounds), 1.0)
        for scheme in SCHEMES:
            stats, secs, _ = engine_stats(app, store, events, scheme)
            t_batch = modeled_time(stats, scheme, WIDTH, INTERVAL, t_op)
            tput = INTERVAL / t_batch
            fill = INTERVAL / max(tput, 1e-9)
            rows.append(dict(fig="fig13", app=name, scheme=scheme,
                             p99_latency_s=0.99 * fill + t_batch,
                             events_per_s=tput))
    return rows
