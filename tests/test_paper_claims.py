"""Regression-lock the paper's headline findings (small workloads).

These assert the *orderings* the paper reports (its Figures 8/10/11), on
the modeled 40-wide executor over measured schedule structure.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import throughput_model
from repro.apps import ALL_APPS

WIDTH = 40


def tput(app, events, schemes, **kw):
    store = app.make_store()
    res = throughput_model(app, store, events, schemes, [WIDTH], **kw)
    return {s: d["by_width"][WIDTH] for s, d in res.items()}


def test_tstream_beats_prior_on_gs():
    """Paper Finding 1: TStream outperforms prior schemes at scale."""
    app = ALL_APPS["gs"]
    rng = np.random.default_rng(0)
    events = {k: jnp.asarray(v) for k, v in app.gen_events(rng, 200).items()}
    t = tput(app, events, ["tstream", "lock", "pat", "mvlk"])
    assert t["tstream"] > 2 * t["pat"] > t["lock"]
    assert t["tstream"] > t["mvlk"] >= t["lock"]


def test_tstream_beats_prior_on_sl_with_dependencies():
    """Paper Finding 1 on SL (heavy data dependencies)."""
    app = ALL_APPS["sl"]
    rng = np.random.default_rng(1)
    events = {k: jnp.asarray(v) for k, v in app.gen_events(rng, 200).items()}
    t = tput(app, events, ["tstream", "lock", "pat"])
    assert t["tstream"] > t["pat"] > t["lock"]


def test_pat_degrades_with_multipartition_ratio():
    """Paper Finding 3a / Fig 10: PAT's schedule depth grows with the
    multi-partition ratio (partition-lock coupling); TStream's does not.
    Asserted on the deterministic schedule structure (rounds), which is
    immune to wall-clock noise."""
    from benchmarks.common import engine_stats
    app = ALL_APPS["gs"]
    rounds = {}
    for ratio in (0.0, 1.0):
        rng = np.random.default_rng(2)
        events = {k: jnp.asarray(v) for k, v in app.gen_events(
            rng, 150, n_partitions=16, mp_ratio=ratio, mp_len=6).items()}
        store = app.make_store()
        st_p, _, _ = engine_stats(app, store, events, "pat", n_partitions=16)
        st_t, _, _ = engine_stats(app, store, events, "tstream")
        rounds[ratio] = (float(st_p.rounds), float(st_t.rounds))
    assert rounds[1.0][0] > 3 * rounds[0.0][0]      # PAT depth explodes
    assert rounds[1.0][1] <= rounds[0.0][1] + 3     # TStream flat


def test_tstream_tolerates_skew():
    """Paper Finding 3c / Fig 11b: prior schemes degrade under skew,
    TStream's log-depth fast path stays within 2x."""
    app = ALL_APPS["gs"]
    out = {}
    for theta in (0.0, 1.2):
        rng = np.random.default_rng(3)
        events = {k: jnp.asarray(v) for k, v in app.gen_events(
            rng, 150, theta=theta, read_ratio=0.0).items()}
        out[theta] = tput(app, events, ["tstream", "lock"])
    assert out[1.2]["tstream"] > 0.5 * out[0.0]["tstream"]


def test_interval_increases_throughput():
    """Paper Fig 12a: larger punctuation interval -> higher throughput
    (more parallelism to amortize sync)."""
    from benchmarks.common import engine_stats, modeled_time
    app = ALL_APPS["tp"]
    tputs = []
    for interval in (50, 500):
        rng = np.random.default_rng(4)
        store = app.make_store()
        events = {k: jnp.asarray(v)
                  for k, v in app.gen_events(rng, interval).items()}
        stats, secs, _ = engine_stats(app, store, events, "tstream")
        stats_l, secs_l, _ = engine_stats(app, store, events, "lock")
        t_op = secs_l / max(float(stats_l.rounds), 1.0)
        tputs.append(interval / modeled_time(stats, "tstream", WIDTH,
                                             interval, t_op))
    assert tputs[1] > tputs[0]
