"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes/segment patterns, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.segscan import ops as segops
from repro.kernels.segscan import ref as segref
from repro.kernels.hash_probe import kernel as hpk
from repro.kernels.hash_probe import ops as hpops
from repro.kernels.hash_probe import ref as hpref

# radix_partition kernel tests are deterministic and live in the ungated
# tests/test_restructure_parity.py so coverage survives without hypothesis


def _mk_segments(rng, n, avg_seg):
    flags = rng.random(n) < (1.0 / avg_seg)
    flags[0] = True
    return flags


@pytest.mark.parametrize("n", [1, 7, 256, 300, 1024, 2500])
@pytest.mark.parametrize("w", [1, 2, 32, 128])
@pytest.mark.parametrize("avg_seg", [1.5, 8, 1000])
def test_segscan_affine_matches_ref(n, w, avg_seg):
    rng = np.random.default_rng(n * 1000 + w)
    a = jnp.asarray(rng.uniform(0.0, 1.5, (n, w)).astype(np.float32))
    b = jnp.asarray(rng.uniform(-2.0, 2.0, (n, w)).astype(np.float32))
    f = jnp.asarray(_mk_segments(rng, n, avg_seg))
    A0, B0 = segref.segscan_affine_ref(f, a, b)
    A1, B1 = segops.segscan_affine(a, b, f, interpret=True)
    np.testing.assert_allclose(np.asarray(A1), np.asarray(A0), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(B1), np.asarray(B0), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("n", [5, 256, 777, 2048])
@pytest.mark.parametrize("w", [1, 32])
def test_segscan_max_matches_ref(n, w):
    rng = np.random.default_rng(n + w)
    m = jnp.asarray(rng.uniform(-5, 5, (n, w)).astype(np.float32))
    f = jnp.asarray(_mk_segments(rng, n, 6))
    M0 = segref.segscan_max_ref(f, m)
    M1 = segops.segscan_max(m, f, interpret=True)
    np.testing.assert_allclose(np.asarray(M1), np.asarray(M0), rtol=1e-6,
                               atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 600),
       avg=st.sampled_from([1.0, 3.0, 50.0]))
def test_segscan_affine_property(seed, n, avg):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0, 2, (n, 3)).astype(np.float32))
    b = jnp.asarray(rng.uniform(-1, 1, (n, 3)).astype(np.float32))
    f = jnp.asarray(_mk_segments(rng, n, avg))
    A0, B0 = segref.segscan_affine_ref(f, a, b)
    A1, B1 = segops.segscan_affine(a, b, f, interpret=True)
    np.testing.assert_allclose(np.asarray(A1), np.asarray(A0), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(B1), np.asarray(B0), rtol=2e-5,
                               atol=2e-5)


def test_segscan_engine_integration():
    """Engine fast path with use_pallas=True equals the oracle on GS."""
    from repro.apps import GS
    from repro.core.blotter import build_opbatch
    from repro.core.engines import evaluate
    rng = np.random.default_rng(0)
    store = GS.make_store()
    events = {k: jnp.asarray(v) for k, v in GS.gen_events(rng, 48).items()}
    ops, _ = build_opbatch(GS, store, events, jnp.int32(0))
    r1, v1, _ = evaluate(store, ops, GS.funs, "tstream_scan", use_pallas=True)
    r0, v0, _ = evaluate(store, ops, GS.funs, "lock")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(r1["pre"]), np.asarray(r0["pre"]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_keys,n_buckets", [(50, 64), (500, 256),
                                              (4000, 2048)])
def test_hash_probe_matches_ref_and_truth(n_keys, n_buckets):
    rng = np.random.default_rng(n_keys)
    keys = rng.choice(2**31 - 1, size=n_keys, replace=False).astype(np.int32)
    lo, hi = hpref.build_table(keys, n_buckets)
    lo, hi = jnp.asarray(lo), jnp.asarray(hi)
    # present keys resolve to a slot holding the key
    q = jnp.asarray(keys[: min(n_keys, 300)])
    s_ref = np.asarray(hpref.hash_probe_ref(q, lo, hi))
    s_ker = np.asarray(hpops.hash_probe(q, lo, hi, interpret=True))
    np.testing.assert_array_equal(s_ker, s_ref)
    assert np.all(s_ker >= 0)
    flat = np.asarray(lo).reshape(-1).astype(np.int64) \
        + np.asarray(hi).reshape(-1).astype(np.int64) * 65536
    np.testing.assert_array_equal(flat[s_ker], np.asarray(q, np.int64))
    # absent keys return -1
    absent = rng.choice(2**31 - 1, size=200).astype(np.int32)
    absent = np.setdiff1d(absent, keys)[:100]
    s_abs = np.asarray(hpops.hash_probe(jnp.asarray(absent), lo, hi,
                                        interpret=True))
    assert np.all(s_abs == -1)
