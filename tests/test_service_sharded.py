"""Sharded streaming-service contracts (subprocess forces 8 host devices).

The worker (tests/service_worker.py) runs the chunked service over the
sharded fused driver and reports JSON verdicts: chunked == monolithic ==
single-device bitwise, crash -> restore -> replay bit-identity, and
exchange-stat aggregation into the merged accounting record.
"""
import json
import os
import subprocess
import sys

import pytest


@pytest.fixture(scope="module")
def worker_verdicts():
    worker = os.path.join(os.path.dirname(__file__), "service_worker.py")
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("case", ["gs/chunked", "sl/chunked",
                                  "gs/crash_resume"])
def test_sharded_service(worker_verdicts, case):
    v = worker_verdicts[case]
    assert v["ok"], f"{case}: {v.get('why')}"
