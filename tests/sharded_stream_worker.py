"""Sharded fused streaming worker (subprocess: forces 8 host devices).

Each check compares the sharded fused driver against the single-device
fused driver and reports a JSON verdict; the pytest wrapper
(`tests/test_sharded_stream.py`) asserts on the verdicts.  Bit-identity
here means **bitwise equality** of every per-interval output and the
final state (DESIGN.md §2.5).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import ALL_APPS                               # noqa: E402
from repro.core.scheduler import DualModeEngine, EngineConfig  # noqa: E402

MESH1 = jax.make_mesh((8,), ("dev",))
MESH2 = jax.make_mesh((2, 4), ("socket", "core"))


def bit_identical(app_name, layout, mesh, *, n_events=128, interval=32,
                  slack=8.0, seed=11, cfg=None, mutate=None,
                  gen_kwargs=None, cfg_ref=None):
    app = ALL_APPS[app_name]
    rng = np.random.default_rng(seed)
    stream = app.gen_events(rng, n_events, **(gen_kwargs or {}))
    if mutate:
        mutate(stream)
    store = app.make_store()
    cfg = cfg or EngineConfig()
    ref = DualModeEngine(app, store, cfg_ref or cfg)
    outs_r, vals_r = ref.run_stream(store.values, stream, interval,
                                    fused=True)
    eng = DualModeEngine(app, store, cfg, mesh=mesh, layout=layout,
                        exchange_slack=slack)
    outs_s, vals_s = eng.run_stream(store.values, stream, interval)
    st = eng.last_exchange_stats
    if int(np.sum(st["dropped"])) != 0:
        return dict(ok=False, why="unexpected exchange drops")
    if not np.array_equal(np.asarray(vals_s), np.asarray(vals_r)):
        return dict(ok=False, why="final state differs")
    for i, (a, b) in enumerate(zip(outs_s, outs_r)):
        for k in a:
            if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
                return dict(ok=False, why=f"output {k} interval {i} differs")
    return dict(ok=True, shipped=int(st["shipped"][0]),
                capacity=int(st["capacity"]))


def overdraw(stream):
    stream["amount"] = (stream["amount"] * 100).astype(np.float32)


def check_overflow():
    """Tiny capacity forces drops; the engine must COUNT them (and the
    run completes — degraded, not crashed)."""
    app = ALL_APPS["gs"]
    rng = np.random.default_rng(9)
    stream = app.gen_events(rng, 64)
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig(), mesh=MESH1,
                        exchange_slack=1.0)
    eng.run_stream(store.values, stream, 32)
    st = eng.last_exchange_stats
    dropped = int(np.sum(st["dropped"]))
    return dict(ok=dropped > 0, dropped=dropped,
                capacity=int(st["capacity"]))


def check_probe_parity():
    """Hash-probe uid->owner routing (flag-gated) must route identically
    to the direct-addressed gather."""
    app = ALL_APPS["gs"]
    rng = np.random.default_rng(9)
    stream = app.gen_events(rng, 64)
    store = app.make_store()
    e1 = DualModeEngine(app, store, EngineConfig(), mesh=MESH1,
                        exchange_slack=8.0)
    o1, v1 = e1.run_stream(store.values, stream, 32)
    e2 = DualModeEngine(app, store, EngineConfig(use_hash_probe_route=True),
                        mesh=MESH1, exchange_slack=8.0)
    o2, v2 = e2.run_stream(store.values, stream, 32)
    if not np.array_equal(np.asarray(v1), np.asarray(v2)):
        return dict(ok=False, why="state differs")
    for a, b in zip(o1, o2):
        for k in a:
            if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
                return dict(ok=False, why=f"output {k} differs")
    return dict(ok=True)


def main():
    out = {}

    def run(name, fn, *a, **kw):
        try:
            out[name] = fn(*a, **kw)
        except Exception as e:  # pragma: no cover - surfaced via verdict
            traceback.print_exc(file=sys.stderr)
            out[name] = dict(ok=False, why=f"{type(e).__name__}: {e}")

    # every app under shared_nothing (assoc fast path + sharded lockstep)
    for app_name in ("gs", "tp", "sl", "ob"):
        run(f"{app_name}/shared_nothing", bit_identical, app_name,
            "shared_nothing", MESH1)
    # every layout (2-D mesh) for both associative apps (TP has
    # heterogeneous max tables -> exercises permuted slot_is_max)
    for layout, mesh in (("shared_nothing", MESH2),
                         ("shared_per_socket", MESH2),
                         ("shared_everything", MESH1)):
        for app_name in ("gs", "tp"):
            run(f"{app_name}/{layout}", bit_identical, app_name, layout,
                mesh)
    # key skew and multi-partition transactions
    run("gs/skew", bit_identical, "gs", "shared_nothing", MESH1, seed=5,
        gen_kwargs=dict(theta=0.95), slack=8.0)
    run("gs/multipartition", bit_identical, "gs", "shared_nothing", MESH1,
        seed=7, gen_kwargs=dict(n_partitions=16, mp_ratio=0.5, mp_len=6))
    # abort repass under heavy failure + forced dependency residue
    run("sl/abort_repass", bit_identical, "sl", "shared_nothing", MESH1,
        seed=3, cfg=EngineConfig(scheme="tstream", abort_repass=True),
        mutate=overdraw, n_events=96, interval=24)
    run("sl/residue", bit_identical, "sl", "shared_nothing", MESH1, seed=3,
        cfg=EngineConfig(scheme="tstream", max_dep_levels=0),
        mutate=overdraw, n_events=96, interval=24)
    # radix-partition restructure backbone: the sharded driver forced onto
    # the partition rung must match the lexsort single-device reference
    # bit for bit (segscan fast path + gated lockstep path)
    run("gs/partition_restructure", bit_identical, "gs", "shared_nothing",
        MESH1, cfg=EngineConfig(restructure_method="partition"),
        cfg_ref=EngineConfig(restructure_method="lexsort"))
    run("sl/partition_restructure", bit_identical, "sl", "shared_nothing",
        MESH1, cfg=EngineConfig(restructure_method="partition"),
        cfg_ref=EngineConfig(restructure_method="lexsort"))
    # exchange-capacity overflow accounting + hash-probe routing
    run("overflow", check_overflow)
    run("hash_probe_route", check_probe_parity)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
