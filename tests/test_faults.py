"""Chaos suite: fault-injection plane + hardened recovery (DESIGN.md §2.7).

Contracts pinned here:

1. **Chaos invariant**: for every seeded fault schedule (flaky/stalled
   source, executor crash or hang between dispatch and commit, snapshot
   corrupted at publish) the service either completes or crashes with a
   *balanced* accounting record — and crash → restore → replay is
   **bitwise identical** to the uninterrupted run.  The assembler ledger
   ``arrived == assembled + dropped + pending`` balances across every
   injected fault.
2. **Snapshot validity**: ``verify_checkpoint`` detects every corruption
   kind the plane can inject; debris/torn snapshots never shadow a good
   one (``latest_step``/``latest_valid_step``); ``resume`` falls back
   past a corrupted latest snapshot instead of leaking an exception.
3. **Source retry/backoff + straggler alarm**: transient pull failures
   retry with bounded backoff; exhaustion crashes with stats intact; the
   backfill-ratio alarm trips and is logged once per run.
4. **Executor watchdog**: an injected hang is detected, the pipeline
   drains, an emergency punctuation-aligned snapshot is published, and
   the structured ``ExecutorHungError`` surfaces — with recovery still
   bitwise exact.  A plain executor exception surfaces promptly with no
   leaked threads (service.py error path).
5. **Retention**: ``keep_last`` prunes after atomic publish; resume still
   works from the retained tail.

The sharded (8 forced host devices) chaos cases live in
tests/faults_worker.py, driven by test_faults_sharded below.
"""
import json
import logging
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.ckpt import (checkpoint_steps, latest_step, latest_valid_step,
                        load_checkpoint, save_checkpoint, verify_checkpoint)
from repro.core.intervals import ReplaySource, WatermarkPolicy
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.runtime.controller import ControllerConfig
from repro.runtime.faults import (CONTROLLER_DECIDE, EXECUTOR_HANG,
                                  RESHARD_APPLY, SITE_KINDS,
                                  SNAPSHOT_PUBLISH, SOURCE_PULL,
                                  Fault, FaultPlane, InjectedCrashError,
                                  TransientSourceError, corrupt_snapshot,
                                  random_schedule, schedule_from_json,
                                  schedule_to_json)
from repro.runtime.service import (ExecutorHungError, ServiceConfig,
                                   StreamService)
from repro.runtime.service import StragglerPolicy

from test_service import assert_outputs_identical, conservation_ok

INTERVAL = 16
N_EVENTS = 160      # 10 intervals -> 5 chunks of K=2 -> snapshots at 4, 8
JITTER = 3
WM = WatermarkPolicy(allowed_lateness=JITTER)


def mk_source(app):
    return ReplaySource(app.gen_events, N_EVENTS, seed=7,
                        arrival_batch=11, jitter=JITTER)


def mk_engine(app_name="gs", scheme="tstream"):
    app = ALL_APPS[app_name]
    return app, DualModeEngine(app, app.make_store(),
                               EngineConfig(scheme=scheme))


def chaos_cfg(ckpt_dir, **kw):
    base = dict(punct_interval=INTERVAL, chunk_intervals=2,
                snapshot_every=4 if ckpt_dir else 0,
                ckpt_dir=str(ckpt_dir) if ckpt_dir else None, watermark=WM,
                source_retries=2, retry_backoff_s=0.01,
                watchdog_factor=4.0, watchdog_min_s=1.0,
                watchdog_grace_s=20.0,
                straggler=StragglerPolicy(deadline_s=0.5))
    base.update(kw)
    return ServiceConfig(**base)


def assert_ledger_balanced(stats):
    a = stats["assembly"]
    assert a["arrived"] == a["assembled"] + a["dropped"] + a["pending"], a


# ---------------------------------------------------------------------------
# 1. the chaos sweep: seeded schedules x apps x schemes
# ---------------------------------------------------------------------------
def run_chaos_case(app_name, scheme, seed, ckpt_dir):
    """One chaos case: run under a seeded fault schedule, then prove the
    crash → restore → replay continuation is bitwise identical to the
    uninterrupted reference and every accounting record balances."""
    app, eng = mk_engine(app_name, scheme)
    # uninterrupted reference (also warms every chunk-shape compile, so
    # the watchdog grace window never races a cold jit below)
    ref = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2, watermark=WM)).run(
            mk_source(app))

    sched = random_schedule(seed, n_pulls=15, n_chunks=5, n_snapshots=2,
                            hang_s=2.5, stall_s=0.05)
    plane = FaultPlane(sched)
    cfg = chaos_cfg(ckpt_dir)
    svc = StreamService(eng, cfg)
    crashed = None
    try:
        rec = svc.run(mk_source(app), faults=plane)
    except Exception as e:
        crashed = svc.last_run
        stats = crashed.stats
        assert stats is not None and stats["crashed"], \
            f"crash without structured stats: {type(e).__name__}: {e}"
        assert conservation_ok(stats), stats
        assert_ledger_balanced(stats)
        assert stats["faults"], "crashed but no fault recorded as fired"
        # the committed prefix already matched the reference bitwise
        if crashed.outputs:
            assert_outputs_identical(crashed.outputs,
                                     ref.outputs[: len(crashed.outputs)])
        try:
            rec = StreamService(eng, cfg).resume(mk_source(app))
        except FileNotFoundError:
            # crashed before any valid snapshot: replay from scratch
            rec = StreamService(eng, cfg).run(mk_source(app))

    snap = rec.stats["replayed"] // INTERVAL
    np.testing.assert_array_equal(rec.final_values, ref.final_values)
    assert_outputs_identical(rec.outputs, ref.outputs[snap:])
    assert conservation_ok(rec.stats)
    assert_ledger_balanced(rec.stats)
    return plane, crashed


@pytest.mark.parametrize("app_name,scheme,seed", [
    ("gs", "tstream", 0),
    ("gs", "tstream", 1),
    ("gs", "tstream", 2),
    ("gs", "tstream", 3),
    ("gs", "tstream", 4),
    ("sl", "tstream", 1),     # gated lockstep path
    ("sl", "tstream", 5),
    ("gs", "mvlk", 2),        # MVLK scheme
    ("gs", "mvlk", 6),
])
def test_chaos_schedule(app_name, scheme, seed, tmp_path):
    run_chaos_case(app_name, scheme, seed, tmp_path / f"s{seed}")


def test_chaos_fires_every_site_across_sweep(tmp_path):
    """The seeds above aren't vacuous: across a seed range the generator
    schedules every site at least once."""
    sites = set()
    for seed in range(24):
        for f in random_schedule(seed, n_pulls=15, n_chunks=5,
                                 n_snapshots=2, n_decisions=3,
                                 n_reshards=3):
            sites.add(f.site)
    assert sites == set(SITE_KINDS), sites
    # ... and with the controller + reshard sites closed (the
    # non-adaptive default) no pre-existing seed's schedule changes
    for seed in range(16):
        sched = random_schedule(seed, n_pulls=15, n_chunks=5, n_snapshots=2)
        assert all(f.site not in (CONTROLLER_DECIDE, RESHARD_APPLY)
                   for f in sched)


# ---------------------------------------------------------------------------
# 2. snapshot validity: verify / fallback / debris
# ---------------------------------------------------------------------------
def _save_ref_ckpt(d, step=4):
    return save_checkpoint(str(d), step,
                           dict(values=np.arange(24.0).reshape(4, 6)),
                           extra_meta=dict(intervals_done=step,
                                           punct_interval=INTERVAL))


@pytest.mark.parametrize("kind", ["torn_manifest", "corrupt_leaf",
                                  "truncate_leaf"])
def test_verify_detects_corruption(tmp_path, kind):
    path = _save_ref_ckpt(tmp_path)
    assert verify_checkpoint(str(tmp_path), 4) == (True, "ok")
    corrupt_snapshot(path, kind)
    if kind == "torn_manifest":
        # an unparseable manifest is invisible: the step no longer exists
        assert checkpoint_steps(str(tmp_path)) == []
    else:
        ok, why = verify_checkpoint(str(tmp_path), 4)
        assert not ok and "leaf" in why


def test_debris_never_shadows_valid_snapshot(tmp_path):
    path = _save_ref_ckpt(tmp_path, step=4)
    corrupt_snapshot(path, "debris")      # manifest-less step_00000005
    assert os.path.isdir(str(tmp_path / "step_00000005"))
    assert latest_step(str(tmp_path)) == 4
    assert latest_valid_step(str(tmp_path)) == 4


def test_latest_valid_skips_corrupt_latest(tmp_path):
    _save_ref_ckpt(tmp_path, step=4)
    p8 = _save_ref_ckpt(tmp_path, step=8)
    corrupt_snapshot(p8, "corrupt_leaf")
    assert latest_step(str(tmp_path)) == 8          # manifest still reads
    assert latest_valid_step(str(tmp_path)) == 4    # but it doesn't verify
    with pytest.raises(ValueError, match="verification"):
        load_checkpoint(str(tmp_path), 8,
                        dict(values=np.zeros((4, 6))), verify=True)


@pytest.mark.parametrize("kind", ["torn_manifest", "corrupt_leaf",
                                  "truncate_leaf", "debris"])
def test_resume_falls_back_past_corrupt_latest(tmp_path, kind):
    """Corruption of the latest snapshot NEVER leaks an exception out of
    resume — it restores the previous valid snapshot and the continuation
    is still bitwise identical to the uninterrupted run."""
    app, eng = mk_engine()
    ref = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2, watermark=WM)).run(
            mk_source(app))
    cfg = ServiceConfig(punct_interval=INTERVAL, chunk_intervals=2,
                        snapshot_every=4, ckpt_dir=str(tmp_path),
                        watermark=WM)
    svc = StreamService(eng, cfg)
    with pytest.raises(RuntimeError, match="injected failure"):
        svc.run(mk_source(app), crash_after_interval=8)
    assert svc.last_run.snapshots == [4, 8]
    corrupt_snapshot(str(tmp_path / "step_00000008"), kind)

    rec = StreamService(eng, cfg).resume(mk_source(app))
    expect_from = 8 if kind == "debris" else 4   # debris damages only step 9
    assert rec.stats["replayed"] // INTERVAL == expect_from
    np.testing.assert_array_equal(rec.final_values, ref.final_values)
    assert_outputs_identical(rec.outputs, ref.outputs[expect_from:])


# ---------------------------------------------------------------------------
# 3. retention (keep_last)
# ---------------------------------------------------------------------------
def test_keep_last_prunes_after_publish(tmp_path):
    app, eng = mk_engine()
    cfg = ServiceConfig(punct_interval=INTERVAL, chunk_intervals=2,
                        snapshot_every=2, ckpt_dir=str(tmp_path),
                        watermark=WM, keep_last=2)
    rec = StreamService(eng, cfg).run(mk_source(app))
    assert rec.snapshots == [2, 4, 6, 8, 10]
    assert checkpoint_steps(str(tmp_path)) == [10, 8]
    # resume still works from the retained tail
    ref = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2, watermark=WM)).run(
            mk_source(app))
    rec2 = StreamService(eng, cfg).resume(mk_source(app))
    assert rec2.stats["replayed"] // INTERVAL == 10
    np.testing.assert_array_equal(rec2.final_values, ref.final_values)


# ---------------------------------------------------------------------------
# 4. source retry/backoff + straggler backfill alarm
# ---------------------------------------------------------------------------
def test_source_retry_recovers_transient_faults(tmp_path):
    app, eng = mk_engine()
    ref = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2, watermark=WM)).run(
            mk_source(app))
    plane = FaultPlane([Fault(SOURCE_PULL, 1, "raise"),
                        Fault(SOURCE_PULL, 5, "raise"),
                        Fault(SOURCE_PULL, 6, "raise")])
    cfg = chaos_cfg(None, source_retries=2, retry_backoff_s=0.001)
    rec = StreamService(eng, cfg).run(mk_source(app), faults=plane)
    # retried pulls lose nothing: the run is bitwise identical
    np.testing.assert_array_equal(rec.final_values, ref.final_values)
    assert_outputs_identical(rec.outputs, ref.outputs)
    assert rec.stats["source"]["retries"] == 3
    assert rec.stats["source"]["backoff_s"] > 0
    assert len(plane.fired) == 3


def test_source_retry_exhaustion_crashes_with_stats(tmp_path):
    app, eng = mk_engine()
    plane = FaultPlane([Fault(SOURCE_PULL, 3, "raise"),
                        Fault(SOURCE_PULL, 4, "raise")])
    cfg = chaos_cfg(None, source_retries=1, retry_backoff_s=0.001)
    svc = StreamService(eng, cfg)
    with pytest.raises(TransientSourceError):
        svc.run(mk_source(app), faults=plane)
    stats = svc.last_run.stats
    assert stats["crashed"]
    assert stats["error"]["type"] == "TransientSourceError"
    assert conservation_ok(stats)
    assert_ledger_balanced(stats)


def test_backfill_alarm_trips_and_logs_once(tmp_path, caplog):
    """Satellite: every pull missing the (zero) deadline trips the
    straggler backfill-ratio alarm — recorded in stats["source"] and
    logged exactly once per run."""
    app, eng = mk_engine()
    cfg = chaos_cfg(None, straggler=StragglerPolicy(deadline_s=0.0,
                                                    max_backfill_ratio=0.2))
    with caplog.at_level(logging.WARNING, logger="repro.runtime.service"):
        rec = StreamService(eng, cfg).run(mk_source(app))
    src = rec.stats["source"]
    assert src["deadline_misses"] == src["pulls"] > 0
    assert src["alarm"] and src["backfill_ratio"] > 0.2
    alarms = [r for r in caplog.records if "backfill" in r.getMessage()]
    assert len(alarms) == 1


def test_no_alarm_on_clean_run(tmp_path):
    app, eng = mk_engine()
    cfg = chaos_cfg(None)
    rec = StreamService(eng, cfg).run(mk_source(app))
    src = rec.stats["source"]
    assert src["retries"] == 0 and not src["alarm"]
    assert src["pulls"] == (N_EVENTS + 10) // 11


# ---------------------------------------------------------------------------
# 5. executor watchdog + error path
# ---------------------------------------------------------------------------
def test_watchdog_detects_hang_and_recovers_bitwise(tmp_path):
    """An executor hang is detected within the watchdog budget, every
    committable in-flight chunk drains, an *emergency* punctuation-aligned
    snapshot is published, and resume from it is bitwise exact."""
    app, eng = mk_engine()
    ref = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2, watermark=WM)).run(
            mk_source(app))    # warms the chunk compiles
    plane = FaultPlane([Fault(EXECUTOR_HANG, 2, "hang", duration_s=60.0)])
    cfg = chaos_cfg(tmp_path, watchdog_min_s=0.5, watchdog_grace_s=2.0)
    svc = StreamService(eng, cfg)
    with pytest.raises(ExecutorHungError):
        svc.run(mk_source(app), faults=plane)
    stats = svc.last_run.stats
    err = stats["error"]
    assert err["type"] == "ExecutorHungError" and not err["hung_thread"]
    # hang hit after the chunk ending interval 6 dispatched: the drain
    # committed it and the emergency snapshot landed at that boundary
    assert err["emergency_snapshot"] == 6
    assert svc.last_run.snapshots == [4, 6]
    assert len(svc.last_run.outputs) == 6     # intervals 0..6 committed
    assert conservation_ok(stats)
    assert_ledger_balanced(stats)
    assert stats["faults"] == [dict(site=EXECUTOR_HANG, visit=2,
                                    kind="hang", duration_s=60.0)]

    rec = StreamService(eng, cfg).resume(mk_source(app))
    assert rec.stats["replayed"] // INTERVAL == 6
    np.testing.assert_array_equal(rec.final_values, ref.final_values)
    assert_outputs_identical(rec.outputs, ref.outputs[6:])


def test_executor_exception_surfaces_with_stats_and_no_leaked_threads(
        tmp_path):
    """Satellite: an exception on the executor thread mid-chunk surfaces
    to the caller with the merged stats intact — and neither the executor
    nor the watchdog thread leaks."""
    app, eng = mk_engine()
    plane = FaultPlane([Fault("executor.crash", 1, "crash")])
    cfg = chaos_cfg(tmp_path)
    svc = StreamService(eng, cfg)
    with pytest.raises(InjectedCrashError):
        svc.run(mk_source(app), faults=plane)
    stats = svc.last_run.stats
    assert stats["crashed"] and stats["error"]["type"] == "InjectedCrashError"
    assert not stats["error"]["hung_thread"]
    assert conservation_ok(stats)
    assert_ledger_balanced(stats)
    assert not [t for t in threading.enumerate()
                if t.name.startswith("stream-service")], \
        "leaked a service thread"


def test_controller_decide_crash_recovers_bitwise(tmp_path):
    """The new ``controller.decide`` site: crash BETWEEN a decision and
    the snapshot that would have recorded it.  The decision dies with
    the run, is recomputed from the replayed record window after
    restore, and the continuation is bitwise identical to the
    uninterrupted adaptive run — decision trace included
    (DESIGN.md §2.9 replay contract)."""
    from repro.core.intervals import PhasedReplaySource

    app = ALL_APPS["gs"]
    mk_storm = lambda: PhasedReplaySource(app.gen_events, [
        (4 * INTERVAL, dict(theta=0.2)),
        (8 * INTERVAL, dict(theta=2.5)),
        (4 * INTERVAL, dict(theta=0.2)),
    ], seed=7, arrival_batch=2 * INTERVAL, jitter=JITTER)
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    ctl = ControllerConfig(window=2, sustain=2, cooldown=2,
                           degrade_scheme="lock", degrade_chain_frac=0.6)
    cfg = chaos_cfg(tmp_path / "ctl", controller=ctl)
    ref = StreamService(eng, chaos_cfg(None, controller=ctl)).run(mk_storm())
    assert any(d["knob"] == "scheme" for d in ref.decisions), ref.decisions

    plane = FaultPlane([Fault(site=CONTROLLER_DECIDE, at=0, kind="crash")])
    svc = StreamService(eng, cfg)
    with pytest.raises(InjectedCrashError, match="decision boundary"):
        svc.run(mk_storm(), faults=plane)
    crashed = svc.last_run
    assert crashed.stats["crashed"] and crashed.stats["faults"]
    assert conservation_ok(crashed.stats)
    # the dying run DID make the decision...
    assert crashed.decisions and \
        crashed.decisions[0] == ref.decisions[0]
    # ...but no published snapshot recorded it (strict-prefix contract)
    from repro.ckpt import read_manifest_meta
    for step in crashed.snapshots:
        meta = read_manifest_meta(cfg.ckpt_dir, step)
        assert all(d["g"] < step for d in meta["controller"]["trace"])

    rec = StreamService(eng, cfg).resume(mk_storm())
    assert rec.decisions == ref.decisions, \
        (rec.decisions, ref.decisions)
    snap = rec.stats["replayed"] // INTERVAL
    np.testing.assert_array_equal(rec.final_values, ref.final_values)
    assert_outputs_identical(rec.outputs, ref.outputs[snap:])


# ---------------------------------------------------------------------------
# 6. schedule generator properties (hypothesis)
# ---------------------------------------------------------------------------
def _schedule_valid(sched, n_pulls, n_chunks, n_snapshots,
                    n_decisions=0):
    ranges = {SOURCE_PULL: n_pulls, "executor.crash": n_chunks,
              EXECUTOR_HANG: n_chunks, SNAPSHOT_PUBLISH: n_snapshots,
              CONTROLLER_DECIDE: n_decisions}
    seen = set()
    hangs = 0
    for f in sched:
        assert f.site in SITE_KINDS and f.kind in SITE_KINDS[f.site]
        assert 0 <= f.at < ranges[f.site]
        assert (f.site, f.at) not in seen
        seen.add((f.site, f.at))
        hangs += f.kind == "hang"
    assert hangs <= 1, "more than one hang per schedule"


def test_schedule_generator_basic():
    sched = random_schedule(3, n_pulls=15, n_chunks=5, n_snapshots=2)
    assert sched == random_schedule(3, n_pulls=15, n_chunks=5,
                                    n_snapshots=2)
    _schedule_valid(sched, 15, 5, 2)
    assert schedule_from_json(schedule_to_json(sched)) == sched
    assert random_schedule(11, n_pulls=0, n_chunks=0, n_snapshots=0) == []
    _schedule_valid(random_schedule(3, n_pulls=15, n_chunks=5,
                                    n_snapshots=2, n_decisions=4),
                    15, 5, 2, 4)


# guarded import (not importorskip: that would skip the whole module and
# with it the chaos sweep above on an env without hypothesis)
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # pragma: no cover - hypothesis is in requirements-dev
    st = None

if st is not None:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_pulls=st.integers(0, 40),
           n_chunks=st.integers(0, 12), n_snapshots=st.integers(0, 6),
           n_decisions=st.integers(0, 6))
    def test_schedule_generator_deterministic_and_valid(
            seed, n_pulls, n_chunks, n_snapshots, n_decisions):
        a = random_schedule(seed, n_pulls=n_pulls, n_chunks=n_chunks,
                            n_snapshots=n_snapshots, n_decisions=n_decisions)
        b = random_schedule(seed, n_pulls=n_pulls, n_chunks=n_chunks,
                            n_snapshots=n_snapshots, n_decisions=n_decisions)
        assert a == b, "schedule is not a pure function of its seed"
        _schedule_valid(a, n_pulls, n_chunks, n_snapshots, n_decisions)
        assert schedule_from_json(schedule_to_json(a)) == a
        if n_pulls or n_chunks or n_snapshots or n_decisions:
            assert len(a) >= 1, \
                "non-empty site ranges must schedule a fault"


# ---------------------------------------------------------------------------
# 7. sharded chaos (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------
def test_faults_sharded():
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "faults_worker.py")],
        capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    verdicts = json.loads(proc.stdout.strip().splitlines()[-1])
    bad = {k: v for k, v in verdicts.items() if not v.get("ok")}
    assert not bad, bad
