"""Crash-injection recovery for the stream engine (DESIGN.md §2.6).

Mirrors ``runtime/ft.py``'s determinism contract for the streaming
service: punctuation-aligned snapshots through ``ckpt/`` + a replayable
source (pure function of its seed) make crash → restore → replay
**bitwise identical** to the uninterrupted run — final store, every
post-resume per-interval output, and the crashed run's committed prefix
all match the reference exactly.  The sharded (8 forced host devices)
case lives in tests/test_service_sharded.py.
"""
import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core.intervals import ReplaySource, WatermarkPolicy
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.runtime.service import ServiceConfig, StreamService

from test_service import assert_outputs_identical, conservation_ok

INTERVAL = 16


def crash_restore_replay(app_name, scheme, tmp_path, *, abort_repass=False,
                         crash_after=7, snapshot_every=4, jitter=3):
    app = ALL_APPS[app_name]
    store = app.make_store()
    eng = DualModeEngine(app, store,
                         EngineConfig(scheme=scheme,
                                      abort_repass=abort_repass))
    mk = lambda: ReplaySource(app.gen_events, 160, seed=7,
                              arrival_batch=11, jitter=jitter)
    cfg = ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2,
        snapshot_every=snapshot_every, ckpt_dir=str(tmp_path),
        watermark=WatermarkPolicy(allowed_lateness=jitter))
    # uninterrupted reference (no snapshots: prove they don't perturb)
    ref = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2,
        watermark=WatermarkPolicy(allowed_lateness=jitter))).run(mk())

    svc = StreamService(eng, cfg)
    with pytest.raises(RuntimeError, match="injected failure"):
        svc.run(mk(), crash_after_interval=crash_after)
    crashed = svc.last_run
    assert crashed.stats["crashed"]
    # even a crashed record conserves: dispatched-but-uncommitted chunks
    # count as unprocessed, they don't vanish from the accounting
    assert conservation_ok(crashed.stats)
    assert crashed.snapshots, "crash landed before the first snapshot"
    assert len(crashed.outputs) > crashed.snapshots[-1], \
        "crash must land after the snapshot to exercise replay"

    rec = StreamService(eng, cfg).resume(mk())
    snap = rec.stats["replayed"] // INTERVAL
    assert snap == crashed.snapshots[-1]

    # the recovered continuation reproduces the uninterrupted run bitwise
    np.testing.assert_array_equal(rec.final_values, ref.final_values)
    assert_outputs_identical(rec.outputs, ref.outputs[snap:])
    # the crashed run's committed prefix already matched it too
    assert_outputs_identical(crashed.outputs,
                             ref.outputs[: len(crashed.outputs)])
    assert conservation_ok(rec.stats)
    return rec


def test_crash_restore_replay_assoc_path(tmp_path):
    crash_restore_replay("gs", "tstream", tmp_path)


def test_crash_restore_replay_lockstep_abort_repass(tmp_path):
    """The gated lockstep path with the abort repass — state history
    depends on failed-transaction masking, so replay must reproduce the
    exact abort pattern too."""
    crash_restore_replay("sl", "tstream", tmp_path, abort_repass=True)


def test_recovery_spanning_multiple_snapshots(tmp_path):
    """Resume picks the LATEST punctuation-aligned snapshot."""
    rec = crash_restore_replay("gs", "tstream", tmp_path, crash_after=9,
                               snapshot_every=2)
    assert rec.stats["replayed"] // INTERVAL == 8


def test_resume_without_snapshot_raises(tmp_path):
    app = ALL_APPS["gs"]
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    svc = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2, snapshot_every=2,
        ckpt_dir=str(tmp_path / "empty")))
    with pytest.raises(FileNotFoundError):
        svc.resume(ReplaySource(app.gen_events, 64, seed=0))


def test_recovery_rejects_dropping_admission(tmp_path):
    """Admission drops depend on queue occupancy, which replay does not
    reproduce — snapshot/recovery must demand the backpressure mode."""
    with pytest.raises(AssertionError, match="admission"):
        ServiceConfig(punct_interval=INTERVAL, chunk_intervals=2,
                      snapshot_every=2, ckpt_dir=str(tmp_path),
                      admission="drop")
    app = ALL_APPS["gs"]
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    svc = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2, admission="drop"))
    with pytest.raises(ValueError, match="skip_intervals"):
        svc.run(ReplaySource(app.gen_events, 64, seed=0),
                skip_intervals=2)
