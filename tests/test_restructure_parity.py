"""Radix-partition restructure vs the lexsort reference (deterministic).

Every Chains field and sorted column must be **bit-identical** across
backbones — the same correctness bar PRs 1-2 set for the fused/sharded
drivers — swept over skewed/uniform key distributions, all-pad batches
and single-chain degenerates.  Also pins the packed sort's 32-bit
ceiling behavior (uint64 path under x64, warning + lexsort fallback
without) and the fused-driver parity per app.  The hypothesis sweep
lives in ``test_restructure_property.py``.
"""
import dataclasses
import logging
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core.restructure import (commit_from_histogram, commit_index,
                                    packed_sort_fits, restructure,
                                    restructure_path, restructure_stream)
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.core.types import OpBatch

from repro.kernels.radix_partition import ops as rpops
from repro.kernels.radix_partition import ref as rpref

CHAIN_FIELDS = ("order", "inv", "seg_start", "seg_id", "pos", "seg_end",
                "n_chains", "max_len")
OP_FIELDS = ("uid", "ts", "txn", "slot", "kind", "fun", "gate", "operand",
             "valid")


# ---------------------------------------------------------------------------
# kernel-level: ref (both rungs) and Pallas kernel vs a numpy oracle
# ---------------------------------------------------------------------------
def _stable_rank_np(keys: np.ndarray, n_buckets: int):
    """Numpy oracle: within-bucket stable rank + histogram."""
    order = np.argsort(keys, kind="stable")
    pos = np.empty(keys.shape[0], np.int64)
    pos[order] = np.arange(keys.shape[0])
    counts = np.bincount(keys, minlength=n_buckets)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return pos - starts[keys], counts


@pytest.mark.parametrize("n", [1, 7, 255, 256, 300, 2500, 40000])
@pytest.mark.parametrize("n_buckets", [1, 3, 127, 128, 129, 1000, 2000])
def test_radix_partition_rank_matches_oracle(n, n_buckets):
    rng = np.random.default_rng(n * 7 + n_buckets)
    keys = rng.integers(0, n_buckets, n).astype(np.int32)
    r0, c0 = _stable_rank_np(keys, n_buckets)
    # XLA counting ref (both the small-K transpose and blocked rungs)
    r1, c1 = rpref.radix_partition_rank_ref(jnp.asarray(keys), n_buckets)
    np.testing.assert_array_equal(np.asarray(r1), r0)
    np.testing.assert_array_equal(np.asarray(c1), c0)
    # Pallas kernel (interpret) when its bucket/row bounds hold
    if rpops.kernel_fits(n_buckets, n) and n <= 3000:
        r2, c2 = rpops.radix_partition_rank(jnp.asarray(keys), n_buckets,
                                            use_pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(r2), r0)
        np.testing.assert_array_equal(np.asarray(c2), c0)


def test_radix_partition_batched_single_dispatch():
    """The (batch, blocks) grid re-initializes the carry per batch."""
    rng = np.random.default_rng(0)
    bn, n, k = 5, 700, 37
    keys = rng.integers(0, k, (bn, n)).astype(np.int32)
    rk, ck = rpops.radix_partition_rank(jnp.asarray(keys), k,
                                        use_pallas=True, interpret=True)
    rr, cr = rpops.radix_partition_rank(jnp.asarray(keys), k,
                                        use_pallas=False)
    for i in range(bn):
        r0, c0 = _stable_rank_np(keys[i], k)
        np.testing.assert_array_equal(np.asarray(rk)[i], r0)
        np.testing.assert_array_equal(np.asarray(ck)[i], c0)
        np.testing.assert_array_equal(np.asarray(rr)[i], r0)
        np.testing.assert_array_equal(np.asarray(cr)[i], c0)


def test_radix_partition_skewed_all_one_bucket():
    keys = np.zeros((1000,), np.int32)
    r, c = rpops.radix_partition_rank(jnp.asarray(keys), 16,
                                      use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(r), np.arange(1000))
    assert int(np.asarray(c)[0]) == 1000 and int(np.asarray(c)[1:].sum()) == 0


def test_bucket_by_owner_backbones_identical():
    """The exchange's counting and sort backbones (band-dispatched in
    production, forceable via ``counting``) produce the same plan."""
    from repro.core.ownership import bucket_by_owner
    rng = np.random.default_rng(3)
    dst = jnp.asarray(rng.integers(0, 9, 1000).astype(np.int32))
    a = bucket_by_owner(dst, 8, 200, counting=True)
    b = bucket_by_owner(dst, 8, 200, counting=False)
    for f in ("take", "ok", "rank", "dst", "dropped"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


def test_kernel_fits_row_bound():
    """The f32 rank-carry exactness bound routes oversized batches to the
    XLA ref instead of silently rounding ranks."""
    assert rpops.kernel_fits(64, 1000)
    assert not rpops.kernel_fits(64, 1 << 24)
    assert not rpops.kernel_fits(1 << 13, 1000)   # bucket VMEM bound


def mk_batch(uid: np.ndarray, valid: np.ndarray, max_ops: int = 4) -> OpBatch:
    """Row-major (ts, slot) batch around the given uid/valid columns."""
    n = uid.shape[0]
    idx = np.arange(n, dtype=np.int32)
    rng = np.random.default_rng(n)
    return OpBatch(
        uid=jnp.asarray(uid.astype(np.int32)),
        ts=jnp.asarray(idx // max_ops), txn=jnp.asarray(idx // max_ops),
        slot=jnp.asarray(idx % max_ops),
        kind=jnp.zeros((n,), jnp.int32),
        fun=jnp.asarray(rng.integers(0, 3, n).astype(np.int32)),
        gate=jnp.full((n,), -1, jnp.int32),
        operand=jnp.asarray(rng.uniform(size=(n, 2)).astype(np.float32)),
        valid=jnp.asarray(valid))


def assert_partition_matches_lexsort(ops: OpBatch, pad_uid: int, *,
                                     use_pallas: bool = False):
    ref_s, ref_c = restructure(ops, pad_uid, rowmajor_ts=True,
                               method="lexsort")
    got_s, got_c = restructure(ops, pad_uid, rowmajor_ts=True,
                               method="partition", use_pallas=use_pallas)
    for f in CHAIN_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(got_c, f)),
                                      np.asarray(getattr(ref_c, f)),
                                      err_msg=f"Chains.{f}")
    for f in OP_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(got_s, f)),
                                      np.asarray(getattr(ref_s, f)),
                                      err_msg=f"sorted.{f}")
    # the partition histogram must reproduce the searchsorted commit map
    p0, ok0 = commit_index(ref_s.uid, pad_uid + 1)
    p1, ok1 = commit_from_histogram(got_c.counts, got_c.starts)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p0))
    np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok0))


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n_slots,theta,pad_frac", [
    (1, 0.0, 0.0), (7, 0.0, 0.1), (60, 0.6, 0.1), (300, 1.2, 0.5),
    (13, 0.6, 0.9),
])
def test_partition_matches_lexsort(seed, n_slots, theta, pad_frac):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 60)) * 4
    w = 1.0 / np.power(np.arange(1, n_slots + 1, dtype=np.float64), theta)
    uid = rng.choice(n_slots, size=n, p=w / w.sum())
    valid = rng.uniform(size=n) > pad_frac
    assert_partition_matches_lexsort(mk_batch(uid, valid), n_slots)


def test_all_pad_batch():
    uid = np.zeros((24,), np.int32)
    assert_partition_matches_lexsort(mk_batch(uid, np.zeros((24,), bool)), 7)


def test_single_chain():
    uid = np.full((40,), 3, np.int32)
    assert_partition_matches_lexsort(mk_batch(uid, np.ones((40,), bool)), 9)


def test_partition_kernel_path_matches():
    rng = np.random.default_rng(11)
    uid = rng.integers(0, 37, 513)
    valid = rng.uniform(size=513) > 0.2
    assert_partition_matches_lexsort(mk_batch(uid, valid), 37,
                                     use_pallas=True)


def test_restructure_stream_single_dispatch_matches_vmap():
    """Batched partition (one kernel dispatch) == per-batch restructure."""
    rng = np.random.default_rng(5)
    n_i, n = 3, 256
    uid = rng.integers(0, 13, (n_i, n)).astype(np.int32)
    batches = [mk_batch(uid[i], rng.uniform(size=n) > 0.1)
               for i in range(n_i)]
    ops_all = OpBatch(*[jnp.stack([getattr(b, f.name) for b in batches])
                        for f in dataclasses.fields(OpBatch)])
    for use_pallas in (False, True):
        sall, call = restructure_stream(ops_all, 13, rowmajor_ts=True,
                                        method="partition",
                                        use_pallas=use_pallas)
        for i, b in enumerate(batches):
            rs, rc = restructure(b, 13, rowmajor_ts=True, method="lexsort")
            for f in CHAIN_FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(call, f))[i],
                    np.asarray(getattr(rc, f)), err_msg=f"[{i}].{f}")
            np.testing.assert_array_equal(np.asarray(sall.uid)[i],
                                          np.asarray(rs.uid))


# ---------------------------------------------------------------------------
# fused drivers: partition backbone is bit-identical to lexsort on all apps
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("app_name", ["gs", "tp", "sl", "ob"])
def test_fused_driver_partition_vs_lexsort(app_name):
    app = ALL_APPS[app_name]
    rng = np.random.default_rng(7)
    stream = app.gen_events(rng, 64)
    store = app.make_store()
    outs = {}
    for method in ("partition", "lexsort"):
        eng = DualModeEngine(app, store,
                             EngineConfig(restructure_method=method))
        outs[method] = eng.run_stream(store.values, stream, 16)
    o_p, v_p = outs["partition"]
    o_l, v_l = outs["lexsort"]
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_l))
    for a, b in zip(o_p, o_l):
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_auto_ladder_engages_partition_on_compact_store():
    """Inside its measured regime (compact key space, large N) the auto
    ladder resolves the partition rung and stays bit-identical."""
    assert restructure_path(1 << 18, 15, rowmajor_ts=True) == "partition"
    assert restructure_path(1 << 10, 15, rowmajor_ts=True) == "packed"
    assert restructure_path(1 << 18, 500, rowmajor_ts=True) == "packed"
    rng = np.random.default_rng(9)
    n = 1 << 18
    ops = mk_batch(rng.integers(0, 15, n), rng.uniform(size=n) > 0.1)
    sa, ca = restructure(ops, 15, rowmajor_ts=True)          # auto
    assert ca.counts is not None, "auto did not take the partition rung"
    sl, cl = restructure(ops, 15, rowmajor_ts=True, method="lexsort")
    for f in CHAIN_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ca, f)),
                                      np.asarray(getattr(cl, f)),
                                      err_msg=f"Chains.{f}")
    np.testing.assert_array_equal(np.asarray(sa.uid), np.asarray(sl.uid))


# ---------------------------------------------------------------------------
# packed-sort 32-bit ceiling (satellite): uint64 path / explicit fallback
# ---------------------------------------------------------------------------
def test_packed_sort_fits_bits():
    assert packed_sort_fits(1 << 19, 10_000, bits=32) is False
    assert packed_sort_fits(1 << 19, 10_000, bits=64) is True
    assert packed_sort_fits(1 << 10, 10_000, bits=32) is True


def test_path_warns_and_falls_back_without_x64(caplog):
    with caplog.at_level(logging.WARNING, logger="repro.core.restructure"):
        path = restructure_path(1 << 19, 10_000, rowmajor_ts=True)
    assert path == "lexsort"
    assert any("packed-uint64" in r.message for r in caplog.records)


def test_forced_partition_requires_rowmajor():
    with pytest.raises(ValueError, match="rowmajor_ts"):
        restructure_path(128, 7, rowmajor_ts=False, method="partition")


def test_packed_sort_uint64_subprocess():
    """With x64 enabled, the >32-bit pack takes the uint64 path and stays
    bit-identical to the stable-sort reference (subprocess: x64 is
    process-global)."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core.restructure import packed_stable_sort, packed_sort_fits
n, m = 1 << 19, 10_000
assert not packed_sort_fits(n, m, bits=32)
rng = np.random.default_rng(0)
major = jnp.asarray(rng.integers(0, m + 1, n).astype(np.int32))
order, major_s, pos = packed_stable_sort(major, m)
ref = np.argsort(np.asarray(major), kind="stable")
np.testing.assert_array_equal(np.asarray(order), ref)
np.testing.assert_array_equal(np.asarray(major_s), np.asarray(major)[ref])
inv = np.empty(n, np.int64); inv[ref] = np.arange(n)
np.testing.assert_array_equal(np.asarray(pos), inv)
print("u64 ok")
"""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "u64 ok" in out.stdout
