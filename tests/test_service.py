"""Continuous streaming service (DESIGN.md §2.6).

Contracts pinned here:

1. **Chunked == monolithic**: the service's K-interval chunked execution
   (donated state carry across chunk calls, including the recompiled tail
   chunk) is *bit-identical* to one monolithic ``run_stream`` over the
   same events — for every app, for tstream and mvlk, and with
   out-of-order arrivals whose jitter stays inside the watermark window.
2. **Watermark accounting**: late rows are rerouted or dropped and
   counted either way; the conservation law holds (every arrived event is
   processed exactly once, counted dropped, or still pending); emitted
   watermarks are monotone.
3. **Admission control**: the bounded ready queue drops whole arrival
   batches with accounting under ``admission="drop"``; ``"block"``
   backpressures the source and never drops.
4. **Merged stats**: one structured record covering watermark, admission
   and exchange drops; each category logged at most once per run.
"""
import logging

import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core.intervals import ReplaySource, WatermarkPolicy
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.runtime.service import (ServiceConfig, StreamService,
                                   ts_base_for)


def conservation_ok(stats):
    d = stats["drops"]
    return stats["arrived"] == (stats["processed"] + stats["replayed"]
                                + d["watermark"] + d["admission"]
                                + stats["unprocessed"])


def assert_outputs_identical(svc_outputs, ref_outputs):
    assert len(svc_outputs) == len(ref_outputs) > 0
    for i, (a, b) in enumerate(zip(svc_outputs, ref_outputs)):
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(
                np.asarray(a[k]), np.asarray(b[k]),
                err_msg=f"output {k} interval {i}")


def run_service_and_reference(app, scheme, *, n_events=80, interval=16,
                              chunk=2, jitter=5, seed=11, cfg_kw=None):
    """Service over a jittered arrival stream vs monolithic run_stream on
    the in-order events.  80 events / interval 16 / K=2 covers the tail
    chunk (chunks of 2, 2, 1 intervals)."""
    src = ReplaySource(app.gen_events, n_events, seed=seed,
                       arrival_batch=13, jitter=jitter)
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig(scheme=scheme))
    outs_ref, vals_ref = eng.run_stream(store.values, src.in_order_events,
                                        interval, fused=True)
    cfg = ServiceConfig(punct_interval=interval, chunk_intervals=chunk,
                        watermark=WatermarkPolicy(allowed_lateness=jitter),
                        **(cfg_kw or {}))
    rec = StreamService(eng, cfg).run(src)
    return rec, outs_ref, vals_ref


@pytest.mark.parametrize("scheme,app_name", [
    ("tstream", "gs"),    # segscan fast path
    ("tstream", "tp"),    # heterogeneous max tables
    ("tstream", "sl"),    # gated lockstep path
    ("tstream", "ob"),    # non-associative lockstep path
    ("mvlk", "gs"),
])
def test_chunked_service_matches_monolithic_bitwise(scheme, app_name):
    app = ALL_APPS[app_name]
    rec, outs_ref, vals_ref = run_service_and_reference(app, scheme)
    np.testing.assert_array_equal(rec.final_values, np.asarray(vals_ref))
    assert_outputs_identical(rec.outputs, outs_ref)
    assert conservation_ok(rec.stats)
    assert rec.stats["drops"] == dict(watermark=0, admission=0, exchange=0)


def test_chunk_size_and_arrival_pattern_invariance():
    """Different K and arrival batchings reach the same bits."""
    app = ALL_APPS["gs"]
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig())
    mk = lambda b, j: ReplaySource(app.gen_events, 96, seed=4,
                                   arrival_batch=b, jitter=j)
    ref, vals_ref = eng.run_stream(store.values, mk(7, 0).in_order_events,
                                   16, fused=True)
    for chunk, batch, jitter in ((1, 7, 0), (3, 29, 4), (6, 96, 9)):
        rec = StreamService(eng, ServiceConfig(
            punct_interval=16, chunk_intervals=chunk,
            watermark=WatermarkPolicy(allowed_lateness=jitter))).run(
                mk(batch, jitter))
        np.testing.assert_array_equal(rec.final_values, np.asarray(vals_ref))
        assert_outputs_identical(rec.outputs, ref)


def test_watermark_drop_accounting_and_monotonicity():
    """Jitter far beyond the lateness window: drops are counted, the run
    completes degraded (never crashes), conservation holds, and recorded
    per-interval watermarks are monotone."""
    app = ALL_APPS["gs"]
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    rec = StreamService(eng, ServiceConfig(
        punct_interval=16, chunk_intervals=2,
        watermark=WatermarkPolicy(allowed_lateness=2, late="drop"))).run(
            ReplaySource(app.gen_events, 256, seed=2, arrival_batch=16,
                         jitter=24))
    assert rec.stats["drops"]["watermark"] > 0
    assert conservation_ok(rec.stats)
    wms = [c["watermark"] for c in rec.commits]
    assert wms == sorted(wms)
    assert len(rec.outputs) * 16 == rec.stats["processed"]


def test_watermark_reroute_accounting():
    """Same jittered stream under reroute: nothing drops, late rows are
    counted and land in later intervals, conservation holds."""
    app = ALL_APPS["gs"]
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    rec = StreamService(eng, ServiceConfig(
        punct_interval=16, chunk_intervals=2,
        watermark=WatermarkPolicy(allowed_lateness=2, late="reroute"))).run(
            ReplaySource(app.gen_events, 256, seed=2, arrival_batch=16,
                         jitter=24))
    assert rec.stats["late_rerouted"] > 0
    assert rec.stats["drops"]["watermark"] == 0
    assert conservation_ok(rec.stats)
    assert sum(c["n_late"] for c in rec.commits) > 0


def test_admission_drop_bounded_queue():
    """A firehose source against a tiny queue: whole arrival batches are
    rejected with accounting; the run still completes."""
    app = ALL_APPS["gs"]
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    rec = StreamService(eng, ServiceConfig(
        punct_interval=16, chunk_intervals=1, queue_intervals=2,
        admission="drop")).run(
            ReplaySource(app.gen_events, 512, seed=1, arrival_batch=64))
    assert rec.stats["drops"]["admission"] > 0
    assert conservation_ok(rec.stats)


def test_admission_block_never_drops():
    app = ALL_APPS["gs"]
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    rec = StreamService(eng, ServiceConfig(
        punct_interval=16, chunk_intervals=1, queue_intervals=1,
        admission="block")).run(
            ReplaySource(app.gen_events, 256, seed=1, arrival_batch=64))
    assert rec.stats["drops"] == dict(watermark=0, admission=0, exchange=0)
    assert rec.stats["processed"] == 256
    assert conservation_ok(rec.stats)


def test_max_intervals_and_latency_record():
    app = ALL_APPS["gs"]
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    rec = StreamService(eng, ServiceConfig(
        punct_interval=16, chunk_intervals=2)).run(
            ReplaySource(app.gen_events, 160, seed=6, arrival_batch=32),
            max_intervals=4)
    assert len(rec.outputs) == 4
    lat = rec.latency_s()
    assert lat.shape == (4 * 16,)
    assert np.all(lat >= 0)
    pct = rec.latency_percentiles((50, 99))
    assert pct["p50"] <= pct["p99"]
    assert rec.sustained_events_per_s() > 0
    assert conservation_ok(rec.stats)
    assert rec.stats["unprocessed"] > 0  # leftovers are accounted, not lost


def test_ts_base_int32_safe_forever():
    """An unbounded run's timestamp base never overflows int32: it equals
    g*interval below the wrap and stays inside int32 arbitrarily far in,
    with monotone per-chunk bases across every wrap boundary."""
    for interval in (16, 512, 4096):
        wrap = 2 ** 30 // interval
        for g in (0, 1, 1000, wrap - 1):
            assert ts_base_for(g, interval) == g * interval
        for g in (wrap, 3 * wrap + 17, 2 ** 40):
            base = ts_base_for(g, interval)
            assert 0 <= base < 2 ** 30
            assert base + interval <= 2 ** 31 - 1
            # within one chunk the bases stay monotone after any wrap
            assert ts_base_for(g, interval) % interval == 0


def test_each_drop_category_logged_once_per_run(caplog):
    """Drops spread over many intervals produce ONE log line per category
    per run — not one per interval."""
    app = ALL_APPS["gs"]
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    with caplog.at_level(logging.INFO, logger="repro.runtime.service"):
        # run A: watermark drops in most intervals (heavy jitter, no
        # admission pressure)
        rec_a = StreamService(eng, ServiceConfig(
            punct_interval=16, chunk_intervals=2,
            watermark=WatermarkPolicy(allowed_lateness=1, late="drop"))).run(
                ReplaySource(app.gen_events, 256, seed=9, arrival_batch=16,
                             jitter=32))
        # run B: admission drops across many cycles (firehose, tiny queue)
        rec_b = StreamService(eng, ServiceConfig(
            punct_interval=16, chunk_intervals=1, queue_intervals=2,
            admission="drop")).run(
                ReplaySource(app.gen_events, 512, seed=9, arrival_batch=64))
    assert rec_a.stats["drops"]["watermark"] > 0
    assert rec_b.stats["drops"]["admission"] > 0
    for needle in ("watermark policy dropped", "admission control dropped"):
        hits = [r for r in caplog.records if needle in r.getMessage()]
        assert len(hits) == 1, f"{needle!r} logged {len(hits)} times"
