"""Dual-mode scheduler: end-to-end punctuation-interval processing."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core.scheduler import DualModeEngine, EngineConfig


@pytest.mark.parametrize("app_name", list(ALL_APPS))
def test_stream_run_matches_lock(app_name):
    """Running several punctuation intervals through TStream's dual-mode
    engine yields the same state evolution as the LOCK (oracle) engine."""
    app = ALL_APPS[app_name]
    rng = np.random.default_rng(7)
    stream = app.gen_events(rng, 96)
    store = app.make_store()

    eng_t = DualModeEngine(app, store, EngineConfig(scheme="tstream"))
    eng_l = DualModeEngine(app, store, EngineConfig(scheme="lock"))
    outs_t, vals_t = eng_t.run_stream(store.values, stream, punct_interval=32)
    outs_l, vals_l = eng_l.run_stream(store.values, stream, punct_interval=32)

    np.testing.assert_allclose(np.asarray(vals_t), np.asarray(vals_l),
                               rtol=1e-5, atol=1e-5)
    for ot, ol in zip(outs_t, outs_l):
        for k in ot:
            np.testing.assert_allclose(np.asarray(ot[k]), np.asarray(ol[k]),
                                       rtol=1e-5, atol=1e-5, err_msg=k)


def test_outputs_have_batch_shape():
    app = ALL_APPS["tp"]
    rng = np.random.default_rng(0)
    stream = app.gen_events(rng, 64)
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig())
    outs, _ = eng.run_stream(store.values, stream, punct_interval=64)
    assert outs[0]["toll"].shape == (64,)
    assert np.all(np.isfinite(np.asarray(outs[0]["toll"])))


def test_abort_repass_masks_failed_txns():
    """§IV-C2 abort handling: with abort_repass, a failed transfer leaves no
    partial effects (rollback-free re-execution)."""
    app = ALL_APPS["sl"]
    rng = np.random.default_rng(3)
    stream = app.gen_events(rng, 64)
    # huge amounts -> most transfers fail on insufficient balance
    stream["amount"] = (stream["amount"] * 100).astype(np.float32)
    store = app.make_store()
    eng = DualModeEngine(app, store,
                         EngineConfig(scheme="tstream", abort_repass=True))
    outs, vals = eng.run_stream(store.values, stream, punct_interval=64)
    # conservation: deposits add money; transfers conserve it.  With the
    # repass, failed transfers contribute nothing.
    deposited = np.sum(stream["amount"][~stream["is_transfer"]][:64]
                       if len(stream["amount"]) >= 64 else 0)
    total_before = float(np.sum(np.asarray(store.values)))
    total_after = float(np.sum(np.asarray(vals)))
    moved = total_after - total_before
    assert moved >= -1e-3
    # committed transfers conserve: delta == 2 * sum(deposit amounts)
    dep_amt = stream["amount"][:64][~stream["is_transfer"][:64]]
    np.testing.assert_allclose(moved, 2 * float(np.sum(dep_amt)), rtol=1e-4)


def test_latency_stats_exposed():
    app = ALL_APPS["gs"]
    rng = np.random.default_rng(0)
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig())
    events = {k: jnp.asarray(v) for k, v in app.gen_events(rng, 32).items()}
    out, vals, stats = eng.step(store.values, events, 0)
    assert int(stats.n_chains) >= 1
    assert int(stats.max_chain) >= 1
