"""Fused partition→segscan→commit megakernel (DESIGN.md §2.8).

The megakernel rung replaces the staged ``plan → coefs → execute``
chain-evaluation pipeline with one dispatch.  Its admission contract:

1. **Bit-identical** to the staged partition path — at the unit level
   (``fused_chain_eval`` XLA ref AND Pallas interpret kernel vs the
   staged pipeline on odd shapes: non-multiple-of-lane N, single chain,
   all-pad, skewed buckets, n=1) and at the engine level (all four apps
   × tstream/mvlk × XLA/Pallas, ``restructure_method="megakernel"`` vs
   ``"partition"``), plus the sharded driver (subprocess, 8 host
   devices).
2. Forcing the rung on an ineligible store (max-typed tables) falls back
   to the staged path with a one-time warning — never wrong results.
3. ``mega_kernel_fits`` routes oversized intervals to the XLA ref.
"""
import dataclasses
import json
import logging
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core.engines import (simple_affine_luts, tstream_scan_coefs,
                                tstream_scan_execute, tstream_scan_plan)
from repro.core.restructure import megakernel_engaged, restructure
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.core.types import (F_ADD, F_MAX, F_NOP, F_PUT, F_READ, OpBatch,
                              make_store)
from repro.kernels.megakernel import fused_chain_eval, mega_kernel_fits

FUNS = (F_NOP, F_READ, F_PUT, F_ADD)


def mk_batch(uid, valid, n_slots, *, w=2, max_ops=4, seed=None):
    """Row-major (ts, slot) batch over the simple-affine fun family."""
    n = uid.shape[0]
    idx = np.arange(n, dtype=np.int32)
    rng = np.random.default_rng(n if seed is None else seed)
    return OpBatch(
        uid=jnp.asarray(uid.astype(np.int32)),
        ts=jnp.asarray(idx // max_ops), txn=jnp.asarray(idx // max_ops),
        slot=jnp.asarray(idx % max_ops),
        kind=jnp.zeros((n,), jnp.int32),
        fun=jnp.asarray(rng.integers(0, len(FUNS), n).astype(np.int32)),
        gate=jnp.full((n,), -1, jnp.int32),
        operand=jnp.asarray(rng.normal(size=(n, w)).astype(np.float32)),
        valid=jnp.asarray(valid))


def staged_pipeline(store, ops, pad_uid):
    """The rung the megakernel must reproduce bit for bit."""
    pres = restructure(ops, pad_uid, rowmajor_ts=True, light=True,
                       method="partition")
    plan = tstream_scan_plan(store, ops, FUNS, prestructured=pres)
    plan = tstream_scan_coefs(plan, use_pallas=False)
    res, vals, _ = tstream_scan_execute(store.values, plan, pad_uid,
                                        raw=True)
    return res, vals


def assert_fused_matches_staged(uid, valid, n_slots, *, seed=None):
    store = make_store([n_slots], 2)
    pad_uid = store.pad_uid
    ops = mk_batch(uid, valid, n_slots, seed=seed)
    res_ref, vals_ref = staged_pipeline(store, ops, pad_uid)
    a_lut, b_lut = simple_affine_luts(FUNS)
    sops, ch = restructure(ops, pad_uid, rowmajor_ts=True, light=True,
                           method="partition", geometry=False)
    assert ch.seg_id is None and ch.pos is None  # the light mega plan
    for use_pallas in (False, True):
        res, vals, stats = fused_chain_eval(
            store.values, sops, ch, pad_uid, a_lut=a_lut, b_lut=b_lut,
            use_pallas=use_pallas, interpret=True)
        tag = "pallas" if use_pallas else "ref"
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.asarray(vals_ref),
                                      err_msg=f"values ({tag})")
        for k in res_ref:
            np.testing.assert_array_equal(np.asarray(res[k]),
                                          np.asarray(res_ref[k]),
                                          err_msg=f"{k} ({tag})")
        assert stats.path == "megakernel"


# ---------------------------------------------------------------------------
# unit level: odd shapes, both dispatch arms
# ---------------------------------------------------------------------------
def test_fused_odd_n_skewed_buckets():
    rng = np.random.default_rng(7)
    n, s = 160, 37                       # n not a multiple of 128 lanes
    w = 1.0 / np.arange(1, s + 1, dtype=np.float64)
    uid = rng.choice(s, size=n, p=w / w.sum())
    valid = rng.uniform(size=n) > 0.15
    assert_fused_matches_staged(uid, valid, s)


def test_fused_single_chain():
    uid = np.full((40,), 3, np.int64)
    assert_fused_matches_staged(uid, np.ones((40,), bool), 8)


def test_fused_all_pad():
    rng = np.random.default_rng(2)
    uid = rng.integers(0, 8, 24)
    assert_fused_matches_staged(uid, np.zeros((24,), bool), 8)


def test_fused_n1():
    assert_fused_matches_staged(np.zeros((1,), np.int64),
                                np.ones((1,), bool), 4)


def test_fused_mixed_pad_tail():
    rng = np.random.default_rng(11)
    uid = rng.integers(0, 5, 100)
    valid = np.ones((100,), bool)
    valid[60:] = False                    # trailing pad block
    assert_fused_matches_staged(uid, valid, 5)


def test_mega_kernel_fits_bounds():
    from repro.kernels.megakernel.ops import MEGA_MAX_CELLS, MEGA_MAX_ROWS
    assert mega_kernel_fits(160, 38)
    assert not mega_kernel_fits(MEGA_MAX_ROWS + 8, 38)       # row bound
    assert not mega_kernel_fits(4096, MEGA_MAX_CELLS // 4096 + 256)
    # oversized intervals still evaluate — through the XLA ref
    rng = np.random.default_rng(5)
    uid = rng.integers(0, 6, 64)
    store = make_store([6], 2)
    ops = mk_batch(uid, np.ones((64,), bool), 6)
    a_lut, b_lut = simple_affine_luts(FUNS)
    sops, ch = restructure(ops, store.pad_uid, rowmajor_ts=True,
                           light=True, method="partition", geometry=False)
    import repro.kernels.megakernel.ops as mops
    res_p, vals_p, _ = fused_chain_eval(
        store.values, sops, ch, store.pad_uid, a_lut=a_lut, b_lut=b_lut,
        use_pallas=True, interpret=True)
    orig = mops.MEGA_MAX_ROWS
    try:
        mops.MEGA_MAX_ROWS = 8            # force the structural fallback
        res_r, vals_r, _ = fused_chain_eval(
            store.values, sops, ch, store.pad_uid, a_lut=a_lut,
            b_lut=b_lut, use_pallas=True, interpret=True)
    finally:
        mops.MEGA_MAX_ROWS = orig
    np.testing.assert_array_equal(np.asarray(vals_p), np.asarray(vals_r))
    for k in res_p:
        np.testing.assert_array_equal(np.asarray(res_p[k]),
                                      np.asarray(res_r[k]), err_msg=k)


# ---------------------------------------------------------------------------
# engine level: the forced rung vs the staged partition path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("app_name", ["gs", "tp", "sl", "ob"])
@pytest.mark.parametrize("scheme", ["tstream", "mvlk"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_engine_megakernel_rung_bit_identical(app_name, scheme, use_pallas):
    app = ALL_APPS[app_name]
    rng = np.random.default_rng(13)
    stream = app.gen_events(rng, 64)
    store = app.make_store()
    outs = {}
    for method in ("partition", "megakernel"):
        cfg = EngineConfig(scheme=scheme, restructure_method=method,
                           use_pallas=use_pallas)
        eng = DualModeEngine(app, store, cfg)
        outs[method] = eng.run_stream(store.values, stream, 16, fused=True)
    outs_a, vals_a = outs["partition"]
    outs_b, vals_b = outs["megakernel"]
    np.testing.assert_array_equal(np.asarray(vals_a), np.asarray(vals_b))
    for a, b in zip(outs_a, outs_b):
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]), err_msg=k)


def test_auto_band_engages_megakernel():
    """Inside the measured CPU win band "auto" engages the rung; below
    it (or past the bucket bound) the staged path carries."""
    from repro.kernels.autotune import mega_bounds
    band = mega_bounds("cpu")
    assert megakernel_engaged(band["min_rows"], 128, method="auto",
                              has_max=False, funs_simple=True)
    assert not megakernel_engaged(band["min_rows"] - 1, 128, method="auto",
                                  has_max=False, funs_simple=True)
    assert not megakernel_engaged(band["min_rows"],
                                  band["max_buckets"] + 1, method="auto",
                                  has_max=False, funs_simple=True)
    # structural ineligibility always wins
    assert not megakernel_engaged(band["min_rows"], 128, method="auto",
                                  has_max=True, funs_simple=True)
    assert not megakernel_engaged(band["min_rows"], 128, method="auto",
                                  has_max=False, funs_simple=False)


def test_forced_rung_on_max_store_falls_back_with_one_warning(caplog):
    import importlib
    R = importlib.import_module("repro.core.restructure")
    R._MEGA_FALLBACK_WARNED.clear()
    with caplog.at_level(logging.WARNING, logger="repro.core.restructure"):
        assert not megakernel_engaged(64, 16, method="megakernel",
                                      has_max=True, funs_simple=True)
        assert not megakernel_engaged(64, 16, method="megakernel",
                                      has_max=True, funs_simple=True)
    warns = [r for r in caplog.records
             if "method='megakernel' forced but" in r.getMessage()]
    assert len(warns) == 1               # once per process, not per call

    # and the TP engine (max-typed tables) still matches bit for bit
    app = ALL_APPS["tp"]
    rng = np.random.default_rng(4)
    stream = app.gen_events(rng, 32)
    store = app.make_store()
    outs = {}
    for method in ("partition", "megakernel"):
        eng = DualModeEngine(app, store,
                             EngineConfig(restructure_method=method))
        outs[method] = eng.run_stream(store.values, stream, 16, fused=True)
    np.testing.assert_array_equal(np.asarray(outs["partition"][1]),
                                  np.asarray(outs["megakernel"][1]))


def test_simple_affine_luts_gate():
    from repro.core.types import FunSpec
    assert simple_affine_luts(FUNS) is not None
    # max-type funs are non-affine -> identity in the LUT; they are
    # excluded by the drivers' has_max gate, not here
    assert simple_affine_luts(FUNS + (F_MAX,)) is not None
    # a general affine fun (no simple (a, b) shape) disables the rung
    scale2 = FunSpec("scale2", lambda v, o: 2.0 * v + o,
                     affine=lambda o: (2.0 * jnp.ones_like(o), o))
    assert simple_affine_luts(FUNS + (scale2,)) is None
    a_lut, b_lut = simple_affine_luts(FUNS)
    np.testing.assert_array_equal(np.asarray(a_lut), [1.0, 1.0, 0.0, 1.0])
    np.testing.assert_array_equal(np.asarray(b_lut),
                                  [False, False, True, True])


# ---------------------------------------------------------------------------
# sharded driver (subprocess: 8 forced host devices)
# ---------------------------------------------------------------------------
_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, numpy as np
from repro.apps import ALL_APPS
from repro.core.scheduler import DualModeEngine, EngineConfig

out = {}
mesh = jax.make_mesh((8,), ("dev",))
for layout in ("shared_nothing", "shared_everything"):
    app = ALL_APPS["gs"]
    rng = np.random.default_rng(11)
    stream = app.gen_events(rng, 128)
    store = app.make_store()
    ref = DualModeEngine(app, store,
                         EngineConfig(restructure_method="partition"))
    outs_r, vals_r = ref.run_stream(store.values, stream, 32, fused=True)
    eng = DualModeEngine(app, store,
                         EngineConfig(restructure_method="megakernel"),
                         mesh=mesh, layout=layout, exchange_slack=8.0)
    outs_s, vals_s = eng.run_stream(store.values, stream, 32)
    ok = (int(np.sum(eng.last_exchange_stats["dropped"])) == 0
          and np.array_equal(np.asarray(vals_s), np.asarray(vals_r))
          and all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
                  for a, b in zip(outs_s, outs_r) for k in a))
    out[layout] = ok
print(json.dumps(out))
"""


def test_sharded_megakernel_bit_identical():
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src"),
                    os.environ.get("PYTHONPATH", "")]))
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    verdict = json.loads(proc.stdout.strip().splitlines()[-1])
    assert verdict == {"shared_nothing": True, "shared_everything": True}
