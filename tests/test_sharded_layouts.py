"""Chain-shard layout correctness (the paper's NUMA configurations) as a
pytest — all three layouts must equal the sequential oracle on the
per-batch path AND be bit-identical to the single-device fused driver on
the fused sharded streaming path.  Runs in a subprocess (needs an
8-device placeholder mesh)."""
import json
import os
import subprocess
import sys


def test_all_layouts_oracle_correct():
    worker = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "fig14_numa_worker.py")
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=1500)
    assert proc.returncode == 0, proc.stderr[-1500:]
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    assert set(data) == {"shared_nothing", "shared_per_socket",
                         "shared_everything"}
    for layout, d in data.items():
        assert d["correct"], f"{layout} diverged from the oracle"
        assert d["fused_bit_identical"], \
            f"{layout} fused sharded stream diverged from the fused driver"
        assert d["fused_dropped"] == 0, f"{layout} dropped exchange ops"
