"""Correctness of the §Perf optimizations:
  * serving head padding/replication (decode output must be unchanged)
  * expert-parallel MoE via shard_map (must match the pjit path, given
    enough capacity)
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import decode_step, forward, init_cache, init_params
from repro.models.serving import pad_params_for_serving, serving_padded


@pytest.mark.parametrize("arch,msize", [("minicpm-2b", 8),   # MHA pad
                                        ("granite-34b", 2),  # GQA replicate
                                        ("qwen1.5-110b", 8)])
def test_head_padding_is_inert(arch, msize):
    cfg = get_arch(arch).smoke()
    padded = serving_padded(cfg, msize)
    if padded is cfg:
        pytest.skip("no padding needed at this axis size")
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    pparams = pad_params_for_serving(cfg, padded, params)

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    ref = forward(cfg, params, dict(tokens=toks), remat="none")
    out = forward(padded, pparams, dict(tokens=toks), remat="none")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-4, atol=2e-4)

    # decode path too
    c0 = init_cache(cfg, 2, 8, dtype=jnp.float32)
    c1 = init_cache(padded, 2, 8, dtype=jnp.float32)
    l0, _ = decode_step(cfg, params, c0, toks[:, :1], jnp.int32(0))
    l1, _ = decode_step(padded, pparams, c1, toks[:, :1], jnp.int32(0))
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l0, np.float32),
                               rtol=2e-4, atol=2e-4)


EP_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import moe_ep
from repro.models.layers import moe_forward
from repro.models.model import _moe_params
from repro.launch.mesh import dp_axes

cfg = get_arch("moonshot-v1-16b-a3b").smoke()
mesh = jax.make_mesh((2, 4), ("data", "model"))
moe_ep.CAPACITY_FACTOR = 16.0  # no capacity drops -> exact match expected
moe_ep.set_ep_mesh(mesh, ("data",))
p = _moe_params(cfg, jax.random.key(0), jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32))
with mesh:
    ref = jax.jit(lambda p, x: moe_forward(cfg, p, x))(p, x)
    out = jax.jit(lambda p, x: moe_ep.moe_forward_ep(cfg, p, x))(p, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=5e-4, atol=5e-4)
print("EP_OK")
"""


def test_ep_moe_matches_pjit_path(tmp_path):
    script = tmp_path / "ep_worker.py"
    script.write_text(EP_WORKER)
    proc = subprocess.run([sys.executable, str(script)], cwd=os.getcwd(),
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "EP_OK" in proc.stdout
