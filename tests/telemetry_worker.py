"""Telemetry replay-safety worker (subprocess: forces 8 host devices).

Sharded cases of the §2.11 replay-safety contract, reported as JSON
verdicts for tests/test_telemetry.py:

* a tracing-enabled sharded service run is bitwise identical to the
  tracing-off run (final state + every per-interval output);
* crash -> restore -> replay with tracing on reproduces the untraced
  uninterrupted run bitwise, while the trace validates against the
  pipeline-stage schema (including ``reshard``-free sharded spans).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys
import tempfile
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.apps import ALL_APPS                                 # noqa: E402
from repro.core.intervals import ReplaySource, WatermarkPolicy  # noqa: E402
from repro.core.scheduler import DualModeEngine, EngineConfig   # noqa: E402
from repro.runtime.service import ServiceConfig, StreamService  # noqa: E402
from repro.runtime.telemetry import (PIPELINE_STAGES, TelemetryConfig,
                                     validate_trace)            # noqa: E402

MESH = jax.make_mesh((8,), ("dev",))
INTERVAL = 32


def _mk_source(app, n_events=192, seed=5, jitter=4):
    return ReplaySource(app.gen_events, n_events, seed=seed,
                        arrival_batch=19, jitter=jitter)


def _outputs_equal(a_list, b_list):
    if len(a_list) != len(b_list):
        return f"interval count {len(a_list)} != {len(b_list)}"
    for i, (a, b) in enumerate(zip(a_list, b_list)):
        for k in a:
            if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
                return f"output {k} interval {i} differs"
    return None


def check_traced_sharded_identical(app_name):
    app = ALL_APPS[app_name]
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig(), mesh=MESH,
                         exchange_slack=8.0)

    def run(tcfg):
        return StreamService(eng, ServiceConfig(
            punct_interval=INTERVAL, chunk_intervals=2,
            watermark=WatermarkPolicy(allowed_lateness=4),
            telemetry=tcfg)).run(_mk_source(app))

    ref = run(None)
    with tempfile.TemporaryDirectory() as tmp:
        trace = os.path.join(tmp, "trace.json")
        rec = run(TelemetryConfig(trace_path=trace))
        if not np.array_equal(rec.final_values, ref.final_values):
            return dict(ok=False, why="final state differs with tracing on")
        why = _outputs_equal(rec.outputs, ref.outputs)
        if why:
            return dict(ok=False, why=f"traced vs untraced: {why}")
        want = [s for s in PIPELINE_STAGES if s != "snapshot.publish"]
        ok, vwhy, info = validate_trace(trace, require_stages=want)
        if not ok:
            return dict(ok=False, why=f"invalid trace: {vwhy}")
    if rec.stats != ref.stats:
        diff = [k for k in ref.stats if rec.stats.get(k) != ref.stats[k]]
        if diff != ["chunks"]:          # lat_s wall-clock only
            return dict(ok=False, why=f"stats diverge beyond timing: {diff}")
    if rec.stats.get("exchange") is None:
        return dict(ok=False, why="exchange stats missing from traced view")
    return dict(ok=True, n_events=info["n_events"])


def check_traced_crash_resume(app_name):
    app = ALL_APPS[app_name]
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig(), mesh=MESH,
                         exchange_slack=8.0)
    ref = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2,
        watermark=WatermarkPolicy(allowed_lateness=4))).run(_mk_source(app))
    with tempfile.TemporaryDirectory() as d:
        trace_a = os.path.join(d, "crash.json")
        trace_b = os.path.join(d, "resume.json")
        cfg = lambda t: ServiceConfig(
            punct_interval=INTERVAL, chunk_intervals=2, snapshot_every=2,
            ckpt_dir=os.path.join(d, "ckpt"),
            watermark=WatermarkPolicy(allowed_lateness=4),
            telemetry=TelemetryConfig(trace_path=t))
        svc = StreamService(eng, cfg(trace_a))
        try:
            svc.run(_mk_source(app), crash_after_interval=3)
            return dict(ok=False, why="injected crash did not fire")
        except RuntimeError:
            pass
        crashed = svc.last_run
        if not crashed.snapshots:
            return dict(ok=False, why="no snapshot before the crash")
        rec = StreamService(eng, cfg(trace_b)).resume(_mk_source(app))
        snap = rec.stats["replayed"] // INTERVAL
        if not np.array_equal(rec.final_values, ref.final_values):
            return dict(ok=False,
                        why="final state differs after traced recovery")
        why = _outputs_equal(rec.outputs, ref.outputs[snap:])
        if why:
            return dict(ok=False, why=f"post-resume {why}")
        # the crashed run's trace must close cleanly and carry snapshot
        # spans; the resume trace covers the replay pipeline
        ok, vwhy, _ = validate_trace(trace_a,
                                     require_stages=["snapshot.publish"])
        if not ok:
            return dict(ok=False, why=f"crash trace invalid: {vwhy}")
        ok, vwhy, _ = validate_trace(trace_b, require_stages=[
            "chunk.dispatch", "chunk.execute", "chunk.commit"])
        if not ok:
            return dict(ok=False, why=f"resume trace invalid: {vwhy}")
        return dict(ok=True, resumed_from=snap)


def main():
    out = {}

    def run(name, fn, *a):
        try:
            out[name] = fn(*a)
        except Exception as e:  # pragma: no cover - surfaced via verdict
            traceback.print_exc(file=sys.stderr)
            out[name] = dict(ok=False, why=f"{type(e).__name__}: {e}")

    run("gs/traced_identical", check_traced_sharded_identical, "gs")
    run("gs/traced_crash_resume", check_traced_crash_resume, "gs")
    print(json.dumps(out))


if __name__ == "__main__":
    main()
