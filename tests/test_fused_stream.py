"""Scan-fused device-resident streaming (DESIGN.md §2.4).

Two contracts are pinned here:

1. ``run_stream(fused=True)`` — the whole-stream ``lax.scan`` driver — is
   *bit-identical* to the host-side per-interval loop: same per-interval
   outputs, same final state, for every app and every consistency-
   preserving scheme, including the abort-repass and Pallas paths.
2. The O(N log N) ``restructure`` lexsort runs exactly **once** per
   evaluated batch on every chain-based path (tstream scan/lockstep, mvlk,
   and the scheduler's abort repass, which must reuse the existing sort).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core import engines as engines_mod
from repro.core import scheduler as scheduler_mod
from repro.core.blotter import build_opbatch
from repro.core.restructure import restructure
from repro.core.scheduler import DualModeEngine, EngineConfig, _step_impl

SCHEMES = ["tstream", "lock", "mvlk"]


def _run_both(app, cfg, n_events=48, interval=16, seed=11, mutate=None):
    rng = np.random.default_rng(seed)
    stream = app.gen_events(rng, n_events)
    if mutate:
        mutate(stream)
    store = app.make_store()
    eng = DualModeEngine(app, store, cfg)
    outs_f, vals_f = eng.run_stream(store.values, stream, interval,
                                    fused=True)
    outs_u, vals_u = eng.run_stream(store.values, stream, interval,
                                    fused=False)
    return (outs_f, vals_f), (outs_u, vals_u)


def _assert_identical(fused, unfused):
    (outs_f, vals_f), (outs_u, vals_u) = fused, unfused
    np.testing.assert_array_equal(np.asarray(vals_f), np.asarray(vals_u))
    assert len(outs_f) == len(outs_u) > 1
    for of, ou in zip(outs_f, outs_u):
        assert set(of) == set(ou)
        for k in of:
            np.testing.assert_array_equal(np.asarray(of[k]),
                                          np.asarray(ou[k]), err_msg=k)


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("app_name", list(ALL_APPS))
def test_fused_matches_unfused_bitwise(app_name, scheme):
    app = ALL_APPS[app_name]
    fused, unfused = _run_both(app, EngineConfig(scheme=scheme))
    _assert_identical(fused, unfused)


def test_fused_matches_unfused_abort_repass():
    """The fused driver's repass masks ``valid`` in the *existing* sorted
    layout; results must still match the loop driver bit for bit."""
    app = ALL_APPS["sl"]
    cfg = EngineConfig(scheme="tstream", abort_repass=True)

    def overdraw(stream):  # most transfers fail -> repass actually masks
        stream["amount"] = (stream["amount"] * 100).astype(np.float32)

    fused, unfused = _run_both(app, cfg, seed=3, mutate=overdraw)
    _assert_identical(fused, unfused)


def test_fused_pallas_lane_prepad_matches():
    """use_pallas under the fused driver lane-pads once per stream; results
    must equal the per-interval Pallas path and the pure-jnp reference."""
    app = ALL_APPS["gs"]
    fused_p, unfused_p = _run_both(
        app, EngineConfig(scheme="tstream", use_pallas=True),
        n_events=32, interval=16)
    _assert_identical(fused_p, unfused_p)
    fused_ref, _ = _run_both(app, EngineConfig(scheme="tstream"),
                             n_events=32, interval=16)
    np.testing.assert_allclose(np.asarray(fused_p[1]),
                               np.asarray(fused_ref[1]), rtol=1e-6, atol=1e-6)


def test_fused_empty_and_tail_truncation():
    """Streams shorter than one interval yield no outputs; tails beyond the
    last full interval are dropped — same as the loop driver."""
    app = ALL_APPS["gs"]
    rng = np.random.default_rng(0)
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig())
    short = app.gen_events(rng, 7)
    outs, vals = eng.run_stream(store.values, short, 16, fused=True)
    assert outs == []
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(store.values))
    ragged = app.gen_events(rng, 40)  # 2 full intervals of 16 + tail of 8
    fused = eng.run_stream(store.values, ragged, 16, fused=True)
    unfused = eng.run_stream(store.values, ragged, 16, fused=False)
    assert len(fused[0]) == len(unfused[0]) == 2
    _assert_identical(fused, unfused)


# ---------------------------------------------------------------------------
# restructure call-count regression: the lexsort must run once per batch
# ---------------------------------------------------------------------------
class _CountingRestructure:
    def __init__(self):
        self.calls = 0

    def __call__(self, ops, pad_uid, **kw):
        self.calls += 1
        return restructure(ops, pad_uid, **kw)


@pytest.fixture
def count_restructure(monkeypatch):
    counter = _CountingRestructure()
    # both modules bound the name at import time; patch each binding
    monkeypatch.setattr(engines_mod, "restructure", counter)
    monkeypatch.setattr(scheduler_mod, "restructure", counter)
    return counter


def _ops_for(app, n_events=24, seed=0):
    rng = np.random.default_rng(seed)
    store = app.make_store()
    events = {k: jnp.asarray(v)
              for k, v in app.gen_events(rng, n_events).items()}
    ops, _ = build_opbatch(app, store, events, jnp.int32(0))
    return store, ops, events


@pytest.mark.parametrize("scheme,app_name", [
    ("tstream", "gs"),    # segscan fast path
    ("tstream", "sl"),    # lockstep path (gates)
    ("tstream", "ob"),    # lockstep path (non-associative)
    ("mvlk", "sl"),       # mvlk must NOT re-sort inside lockstep
    ("mvlk", "gs"),
])
def test_restructure_runs_once_per_batch(count_restructure, scheme, app_name):
    app = ALL_APPS[app_name]
    store, ops, _ = _ops_for(app)
    engines_mod.evaluate(store, ops, app.funs, scheme,
                         associative_only=app.associative_only,
                         has_gates=app.has_gates)
    assert count_restructure.calls == 1


def test_restructure_runs_once_with_abort_repass(count_restructure):
    """The repass re-evaluates the identical batch: it must reuse the sort."""
    app = ALL_APPS["sl"]
    store, _, events = _ops_for(app, n_events=16, seed=3)
    cfg = EngineConfig(scheme="tstream", abort_repass=True)
    _step_impl(store, events, jnp.int32(0), app=app, cfg=cfg)
    assert count_restructure.calls == 1
