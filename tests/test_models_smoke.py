"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step + one decode step on CPU; asserts shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import (cell_is_applicable, decode_step, forward,
                          init_cache, init_params, loss_fn)

B, S = 2, 32


def make_batch(cfg, rng):
    if cfg.frontend == "audio":
        return dict(
            frames=jnp.asarray(rng.normal(size=(B, S, cfg.d_model))
                               .astype(np.float32)).astype(jnp.bfloat16),
            labels=jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                               dtype=jnp.int32),
        )
    batch = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        labels=jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    )
    if cfg.frontend == "vision":
        npatch = 4
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, npatch, cfg.d_model)).astype(np.float32)
        ).astype(jnp.bfloat16)
        batch["patch_pos"] = jnp.asarray(
            np.stack([rng.choice(S, npatch, replace=False)
                      for _ in range(B)]), jnp.int32)
    if cfg.mrope:
        pos = np.broadcast_to(np.arange(S)[None, None], (B, 3, S))
        batch["pos3"] = jnp.asarray(pos.copy(), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_grad(arch):
    cfg = get_arch(arch).smoke()
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, rng)

    logits = jax.jit(lambda p, b: forward(cfg, p, b, remat="none"))(params,
                                                                    batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, remat="dots")))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode(arch):
    cfg = get_arch(arch).smoke()
    if cfg.encoder_only:
        pytest.skip("encoder-only: no decode step (documented skip)")
    params = init_params(cfg, jax.random.key(0))
    caches = init_cache(cfg, batch=B, max_seq=S)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    caches_out = caches
    for i in range(3):
        logits, caches_out = step(params, caches_out, tok, jnp.int32(i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_decode_matches_prefill_dense():
    """Greedy decode logits equal full-forward logits (KV-cache correctness)."""
    cfg = get_arch("granite-34b").smoke()
    params = init_params(cfg, jax.random.key(1), dtype=jnp.float32)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)), jnp.int32)
    full = forward(cfg, params, dict(tokens=toks), remat="none")
    caches = init_cache(cfg, batch=B, max_seq=16, dtype=jnp.float32)
    outs = []
    for i in range(8):
        lg, caches = decode_step(cfg, params, caches, toks[:, i : i + 1],
                                 jnp.int32(i))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_decode_matches_prefill_ssm():
    """Mamba2 recurrence equals the chunked SSD scan."""
    cfg = get_arch("mamba2-2.7b").smoke()
    params = init_params(cfg, jax.random.key(2), dtype=jnp.float32)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)), jnp.int32)
    full = forward(cfg, params, dict(tokens=toks), remat="none")
    caches = init_cache(cfg, batch=B, max_seq=16, dtype=jnp.float32)
    outs = []
    for i in range(8):
        lg, caches = decode_step(cfg, params, caches, toks[:, i : i + 1],
                                 jnp.int32(i))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full, np.float32), rtol=5e-2,
                               atol=5e-2)


def test_param_counts_match_flagship_scale():
    """Analytic parameter counts land near the published sizes."""
    cases = {"deepseek-v3-671b": (600e9, 750e9),
             "qwen1.5-110b": (95e9, 125e9),
             "granite-34b": (28e9, 40e9),
             "mamba2-2.7b": (2.0e9, 3.4e9),
             "nemotron-4-15b": (12e9, 18e9)}
    for name, (lo, hi) in cases.items():
        n = get_arch(name).param_count()
        assert lo < n < hi, (name, n)
