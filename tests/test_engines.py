"""Engine correctness: every consistency-preserving scheme must produce a
schedule conflict-equivalent to timestamp order (paper Definition 2), i.e.
bitwise-identical final state + per-op reads to the sequential oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core.blotter import build_opbatch
from repro.core.engines import evaluate

CORRECT_SCHEMES = ["tstream", "tstream_lockstep", "lock", "mvlk", "pat"]


def run_scheme(app, scheme, n_events=64, seed=0, **kw):
    rng = np.random.default_rng(seed)
    store = app.make_store()
    events = {k: jnp.asarray(v) for k, v in
              app.gen_events(rng, n_events).items()}
    ops, _ = build_opbatch(app, store, events, jnp.int32(0))
    res, values, stats = evaluate(
        store, ops, app.funs, scheme,
        associative_only=app.associative_only, has_gates=app.has_gates, **kw)
    return jax.device_get(res), np.asarray(values), stats


@pytest.mark.parametrize("app_name", list(ALL_APPS))
@pytest.mark.parametrize("scheme", CORRECT_SCHEMES)
def test_scheme_matches_oracle(app_name, scheme):
    app = ALL_APPS[app_name]
    if scheme == "pat" and app.has_gates:
        kw = {}
    res_o, val_o, _ = run_scheme(app, "lock")
    res_s, val_s, _ = run_scheme(app, scheme)
    np.testing.assert_allclose(val_s, val_o, rtol=1e-5, atol=1e-5,
                               err_msg=f"{app_name}/{scheme} final state")
    np.testing.assert_allclose(res_s["pre"], res_o["pre"], rtol=1e-5,
                               atol=1e-5, err_msg=f"{app_name}/{scheme} pre")
    np.testing.assert_array_equal(res_s["success"], res_o["success"],
                                  err_msg=f"{app_name}/{scheme} success")


@pytest.mark.parametrize("app_name", list(ALL_APPS))
def test_nolock_runs(app_name):
    """No-Lock is the (incorrect) upper bound — only check it executes."""
    app = ALL_APPS[app_name]
    res, values, stats = run_scheme(app, "nolock")
    assert np.all(np.isfinite(values))


def test_fast_path_used_for_associative_apps():
    from repro.apps import GS, TP, SL, OB
    assert GS.associative_only and TP.associative_only
    assert not SL.associative_only and not OB.associative_only
