"""Property-based tests (hypothesis): TStream's restructured execution is
conflict-equivalent to timestamp order on *arbitrary* generated workloads —
the system invariant of paper Definition 2."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.engines import evaluate
from repro.core.types import ASSOC_FUNS, CORE_FUNS, OpBatch, OpKind, make_store

F_NOP_I, F_READ_I, F_PUT_I, F_ADD_I, F_MAX_I, F_TAKE_I = range(6)


def make_opbatch(rng, n_txn, max_ops, n_keys, width, fun_pool, gate_prob=0.0):
    """Random transactions with distinct keys per txn; optional gating of an
    op on the success of an earlier op of the same txn (cross-chain CFun)."""
    n = n_txn * max_ops
    keys = np.stack([rng.choice(n_keys, size=max_ops, replace=False)
                     for _ in range(n_txn)])
    fun = rng.choice(fun_pool, size=(n_txn, max_ops))
    valid = rng.random((n_txn, max_ops)) < 0.9
    gate = np.full((n_txn, max_ops), -1, np.int32)
    for t in range(n_txn):
        for s in range(1, max_ops):
            if rng.random() < gate_prob and valid[t, s] and valid[t, s - 1] \
                    and fun[t, s] in (F_ADD_I, F_PUT_I):
                gate[t, s] = t * max_ops + (s - 1)
    kind = np.where(fun == F_READ_I, int(OpKind.READ),
                    int(OpKind.READ_MODIFY))
    txn = np.repeat(np.arange(n_txn, dtype=np.int32), max_ops)
    return OpBatch(
        uid=jnp.asarray(keys.reshape(n), jnp.int32),
        ts=jnp.asarray(txn),
        txn=jnp.asarray(txn),
        slot=jnp.asarray(np.tile(np.arange(max_ops, dtype=np.int32), n_txn)),
        kind=jnp.asarray(kind.reshape(n), jnp.int32),
        fun=jnp.asarray(fun.reshape(n), jnp.int32),
        gate=jnp.asarray(gate.reshape(n), jnp.int32),
        operand=jnp.asarray(
            rng.uniform(0.5, 10.0, (n, width)).astype(np.float32)),
        valid=jnp.asarray(valid.reshape(n)),
    )


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n_txn=st.integers(2, 24),
       max_ops=st.integers(1, 5),
       n_keys=st.sampled_from([5, 16, 64]))
def test_associative_scan_path_matches_oracle(seed, n_txn, max_ops, n_keys):
    rng = np.random.default_rng(seed)
    store = make_store([n_keys], 2,
                       init=jnp.asarray(rng.uniform(0, 5, (n_keys + 1, 2))
                                        .astype(np.float32)))
    ops = make_opbatch(rng, n_txn, max_ops, n_keys, 2,
                       [F_READ_I, F_PUT_I, F_ADD_I])
    r1, v1, _ = evaluate(store, ops, ASSOC_FUNS, "tstream_scan")
    r0, v0, _ = evaluate(store, ops, ASSOC_FUNS, "lock")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(r1["pre"]), np.asarray(r0["pre"]),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n_txn=st.integers(2, 16),
       n_keys=st.sampled_from([4, 12]),
       gate_prob=st.sampled_from([0.0, 0.5]))
def test_lockstep_with_gates_matches_oracle(seed, n_txn, n_keys, gate_prob):
    """Heavy contention (few keys) + TAKE + gated ops: the dependency-level
    scheduler plus the sequential fallback must stay exact."""
    rng = np.random.default_rng(seed)
    store = make_store([n_keys], 2,
                       init=jnp.asarray(rng.uniform(5, 30, (n_keys + 1, 2))
                                        .astype(np.float32)))
    ops = make_opbatch(rng, n_txn, 4, n_keys, 2,
                       [F_READ_I, F_PUT_I, F_ADD_I, F_TAKE_I],
                       gate_prob=gate_prob)
    r1, v1, _ = evaluate(store, ops, CORE_FUNS, "tstream_lockstep",
                         has_gates=True)
    r0, v0, _ = evaluate(store, ops, CORE_FUNS, "lock")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_array_equal(np.asarray(r1["success"]),
                                  np.asarray(r0["success"]))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_parts=st.sampled_from([2, 4, 16]))
def test_pat_matches_oracle(seed, n_parts):
    rng = np.random.default_rng(seed)
    store = make_store([32], 2,
                       init=jnp.asarray(rng.uniform(5, 30, (33, 2))
                                        .astype(np.float32)))
    ops = make_opbatch(rng, 12, 3, 32, 2, [F_READ_I, F_PUT_I, F_ADD_I,
                                           F_TAKE_I])
    r1, v1, _ = evaluate(store, ops, CORE_FUNS, "pat", n_partitions=n_parts)
    r0, v0, _ = evaluate(store, ops, CORE_FUNS, "lock")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0), rtol=2e-5,
                               atol=2e-5)
