"""Hypothesis sweep: radix-partition restructure == lexsort reference.

Random skewed/uniform key distributions, pad fractions up to all-pad, and
tiny-to-mid batch shapes; every Chains field, sorted column and the
histogram commit map must be bit-identical (the shared assertion lives in
``test_restructure_parity``, which also carries the deterministic edge
cases so coverage survives without hypothesis installed).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from test_restructure_parity import assert_partition_matches_lexsort, mk_batch


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_txn=st.integers(1, 50),
       max_ops=st.integers(1, 5), n_slots=st.integers(1, 60),
       theta=st.sampled_from([0.0, 0.6, 1.2]),
       pad_frac=st.sampled_from([0.0, 0.1, 0.9, 1.0]))
def test_partition_matches_lexsort_property(seed, n_txn, max_ops, n_slots,
                                            theta, pad_frac):
    rng = np.random.default_rng(seed)
    n = n_txn * max_ops
    w = 1.0 / np.power(np.arange(1, n_slots + 1, dtype=np.float64), theta)
    uid = rng.choice(n_slots, size=n, p=w / w.sum())
    valid = rng.uniform(size=n) >= pad_frac
    assert_partition_matches_lexsort(mk_batch(uid, valid, max_ops), n_slots)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 700),
       n_slots=st.integers(1, 2100))
def test_partition_kernel_property(seed, n, n_slots):
    """Pallas kernel rung (interpret) across shapes incl. multi-block."""
    rng = np.random.default_rng(seed)
    uid = rng.integers(0, n_slots, n)
    valid = rng.uniform(size=n) > 0.15
    assert_partition_matches_lexsort(mk_batch(uid, valid), n_slots,
                                     use_pallas=True)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 300),
       n_slots=st.integers(1, 80),
       pad_frac=st.sampled_from([0.0, 0.15, 0.9, 1.0]),
       theta=st.sampled_from([0.0, 1.0]))
def test_megakernel_matches_staged_property(seed, n, n_slots, pad_frac,
                                            theta):
    """The fused megakernel (XLA ref + Pallas interpret) is bit-identical
    to the staged partition pipeline across random odd shapes, skew and
    pad fractions (the shared assertion lives in ``test_megakernel``)."""
    from test_megakernel import assert_fused_matches_staged
    rng = np.random.default_rng(seed)
    w = 1.0 / np.power(np.arange(1, n_slots + 1, dtype=np.float64), theta)
    uid = rng.choice(n_slots, size=n, p=w / w.sum())
    valid = rng.uniform(size=n) >= pad_frac
    assert_fused_matches_staged(uid, valid, n_slots, seed=seed % 1000)
