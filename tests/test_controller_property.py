"""Adaptive control plane (DESIGN.md §2.9, ``runtime/controller.py``).

Contracts pinned here:

1. **Purity**: ``decide`` is a pure function of (config, plan, record
   window, boundary, cool-down state) — same inputs, same decisions, and
   it never mutates its arguments.
2. **Hysteresis**: a knob never switches twice within ``cooldown``
   global intervals, whatever the record stream does.
3. **Legal lattice**: the folded plan never leaves the configured
   lattice — scheme ∈ {base, degrade}, slack a bounded geometric ladder,
   chunk on the (snapshot-tiling, queue-bounded) ladder, rung on the
   rung ladder.
4. **Replay**: the decision trace is the whole story —
   ``replay_plan(init, trace)`` equals the live plan after any number of
   steps, and ``restore(trace)`` rebuilds an equivalent controller
   (plan, escalation count, cool-down state).
5. **Integration**: a run whose controller *grows K mid-stream* is still
   bit-identical to one monolithic ``run_stream`` over the same events
   (chunk boundaries are punctuation boundaries whatever K does), the
   per-chunk time series ``stats["chunks"]`` is ring-bounded with a
   stable schema, and ``escalate_overflow`` now composes with snapshots
   instead of being statically excluded.
"""
import copy

import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core.intervals import PhasedReplaySource, ReplaySource, \
    WatermarkPolicy
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.runtime.controller import (KNOBS, ControllerConfig, Plan,
                                      PlanController, decide, replay_plan)
from repro.runtime.service import ServiceConfig, StreamService

from test_service import assert_outputs_identical

BASE = Plan(scheme="tstream", rung="auto", slack=1.0, chunk=2)


def mk_record(i, *, scheme="tstream", fail=0, ops=64, max_chain=1,
              qfill=0, x_drop=0, x_fill=0, x_cap=20, k=2, lat_s=0.01):
    return dict(i=i, g0=i * k, k=k, events=k * 16, lat_s=lat_s,
                qfill=qfill, scheme=scheme, fail=fail, ops=ops,
                max_chain=max_chain, n_chains=1, rounds=1, x_drop=x_drop,
                x_ship=10, x_fill=x_fill, x_cap=x_cap)


# ---------------------------------------------------------------------------
# hypothesis property suite (guarded import, same pattern as test_faults)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # pragma: no cover - hypothesis is in requirements-dev
    st = None

if st is not None:
    record_st = st.builds(
        lambda scheme, fail, chain, qfill, drop, fill: dict(
            scheme=scheme, fail=fail, max_chain=chain, qfill=qfill,
            x_drop=drop, x_fill=fill),
        st.sampled_from(["tstream", "lock"]), st.integers(0, 64),
        st.integers(0, 32), st.integers(0, 16), st.integers(0, 8),
        st.integers(0, 30))

    cfg_st = st.builds(
        lambda sustain, cooldown, snap: dict(sustain=sustain,
                                             cooldown=cooldown, snap=snap),
        st.integers(1, 3), st.integers(1, 6), st.sampled_from([0, 4]))

    def _mk_cfg(p):
        return ControllerConfig(
            window=3, sustain=p["sustain"], cooldown=p["cooldown"],
            degrade_scheme="lock", degrade_chain_frac=0.5,
            degrade_fail_frac=0.25, slack_widen=True, slack_factor=2.0,
            slack_max=16.0, fill_widen=0.9, max_escalations=3,
            chunk_ladder=(1, 2, 4, 8, 16), backlog_grow=2.0,
            rung_ladder=("auto", "safe"), rung_chain_frac=0.6)

    def _drive(cfg, records, sharded, snap):
        """Fold a synthetic record stream through a controller, one
        boundary per record, returning the controller."""
        ctl = PlanController(cfg, BASE, sharded=sharded, snap_align=snap,
                             queue_cap=8)
        window = []
        for j, r in enumerate(records):
            window.append(mk_record(j, **r))
            ctl.step(j * 2, window[-cfg.window:])
        return ctl

    @settings(max_examples=60, deadline=None)
    @given(params=cfg_st, records=st.lists(record_st, min_size=1,
                                           max_size=16),
           sharded=st.booleans())
    def test_controller_pure_lattice_cooldown_replay(params, records,
                                                     sharded):
        cfg = _mk_cfg(params)
        snap = params["snap"]
        # purity: decide() twice on deep copies -> identical decisions,
        # arguments unmutated
        window = [mk_record(j, **r) for j, r in enumerate(records)]
        frozen = copy.deepcopy(window)
        last = {"scheme": 0} if len(records) > 3 else {}
        a = decide(cfg, BASE, window, 10, dict(last), init_plan=BASE,
                   sharded=sharded, esc_done=0, snap_align=snap,
                   queue_cap=8)
        b = decide(cfg, copy.deepcopy(BASE), copy.deepcopy(window), 10,
                   dict(last), init_plan=BASE, sharded=sharded, esc_done=0,
                   snap_align=snap, queue_cap=8)
        assert a == b, "decide is not a pure function of its inputs"
        assert window == frozen, "decide mutated the record window"
        assert len({d["knob"] for d in a}) == len(a), \
            "more than one decision per knob at one boundary"

        # fold the whole stream; then check lattice + hysteresis + replay
        ctl = _drive(cfg, records, sharded, snap)
        seen = {}
        for d in ctl.trace:
            assert d["knob"] in KNOBS
            if d["knob"] in seen:
                assert d["g"] - seen[d["knob"]] >= cfg.cooldown, \
                    f"{d['knob']} switched inside its cool-down"
            seen[d["knob"]] = d["g"]
        plan = ctl.plan
        assert plan.scheme in ("tstream", "lock")
        assert plan.rung in cfg.rung_ladder
        assert plan.chunk == BASE.chunk or plan.chunk in cfg.chunk_ladder
        if snap:
            assert snap % plan.chunk == 0, \
                "chunk switch broke snapshot tiling"
        n_esc = round(np.log2(plan.slack / BASE.slack))
        assert plan.slack <= cfg.slack_max
        assert plan.slack == BASE.slack * 2.0 ** n_esc
        assert ctl.esc_done <= cfg.max_escalations
        if sharded:
            assert all(d["knob"] == "slack" for d in ctl.trace), \
                "sharded lattice is slack-only"
        else:
            assert all(d["knob"] != "slack" for d in ctl.trace)

        # replay: the trace is the whole story
        assert replay_plan(BASE, ctl.trace) == plan
        gs = [d["g"] for d in ctl.trace]
        assert gs == sorted(gs), "trace not monotone in g"
        clone = PlanController(cfg, BASE, sharded=sharded, snap_align=snap,
                               queue_cap=8)
        clone.restore(ctl.trace, plan_check=plan.as_dict())
        assert (clone.plan, clone.esc_done, clone.last_switch) == \
            (plan, ctl.esc_done, ctl.last_switch)


# ---------------------------------------------------------------------------
# deterministic unit cases for each knob's trigger
# ---------------------------------------------------------------------------
def test_degrade_requires_sustained_storm_and_probes_back():
    cfg = ControllerConfig(window=4, sustain=2, cooldown=4,
                           degrade_scheme="lock", degrade_chain_frac=0.5)
    ctl = PlanController(cfg, BASE, sharded=False, snap_align=0,
                         queue_cap=8)
    storm = lambda i: mk_record(i, max_chain=16)          # frac 1.0
    calm = lambda i: mk_record(i, max_chain=1)
    assert ctl.step(0, [storm(0)]) == []                  # 1 < sustain
    assert ctl.step(2, [storm(0), calm(1)]) == []         # not consecutive
    d = ctl.step(4, [calm(0), storm(1), storm(2)])
    assert [x["new"] for x in d] == ["lock"]
    assert d[0]["reason"] == "conflict-storm"
    # degraded records never count as storm evidence; recovery is an
    # unconditional probe once the cool-down expires
    assert ctl.step(6, [mk_record(3, scheme="lock", max_chain=64)]) == []
    d = ctl.step(8, [mk_record(4, scheme="lock", max_chain=64)])
    assert d[0]["reason"] == "probe" and ctl.plan.scheme == "tstream"


def test_slack_widens_before_drop_on_fill_crowding():
    cfg = ControllerConfig(window=2, sustain=1, cooldown=1,
                           fill_widen=0.9, slack_factor=2.0, slack_max=4.0,
                           max_escalations=0)
    ctl = PlanController(cfg, BASE, sharded=True, snap_align=0, queue_cap=8)
    assert ctl.step(0, [mk_record(0, x_fill=17, x_cap=20)]) == []
    d = ctl.step(2, [mk_record(1, x_fill=19, x_cap=20)])   # 95% full, 0 drops
    assert d[0]["reason"] == "fill-crowding" and ctl.plan.slack == 2.0
    d = ctl.step(4, [mk_record(2, x_drop=3)])
    assert d[0]["reason"] == "overflow-drops" and ctl.plan.slack == 4.0
    assert ctl.step(6, [mk_record(3, x_drop=3)]) == [], "slack_max ceiling"


def test_chunk_switch_waits_for_snapshot_boundary():
    cfg = ControllerConfig(window=2, sustain=1, cooldown=1,
                           chunk_ladder=(2, 4, 8), backlog_grow=2.0)
    ctl = PlanController(cfg, BASE, sharded=False, snap_align=4,
                         queue_cap=8)
    backlog = lambda i: mk_record(i, qfill=8)
    assert ctl.step(2, [backlog(0)]) == [], "g=2 is not snapshot-aligned"
    d = ctl.step(4, [backlog(1)])
    assert d[0]["knob"] == "chunk" and ctl.plan.chunk == 4
    # 8 does not tile snap_align=4: the ladder is clipped to legal rungs
    assert ctl.step(8, [backlog(2, )]) == []
    assert ctl.plan.chunk == 4


# ---------------------------------------------------------------------------
# integration: adaptation composes with the service's exactness contracts
# ---------------------------------------------------------------------------
def test_chunk_adaptation_matches_monolithic_bitwise():
    """K grows mid-stream under backlog; the run stays bit-identical to
    one monolithic run_stream (chunk boundaries are punctuation
    boundaries whatever K the controller picks)."""
    app = ALL_APPS["gs"]
    interval, n_iv = 16, 24
    src = lambda: ReplaySource(app.gen_events, interval * n_iv, seed=4,
                               arrival_batch=interval * n_iv, jitter=0)
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    ref, vals_ref = eng.run_stream(app.make_store().values,
                                   src().in_order_events, interval,
                                   fused=True)
    ctl_cfg = ControllerConfig(window=2, sustain=1, cooldown=2,
                               chunk_ladder=(2, 4, 8), backlog_grow=1.0)
    svc = StreamService(eng, ServiceConfig(
        punct_interval=interval, chunk_intervals=2, queue_intervals=16,
        controller=ctl_cfg))
    rec = svc.run(src())
    grown = [d for d in rec.decisions if d["knob"] == "chunk"]
    assert grown and grown[0]["reason"] == "backlog", rec.decisions
    ks = {r["k"] for r in rec.stats["chunks"]}
    assert len(ks) > 1, f"K never actually changed: {ks}"
    np.testing.assert_array_equal(rec.final_values, np.asarray(vals_ref))
    assert_outputs_identical(rec.outputs, ref)
    # the published controller record round-trips
    cstats = rec.stats["controller"]
    assert replay_plan(Plan.from_dict(cstats["init_plan"]),
                       cstats["decisions"]).as_dict() == cstats["plan"]


def test_chunk_record_ring_schema_and_bound():
    app = ALL_APPS["gs"]
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    svc = StreamService(eng, ServiceConfig(
        punct_interval=16, chunk_intervals=1, chunk_record_ring=3))
    rec = svc.run(ReplaySource(app.gen_events, 16 * 8, seed=1,
                               arrival_batch=32, jitter=0))
    chunks = rec.stats["chunks"]
    assert len(chunks) == 3, "ring bound not enforced"
    keys = {"i", "g0", "k", "events", "lat_s", "qfill", "scheme", "fail",
            "ops", "max_chain", "n_chains", "rounds", "x_drop", "x_ship",
            "x_fill", "x_cap"}
    assert all(keys <= set(r) for r in chunks)
    assert [r["i"] for r in chunks] == [5, 6, 7], "newest-last ordering"
    assert all(r["max_chain"] >= 1 and r["ops"] >= 16 for r in chunks), \
        "single-device records must carry engine chain stats"


def test_escalation_now_composes_with_snapshots(tmp_path):
    """PR 5 statically excluded escalate_overflow + snapshot_every; the
    decision trace made the combination legal (DESIGN.md §2.9)."""
    ServiceConfig(punct_interval=16, chunk_intervals=2, snapshot_every=4,
                  ckpt_dir=str(tmp_path), escalate_overflow=2)


def test_adaptive_storm_degrades_and_recovers():
    """End-to-end single-device storm: calm -> hot-key skew -> calm.  The
    controller degrades tstream -> lock under the sustained storm, probes
    back, and the decision trace tells that story in order."""
    app = ALL_APPS["gs"]
    interval = 64
    src = PhasedReplaySource(app.gen_events, [
        (4 * interval, dict(theta=0.2)),
        (8 * interval, dict(theta=2.5)),
        (8 * interval, dict(theta=0.2)),
    ], seed=7, arrival_batch=2 * interval)
    eng = DualModeEngine(app, app.make_store(), EngineConfig())
    ctl_cfg = ControllerConfig(window=2, sustain=2, cooldown=2,
                               degrade_scheme="lock",
                               degrade_chain_frac=0.6)
    rec = StreamService(eng, ServiceConfig(
        punct_interval=interval, chunk_intervals=2,
        controller=ctl_cfg)).run(src)
    schemes = [(d["old"], d["new"]) for d in rec.decisions
               if d["knob"] == "scheme"]
    assert ("tstream", "lock") in schemes, rec.decisions
    assert ("lock", "tstream") in schemes, "probe-back never fired"
    assert {r["scheme"] for r in rec.stats["chunks"]} == \
        {"tstream", "lock"}
    assert rec.stats["controller"]["plan"]["scheme"] == "tstream", \
        "run should end probed back to the base scheme"
