"""Launch-layer unit tests: sharding rules, cell applicability, input specs.

(The heavy 512-device compiles are exercised by the sweep, not pytest; these
tests validate the rule layer on the host device.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.models import SHAPES, cell_is_applicable
from repro.launch.sharding import sanitize_spec
from repro.launch.steps import batch_struct


class FakeMesh:
    """Minimal stand-in with shape/axis_names for rule-level tests."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


def test_sanitize_drops_nondividing_axes():
    mesh = FakeMesh({"data": 16, "model": 16})
    assert sanitize_spec(P("model", "data"), (122753, 2304), mesh) \
        == P(None, "data")
    assert sanitize_spec(P(("data", "model"), None), (256, 4), mesh) \
        == P(("data", "model"), None)
    # tuple entries shrink to their largest dividing prefix
    assert sanitize_spec(P(("data", "model"),), (16,), mesh) == P(("data",),)


def test_cell_applicability_matrix():
    """The assignment's 40 cells resolve to 31 executed + 9 documented skips."""
    executed, skipped = 0, 0
    for arch in ARCHS.values():
        for shape in SHAPES:
            ok, why = cell_is_applicable(arch, shape)
            if ok:
                executed += 1
            else:
                skipped += 1
                assert why
    assert executed == 31 and skipped == 9


@pytest.mark.parametrize("arch", ["qwen2-vl-72b", "hubert-xlarge",
                                  "granite-34b"])
def test_batch_struct_fields(arch):
    cfg = get_arch(arch)
    from repro.models import SHAPE_BY_NAME
    b = batch_struct(cfg, SHAPE_BY_NAME["train_4k"])
    if cfg.frontend == "audio":
        assert "frames" in b and b["frames"].shape[-1] == cfg.d_model
    else:
        assert b["tokens"].shape == (256, 4096)
    if cfg.frontend == "vision":
        assert "patch_embeds" in b and "pos3" in b
    d = batch_struct(cfg, SHAPE_BY_NAME["decode_32k"])
    assert d["tokens"].shape == (128, 1)


def test_param_specs_on_host_mesh():
    """Every param leaf gets a spec the real mesh accepts (divisibility)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.launch.sharding import param_shardings
    from repro.launch.steps import abstract_params
    for arch in ["granite-34b", "mamba2-2.7b", "zamba2-2.7b",
                 "deepseek-v3-671b"]:
        cfg = get_arch(arch).smoke()
        params = abstract_params(cfg)
        shardings = param_shardings(params, mesh)
        n = len(jax.tree_util.tree_leaves(shardings))
        assert n == len(jax.tree_util.tree_leaves(params))
