"""Streaming-service worker (subprocess: forces 8 host devices).

Sharded cases of the service contracts (DESIGN.md §2.6), reported as
JSON verdicts for tests/test_service_sharded.py:

* the K-chunked service over the sharded fused driver is bit-identical
  to the monolithic sharded ``run_stream`` AND to the single-device
  fused driver on the same in-order events;
* crash -> restore -> replay on the sharded driver reproduces the
  uninterrupted run bitwise (final state + every per-interval output);
* per-chunk exchange statistics aggregate into the service's merged
  accounting record.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys
import tempfile
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.apps import ALL_APPS                                # noqa: E402
from repro.core.intervals import ReplaySource, WatermarkPolicy  # noqa: E402
from repro.core.scheduler import DualModeEngine, EngineConfig   # noqa: E402
from repro.runtime.service import ServiceConfig, StreamService  # noqa: E402

MESH = jax.make_mesh((8,), ("dev",))
INTERVAL = 32


def _mk_source(app, n_events=192, seed=5, jitter=4):
    return ReplaySource(app.gen_events, n_events, seed=seed,
                        arrival_batch=19, jitter=jitter)


def _outputs_equal(a_list, b_list):
    for i, (a, b) in enumerate(zip(a_list, b_list)):
        for k in a:
            if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
                return f"output {k} interval {i} differs"
    if len(a_list) != len(b_list):
        return f"interval count {len(a_list)} != {len(b_list)}"
    return None


def check_chunked_sharded_bit_identical(app_name):
    app = ALL_APPS[app_name]
    store = app.make_store()
    jitter = 4
    # single-device fused reference
    eng1 = DualModeEngine(app, store, EngineConfig())
    outs_1, vals_1 = eng1.run_stream(
        store.values, _mk_source(app).in_order_events, INTERVAL, fused=True)
    # monolithic sharded
    eng8 = DualModeEngine(app, store, EngineConfig(), mesh=MESH,
                          exchange_slack=8.0)
    outs_m, vals_m = eng8.run_stream(
        store.values, _mk_source(app).in_order_events, INTERVAL)
    # chunked service over the sharded driver
    rec = StreamService(eng8, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2,
        watermark=WatermarkPolicy(allowed_lateness=jitter))).run(
            _mk_source(app))
    for tag, outs, vals in (("1dev", outs_1, vals_1),
                            ("sharded-monolithic", outs_m, vals_m)):
        if not np.array_equal(rec.final_values, np.asarray(vals)):
            return dict(ok=False, why=f"final state differs vs {tag}")
        why = _outputs_equal(rec.outputs, outs)
        if why:
            return dict(ok=False, why=f"vs {tag}: {why}")
    if rec.stats.get("exchange") is None:
        return dict(ok=False, why="exchange stats missing from record")
    if rec.stats["exchange"]["shipped"] <= 0:
        return dict(ok=False, why="exchange shipped not aggregated")
    return dict(ok=True, shipped=rec.stats["exchange"]["shipped"],
                dropped=rec.stats["drops"]["exchange"])


def check_sharded_crash_resume(app_name):
    app = ALL_APPS[app_name]
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig(), mesh=MESH,
                         exchange_slack=8.0)
    with tempfile.TemporaryDirectory() as d:
        cfg = ServiceConfig(
            punct_interval=INTERVAL, chunk_intervals=2, snapshot_every=2,
            ckpt_dir=d, watermark=WatermarkPolicy(allowed_lateness=4))
        ref = StreamService(eng, ServiceConfig(
            punct_interval=INTERVAL, chunk_intervals=2,
            watermark=WatermarkPolicy(allowed_lateness=4))).run(
                _mk_source(app))
        svc = StreamService(eng, cfg)
        try:
            svc.run(_mk_source(app), crash_after_interval=3)
            return dict(ok=False, why="injected crash did not fire")
        except RuntimeError:
            pass
        crashed = svc.last_run
        if not crashed.snapshots:
            return dict(ok=False, why="no snapshot before the crash")
        rec = StreamService(eng, cfg).resume(_mk_source(app))
        snap = rec.stats["replayed"] // INTERVAL
        if snap != crashed.snapshots[-1]:
            return dict(ok=False, why=f"resumed from {snap}, "
                        f"snapshot was {crashed.snapshots[-1]}")
        if not np.array_equal(rec.final_values, ref.final_values):
            return dict(ok=False, why="final state differs after recovery")
        why = _outputs_equal(rec.outputs, ref.outputs[snap:])
        if why:
            return dict(ok=False, why=f"post-resume {why}")
        why = _outputs_equal(crashed.outputs,
                             ref.outputs[: len(crashed.outputs)])
        if why:
            return dict(ok=False, why=f"pre-crash {why}")
        return dict(ok=True, resumed_from=snap)


def main():
    out = {}

    def run(name, fn, *a):
        try:
            out[name] = fn(*a)
        except Exception as e:  # pragma: no cover - surfaced via verdict
            traceback.print_exc(file=sys.stderr)
            out[name] = dict(ok=False, why=f"{type(e).__name__}: {e}")

    run("gs/chunked", check_chunked_sharded_bit_identical, "gs")
    run("sl/chunked", check_chunked_sharded_bit_identical, "sl")
    run("gs/crash_resume", check_sharded_crash_resume, "gs")
    print(json.dumps(out))


if __name__ == "__main__":
    main()
