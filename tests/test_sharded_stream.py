"""Sharded fused streaming (DESIGN.md §2.5).

Contracts pinned here:

1. The sharded fused ``run_stream`` (owner-routed exchange, per-shard
   restructure/coefficient hoisting) is **bit-identical** to the
   single-device fused driver — across all four apps, all three chain-
   shard layouts, key skew, multi-partition transactions, the abort
   repass, and the forced dependency-cycle residue.  (Subprocess with a
   forced 8-device host mesh.)
2. Exchange-capacity overflow is *accounted*, never silent.
3. The hash-probe uid->owner lookup (flag-gated hot-path use of
   ``kernels/hash_probe``) routes identically to the direct gather.
4. ``make_local_store`` is the one local-store constructor and sets
   every field consistently (the historical per-socket/everything bodies
   omitted ``table_base``/``table_capacity``).
5. The segment-relative segmented scans produce bit-identical chain
   results at any array offset — the property the sharded schedule's
   bit-identity rests on.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ownership import (bucket_by_owner, build_ownership,
                                  exchange_capacity, make_local_store,
                                  permute_values, route_gather,
                                  unpermute_values, unroute_gather)
from repro.core.restructure import segmented_scan_affine
from repro.core.types import make_store


# ---------------------------------------------------------------------------
# subprocess: bit-identity vs the single-device fused driver (8 devices)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def worker_verdicts():
    worker = os.path.join(os.path.dirname(__file__),
                          "sharded_stream_worker.py")
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("case", [
    "gs/shared_nothing", "tp/shared_nothing", "sl/shared_nothing",
    "ob/shared_nothing", "gs/shared_per_socket", "tp/shared_per_socket",
    "gs/shared_everything", "tp/shared_everything", "gs/skew",
    "gs/multipartition", "sl/abort_repass", "sl/residue",
    "gs/partition_restructure", "sl/partition_restructure",
])
def test_sharded_bit_identical(worker_verdicts, case):
    v = worker_verdicts[case]
    assert v["ok"], f"{case}: {v.get('why')}"


def test_exchange_overflow_is_accounted(worker_verdicts):
    v = worker_verdicts["overflow"]
    assert v["ok"], v
    assert v["dropped"] > 0


def test_hash_probe_routing_matches_gather(worker_verdicts):
    v = worker_verdicts["hash_probe_route"]
    assert v["ok"], v.get("why")


# ---------------------------------------------------------------------------
# unified local-store construction (in-process; no mesh needed)
# ---------------------------------------------------------------------------
def test_make_local_store_fields_consistent():
    """One helper, consistent fields — regression for the historical
    copy-pasted bodies that omitted table_base/table_capacity."""
    vals = jnp.zeros((17, 2))
    ls = make_local_store(vals)
    assert ls.table_base == (0,)
    assert ls.table_capacity == (16,)
    assert ls.table_is_max == (False,)
    assert ls.slot_is_max is None
    assert ls.pad_uid == 16

    flags = jnp.zeros((17,), bool).at[3].set(True)
    lsm = make_local_store(vals, flags)
    assert lsm.table_base == (0,) and lsm.table_capacity == (16,)
    assert lsm.table_is_max == (True,)
    np.testing.assert_array_equal(np.asarray(lsm.uid_is_max()),
                                  np.asarray(flags))


def test_ownership_permutation_roundtrip_and_max_flags():
    store = make_store([10, 10], 3, is_max=[False, True])
    own = build_ownership(store, 4)
    assert own.per == 5 and own.s_pad == 20
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.uniform(size=(21, 3)).astype(np.float32))
    vals = vals.at[-1].set(0.0)
    back = unpermute_values(own, permute_values(own, vals))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(vals))
    # max flags follow the permutation: slots of table 1 stay max-typed
    sim = np.asarray(own.slot_is_max)
    fwd = np.asarray(own.fwd)
    for uid in range(20):
        assert sim[fwd[uid]] == (uid >= 10)


# ---------------------------------------------------------------------------
# owner-routed bucketing (in-process)
# ---------------------------------------------------------------------------
def test_bucket_roundtrip_and_overflow_count():
    rng = np.random.default_rng(1)
    dst = jnp.asarray(rng.integers(0, 4, 40).astype(np.int32)).at[5].set(4)
    plan = bucket_by_owner(dst, 4, cap=20)
    assert int(plan.dropped) == 0
    field = jnp.arange(40, dtype=jnp.int32) * 10
    bucketed = route_gather(plan, field, -1)
    ret = unroute_gather(plan, bucketed.reshape(80), 4, 20, pad_value=-7)
    exp = np.where(np.asarray(dst) < 4, np.asarray(field), -7)
    np.testing.assert_array_equal(np.asarray(ret), exp)

    tight = bucket_by_owner(dst, 4, cap=2)
    counts = np.bincount(np.asarray(dst), minlength=5)[:4]
    assert int(tight.dropped) == int(np.maximum(counts - 2, 0).sum())


def test_exchange_capacity_policy():
    assert exchange_capacity(100, 8, 2.0) == 26       # 2x balanced share
    assert exchange_capacity(100, 8, 1.0) == 13       # floor: exact share
    assert exchange_capacity(100, 8, 100.0) == 100    # clamp: worst case
    assert exchange_capacity(1, 8, 2.0) == 1


# ---------------------------------------------------------------------------
# segment-relative scan: offset invariance (bit-identity foundation)
# ---------------------------------------------------------------------------
def test_segmented_scan_offset_invariant():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.uniform(0.5, 1.5, (16, 2)).astype(np.float32))
    b = jnp.asarray(rng.uniform(-1, 1, (16, 2)).astype(np.float32))
    seg = jnp.zeros(16, bool).at[0].set(True).at[5].set(True).at[11].set(True)
    A, B = segmented_scan_affine(a, b, seg)
    # the middle segment (rows 5..10) moved to offset 3 of another array
    pre_a = jnp.asarray(rng.uniform(0.5, 1.5, (3, 2)).astype(np.float32))
    a2 = jnp.concatenate([pre_a, a[5:11], a[:2]])
    b2 = jnp.concatenate([pre_a * 0, b[5:11], b[:2]])
    seg2 = jnp.zeros(11, bool).at[0].set(True).at[3].set(True).at[9].set(True)
    A2, B2 = segmented_scan_affine(a2, b2, seg2)
    np.testing.assert_array_equal(np.asarray(A[5:11]), np.asarray(A2[3:9]))
    np.testing.assert_array_equal(np.asarray(B[5:11]), np.asarray(B2[3:9]))
