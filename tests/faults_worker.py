"""Sharded chaos worker (subprocess: forces 8 host devices).

Sharded cases of the fault plane (DESIGN.md §2.7), reported as JSON
verdicts for tests/test_faults.py::test_faults_sharded:

* a seeded chaos schedule against the sharded driver — a dead executor
  (worker crash / hang) mid-stream still recovers to a run bitwise
  identical to the uninterrupted sharded reference, accounting balanced;
* graceful degradation: repeated exchange overflow triggers the logged
  automatic slack escalation at a punctuation boundary, after which the
  service keeps running;
* escalation + snapshots now compose (DESIGN.md §2.9): the slack
  escalations are controller decisions in the snapshot's trace, so a
  crash mid-escalating-run restores + replays bitwise identical to the
  uninterrupted escalating run — decision trace included.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys
import tempfile
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.apps import ALL_APPS                                 # noqa: E402
from repro.core.intervals import ReplaySource, WatermarkPolicy  # noqa: E402
from repro.core.scheduler import DualModeEngine, EngineConfig   # noqa: E402
from repro.runtime.faults import FaultPlane, random_schedule    # noqa: E402
from repro.runtime.service import ServiceConfig, StreamService  # noqa: E402
from repro.runtime.service import StragglerPolicy              # noqa: E402

MESH = jax.make_mesh((8,), ("dev",))
INTERVAL = 32
JITTER = 4
WM = WatermarkPolicy(allowed_lateness=JITTER)


def _mk_source(app, n_events=192, seed=5):
    return ReplaySource(app.gen_events, n_events, seed=seed,
                        arrival_batch=19, jitter=JITTER)


def _outputs_equal(a_list, b_list):
    for i, (a, b) in enumerate(zip(a_list, b_list)):
        for k in a:
            if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
                return f"output {k} interval {i} differs"
    if len(a_list) != len(b_list):
        return f"interval count {len(a_list)} != {len(b_list)}"
    return None


def check_sharded_chaos(app_name, seed):
    """Seeded chaos schedule against the sharded driver: crash → restore
    → replay must be bitwise identical to the uninterrupted run."""
    app = ALL_APPS[app_name]
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig(), mesh=MESH,
                         exchange_slack=8.0)
    ref = StreamService(eng, ServiceConfig(
        punct_interval=INTERVAL, chunk_intervals=2, watermark=WM)).run(
            _mk_source(app))

    plane = FaultPlane(random_schedule(
        seed, n_pulls=11, n_chunks=3, n_snapshots=1,
        hang_s=2.5, stall_s=0.05))
    with tempfile.TemporaryDirectory() as d:
        cfg = ServiceConfig(
            punct_interval=INTERVAL, chunk_intervals=2, snapshot_every=2,
            ckpt_dir=d, watermark=WM, keep_last=2,
            source_retries=2, retry_backoff_s=0.01,
            watchdog_factor=4.0, watchdog_min_s=1.0, watchdog_grace_s=20.0,
            straggler=StragglerPolicy(deadline_s=0.5))
        svc = StreamService(eng, cfg)
        crashed = False
        try:
            rec = svc.run(_mk_source(app), faults=plane)
        except Exception:
            crashed = True
            stats = svc.last_run.stats
            if stats is None or not stats["crashed"]:
                return dict(ok=False, why="crash without structured stats")
            d_ = stats["drops"]
            if stats["arrived"] != (stats["processed"] + stats["replayed"]
                                    + d_["watermark"] + d_["admission"]
                                    + stats["unprocessed"]):
                return dict(ok=False, why=f"crashed run unbalanced: {stats}")
            try:
                rec = StreamService(eng, cfg).resume(_mk_source(app))
            except FileNotFoundError:
                rec = StreamService(eng, cfg).run(_mk_source(app))
        snap = rec.stats["replayed"] // INTERVAL
        if not np.array_equal(rec.final_values, ref.final_values):
            return dict(ok=False, why="final state differs after recovery")
        why = _outputs_equal(rec.outputs, ref.outputs[snap:])
        if why:
            return dict(ok=False, why=why)
        return dict(ok=True, crashed=crashed, fired=plane.fired,
                    resumed_from=snap)


def check_overflow_escalation(app_name):
    """A starved exchange (slack 1.0) drops ops; with escalate_overflow
    the service widens the slack at a punctuation boundary and completes
    (degraded-service mode, driven by the implicit slack-only
    controller)."""
    app = ALL_APPS[app_name]
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig(), mesh=MESH,
                         exchange_slack=1.0)
    cfg = ServiceConfig(punct_interval=INTERVAL, chunk_intervals=2,
                        watermark=WM, escalate_overflow=2,
                        escalate_factor=2.0)
    rec = StreamService(eng, cfg).run(_mk_source(app, n_events=320, seed=9))
    xch = rec.stats["exchange"]
    if rec.stats["drops"]["exchange"] == 0:
        # slack 1.0 happened to suffice for this app's key skew: the
        # escalation path wasn't exercised — report, don't fail
        return dict(ok=True, skipped="no overflow at slack 1.0",
                    capacity=xch["capacity"])
    if xch["escalations"] == 0:
        return dict(ok=False, why="ops dropped but no escalation fired")
    if xch["slack"] <= 1.0:
        return dict(ok=False, why=f"slack not widened: {xch['slack']}")
    # the service survived the recompile and kept committing
    if rec.stats["processed"] == 0 or rec.stats["crashed"]:
        return dict(ok=False, why="service did not keep running")
    return dict(ok=True, escalations=xch["escalations"], slack=xch["slack"],
                dropped=rec.stats["drops"]["exchange"])


def check_adaptive_escalation_replay(app_name):
    """Escalation composes with snapshots: crash after the first slack
    escalation, restore, replay — bitwise identical to the uninterrupted
    escalating run, decision trace included (DESIGN.md §2.9)."""
    app = ALL_APPS[app_name]
    mk_eng = lambda: DualModeEngine(app, app.make_store(), EngineConfig(),
                                    mesh=MESH, exchange_slack=1.0)
    src = lambda: _mk_source(app, n_events=320, seed=9)
    kw = dict(punct_interval=INTERVAL, chunk_intervals=2, watermark=WM,
              escalate_overflow=2, escalate_factor=2.0, snapshot_every=2)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        ref = StreamService(mk_eng(), ServiceConfig(ckpt_dir=d1,
                                                    **kw)).run(src())
        if ref.stats["exchange"]["escalations"] == 0:
            return dict(ok=True, skipped="no escalation at slack 1.0")
        svc = StreamService(mk_eng(), ServiceConfig(ckpt_dir=d2, **kw))
        try:
            svc.run(src(),
                    crash_after_interval=ref.decisions[0]["g"] + 1)
            return dict(ok=False, why="injected crash did not fire")
        except RuntimeError:
            pass
        rec = svc.resume(src())
        if rec.decisions != ref.decisions:
            return dict(ok=False,
                        why=f"decision traces differ: {rec.decisions} "
                            f"!= {ref.decisions}")
        if not np.array_equal(rec.final_values, ref.final_values):
            return dict(ok=False, why="final state differs after recovery")
        snap = rec.stats["replayed"] // INTERVAL
        why = _outputs_equal(rec.outputs, ref.outputs[snap:])
        if why:
            return dict(ok=False, why=why)
        return dict(ok=True, escalations=ref.stats["exchange"]["escalations"],
                    decisions=len(ref.decisions), resumed_from=snap)


def main():
    out = {}

    def run(name, fn, *a):
        try:
            out[name] = fn(*a)
        except Exception as e:  # pragma: no cover - surfaced via verdict
            traceback.print_exc(file=sys.stderr)
            out[name] = dict(ok=False, why=f"{type(e).__name__}: {e}")

    run("gs/chaos-0", check_sharded_chaos, "gs", 0)
    run("gs/chaos-3", check_sharded_chaos, "gs", 3)
    run("gs/escalation", check_overflow_escalation, "gs")
    run("gs/adaptive-replay", check_adaptive_escalation_replay, "gs")
    print(json.dumps(out))


if __name__ == "__main__":
    main()
