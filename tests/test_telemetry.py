"""Unified telemetry plane (DESIGN.md §2.11).

Contracts pinned here:

1. **Replay safety**: a tracing-enabled service run is bitwise identical
   to the tracing-off run — final state and every per-interval output —
   including crash -> restore -> replay with tracing on both sides.
   (The 8-device sharded cases live in tests/telemetry_worker.py.)
2. **Deterministic histograms**: log-bucket assignment is a pure
   function of the geometry; merge is exact (integer bucket counts +
   integer-nanosecond totals), associative, and conserves count/total.
3. **Advisory-only timing**: with snapshots on, ``allow_timing`` hints
   are recorded and logged but the applied plan never moves on timing
   evidence.
4. **Schema/trace validity**: the Perfetto writer emits a parseable
   Chrome-trace array (tolerating a missing ``]`` after a crash) that
   covers every pipeline stage; ``stats_view`` renders the legacy stats
   dict from a registry snapshot; ``StreamService.stats`` is
   schema-valid before any run.
"""
import json
import logging
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core.intervals import (IntervalAssembler, ReplaySource,
                                  WatermarkPolicy)
from repro.core.scheduler import DualModeEngine, EngineConfig
from repro.runtime.controller import ControllerConfig
from repro.runtime.service import ServiceConfig, StreamService
from repro.runtime.telemetry import (PIPELINE_STAGES, Histogram, Telemetry,
                                     TelemetryConfig, TraceWriter,
                                     counter_value, empty_stats,
                                     histogram_from, stage_summary,
                                     stats_view, validate_trace)


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------
def test_histogram_bucketing_deterministic():
    a, b = Histogram(), Histogram()
    vals = [1e-7, 1e-6, 3.7e-4, 0.2, 5.0, 1e9]   # under lo .. overflow
    a.observe_many(vals)
    for v in vals:
        b.observe(v)
    np.testing.assert_array_equal(a.counts, b.counts)
    assert a.count == b.count == len(vals)
    assert a.total_ns == b.total_ns
    assert a.counts[0] >= 2            # <= lo lands in bucket 0
    assert a.counts[-1] == 1           # overflow bucket holds 1e9


def test_histogram_merge_exact_and_associative():
    rng = np.random.default_rng(7)
    parts = [rng.uniform(1e-6, 10.0, size=n) for n in (13, 57, 220)]
    whole = Histogram()
    whole.observe_many(np.concatenate(parts))

    def hist(v):
        h = Histogram()
        h.observe_many(v)
        return h

    # (a + b) + c == a + (b + c) == whole, bit-for-bit
    left = hist(parts[0]).merge(hist(parts[1])).merge(hist(parts[2]))
    right = hist(parts[0]).merge(hist(parts[1]).merge(hist(parts[2])))
    for m in (left, right):
        np.testing.assert_array_equal(m.counts, whole.counts)
        assert m.count == whole.count
        assert m.total_ns == whole.total_ns      # integer-exact, no float drift
        assert m.vmin == whole.vmin and m.vmax == whole.vmax


def test_histogram_geometry_mismatch_refused():
    with pytest.raises(AssertionError, match="geometry mismatch"):
        Histogram().merge(Histogram(lo=1e-3))


def test_histogram_percentile_within_observed_range():
    h = Histogram()
    h.observe_many([0.001, 0.002, 0.010, 0.500])
    for q in (0, 50, 99, 100):
        assert 0.001 <= h.percentile(q) <= 0.500
    assert np.isnan(Histogram().percentile(50))


def test_histogram_roundtrip():
    h = Histogram()
    h.observe_many([1e-5, 0.3, 7.0])
    r = Histogram.from_dict(json.loads(json.dumps(h.to_dict())))
    np.testing.assert_array_equal(r.counts, h.counts)
    assert (r.count, r.total_ns, r.vmin, r.vmax) == \
        (h.count, h.total_ns, h.vmin, h.vmax)


def test_hypothesis_merge_conservation_and_assembler_ledger():
    """Property suite: histogram merge conserves count/total under any
    split, and the assembler's published ledger satisfies the
    conservation law for any arrival pattern."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.floats(min_value=1e-9, max_value=1e4),
                        min_size=0, max_size=80),
               st.integers(min_value=0, max_value=80))
    @hyp.settings(max_examples=50, deadline=None)
    def check_merge(vals, cut):
        cut = min(cut, len(vals))
        whole, a, b = Histogram(), Histogram(), Histogram()
        whole.observe_many(vals)
        a.observe_many(vals[:cut])
        b.observe_many(vals[cut:])
        m = a.merge(b)
        np.testing.assert_array_equal(m.counts, whole.counts)
        assert m.count == whole.count and m.total_ns == whole.total_ns

    @hyp.given(st.lists(st.lists(st.integers(min_value=0, max_value=200),
                                 min_size=1, max_size=20),
                        min_size=1, max_size=12),
               st.integers(min_value=0, max_value=8),
               st.sampled_from(["reroute", "drop"]))
    @hyp.settings(max_examples=50, deadline=None)
    def check_ledger(batches, lateness, late):
        asm = IntervalAssembler(4, WatermarkPolicy(
            allowed_lateness=lateness, late=late))
        for times in batches:
            t = np.asarray(times, np.int64)
            asm.push({"x": np.arange(t.size)}, t)
            asm.pop_ready()
        assert asm.conservation_ok(), asm.ledger
        tele = Telemetry()
        asm.publish(tele)
        snap = tele.snapshot()
        led = asm.ledger
        for k, v in led.items():
            assert counter_value(snap, f"assembly.{k}") == v
        assert led["arrived"] == (led["assembled"] + led["dropped"]
                                  + led["pending"])

    check_merge()
    check_ledger()


# ---------------------------------------------------------------------------
# registry: events, merge, stats view
# ---------------------------------------------------------------------------
def test_event_rate_limit(caplog):
    tele = Telemetry()
    logger = logging.getLogger("repro.test.telemetry")
    with caplog.at_level(logging.WARNING, logger=logger.name):
        for _ in range(5):
            tele.event("dropped", "dropped %d", 3, logger=logger)
    assert sum("dropped 3" in r.message for r in caplog.records) == 1
    ev = [e for e in tele.snapshot()["events"] if e["name"] == "dropped"]
    assert ev[0]["count"] == 5 and ev[0]["emitted"] == 1


def test_registry_merge():
    a, b = Telemetry(), Telemetry()
    a.count("n", 2, kind="x")
    b.count("n", 3, kind="x")
    a.observe("lat", 0.5)
    b.observe("lat", 0.25)
    a.gauge("g", 1.0)
    b.gauge("g", 9.0)
    b.record("r", step=4)
    a.merge(b)
    snap = a.snapshot()
    assert counter_value(snap, "n", kind="x") == 5
    assert histogram_from(snap, "lat").count == 2
    assert [g["value"] for g in snap["gauges"] if g["name"] == "g"] == [9.0]
    assert snap["records"]["r"] == [dict(step=4)]


def test_empty_stats_schema_valid():
    s = empty_stats()
    assert s["arrived"] == 0 and not s["crashed"]
    assert s["drops"] == dict(watermark=0, admission=0, exchange=0)
    assert s["assembly"]["arrived"] == 0
    assert s["source"]["pulls"] == 0
    assert s["snapshots"] == [] and s["chunks"] == []


def test_service_stats_before_any_run():
    """Regression: ``service.stats`` used to be None before the first
    run — every consumer needed a guard.  Now it is the schema-valid
    zero record."""
    app = ALL_APPS["gs"]
    svc = StreamService(
        DualModeEngine(app, app.make_store(), EngineConfig()),
        ServiceConfig(punct_interval=16))
    assert svc.stats["drops"]["watermark"] == 0
    assert svc.stats["crashed"] is False
    assert svc.stats == empty_stats()


# ---------------------------------------------------------------------------
# trace writer / validator
# ---------------------------------------------------------------------------
def test_trace_writer_and_validator(tmp_path):
    path = str(tmp_path / "t.json")
    w = TraceWriter(path)
    w.emit(dict(name="chunk.execute", ph="X", ts=1, dur=5, pid=1, tid=1,
                cat="pipeline"))
    w.emit(dict(name="mark", ph="i", ts=2, pid=1, tid=1))
    w.close()
    ok, why, info = validate_trace(path,
                                   require_stages=["chunk.execute"])
    assert ok, why
    assert info["n_events"] == 2


def test_validator_tolerates_truncated_trace(tmp_path):
    """A crashed writer never gets to append the closing ``]`` — the
    validator (and Perfetto) must still parse the array."""
    path = str(tmp_path / "t.json")
    w = TraceWriter(path)
    w.emit(dict(name="source.pull", ph="X", ts=0, dur=1, pid=1, tid=1,
                cat="pipeline"))
    w.flush()            # no close(): simulated crash
    ok, why, info = validate_trace(path, require_stages=["source.pull"])
    assert ok, why


def test_validator_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text('[{"ph": "X", "ts": -4}')
    ok, why, _ = validate_trace(str(bad))
    assert not ok


# ---------------------------------------------------------------------------
# replay safety on the live service (single device)
# ---------------------------------------------------------------------------
def _run_service(app, tcfg, *, n_events=80, cfg_kw=None, **run_kw):
    src = ReplaySource(app.gen_events, n_events, seed=11,
                       arrival_batch=13, jitter=5)
    store = app.make_store()
    eng = DualModeEngine(app, store, EngineConfig(scheme="tstream"))
    svc = StreamService(eng, ServiceConfig(
        punct_interval=16, chunk_intervals=2,
        watermark=WatermarkPolicy(allowed_lateness=5),
        telemetry=tcfg, **(cfg_kw or {})))
    return svc, svc.run(src, **run_kw)


def test_tracing_bitwise_identical_single_device(tmp_path):
    app = ALL_APPS["gs"]
    _, ref = _run_service(app, None)
    trace = str(tmp_path / "trace.json")
    _, rec = _run_service(app, TelemetryConfig(trace_path=trace))
    np.testing.assert_array_equal(rec.final_values, ref.final_values)
    assert len(rec.outputs) == len(ref.outputs)
    for a, b in zip(rec.outputs, ref.outputs):
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
    # stats agree except wall-clock chunk latencies
    for k in ref.stats:
        if k != "chunks":
            assert rec.stats[k] == ref.stats[k], k
    want = [s for s in PIPELINE_STAGES if s != "snapshot.publish"]
    ok, why, info = validate_trace(trace, require_stages=want)
    assert ok, why
    assert stage_summary(trace)          # non-empty per-stage table
    # the registry carries the span histograms without touching stats
    snap = rec.telemetry.snapshot()
    assert histogram_from(snap, "span.chunk.execute").count > 0
    assert stats_view(snap) == rec.stats


def test_traced_crash_restore_replay_bitwise(tmp_path):
    app = ALL_APPS["gs"]
    _, ref = _run_service(app, None)
    ck = str(tmp_path / "ckpt")
    kw = dict(snapshot_every=2, ckpt_dir=ck)
    crash_trace = str(tmp_path / "crash.json")
    svc = StreamService(
        DualModeEngine(app, app.make_store(),
                       EngineConfig(scheme="tstream")),
        ServiceConfig(punct_interval=16, chunk_intervals=2,
                      watermark=WatermarkPolicy(allowed_lateness=5),
                      telemetry=TelemetryConfig(trace_path=crash_trace),
                      **kw))
    src = lambda: ReplaySource(app.gen_events, 80, seed=11,
                               arrival_batch=13, jitter=5)
    with pytest.raises(RuntimeError):
        svc.run(src(), crash_after_interval=3)
    assert svc.last_run.snapshots
    # crashed run's trace still parses and carries the snapshot spans
    ok, why, _ = validate_trace(crash_trace,
                                require_stages=["snapshot.publish"])
    assert ok, why
    resume_trace = str(tmp_path / "resume.json")
    rec = StreamService(
        svc.engine, ServiceConfig(
            punct_interval=16, chunk_intervals=2,
            watermark=WatermarkPolicy(allowed_lateness=5),
            telemetry=TelemetryConfig(trace_path=resume_trace),
            **kw)).resume(src())
    snap = rec.stats["replayed"] // 16
    np.testing.assert_array_equal(rec.final_values, ref.final_values)
    assert len(rec.outputs) == len(ref.outputs[snap:])
    for a, b in zip(rec.outputs, ref.outputs[snap:]):
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
    ok, why, _ = validate_trace(resume_trace, require_stages=[
        "chunk.dispatch", "chunk.execute", "chunk.commit"])
    assert ok, why


def test_advisory_timing_recorded_never_applied(tmp_path):
    """With snapshots on, ``allow_timing=True`` becomes advisory: the
    grow-on-low-latency rule fires as a recorded hint, the applied plan
    never moves, and the run still matches the untraced reference."""
    app = ALL_APPS["gs"]
    ctl = ControllerConfig(window=2, sustain=1, cooldown=1,
                           degrade_scheme="", chunk_ladder=(2, 4),
                           backlog_grow=1e9,      # backlog rule can't fire
                           allow_timing=True, grow_lat_s=1e9)
    kw = dict(cfg_kw=dict(controller=ctl, snapshot_every=4,
                          ckpt_dir=str(tmp_path / "ck")), n_events=160)
    _, ref = _run_service(app, None, **kw)
    assert ref.stats["controller"]["plan"]["chunk"] == 2, \
        "timing grow leaked into the applied plan"
    assert not any(d["knob"] == "chunk" for d in ref.decisions)
    hints = ref.stats["controller"].get("advisory", [])
    assert hints, "advisory channel recorded no hints"
    assert all(h["advisory"] for h in hints)
    assert any(h["knob"] == "chunk" and h["reason"] == "amortize-dispatch"
               for h in hints)
    # hints are not decisions: the decision trace stays empty and the
    # snapshot meta (replayed plan) is unaffected
    assert ref.stats["controller"]["decisions"] == []


# ---------------------------------------------------------------------------
# sharded replay safety (subprocess forces 8 host devices)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def telemetry_worker_verdicts():
    worker = os.path.join(os.path.dirname(__file__), "telemetry_worker.py")
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("case", ["gs/traced_identical",
                                  "gs/traced_crash_resume"])
def test_sharded_telemetry_replay_safety(telemetry_worker_verdicts, case):
    v = telemetry_worker_verdicts[case]
    assert v["ok"], f"{case}: {v.get('why')}"
