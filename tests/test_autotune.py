"""Autotuned kernel dispatch: cache, keying, disk round-trip, forcing.

The contract under test (DESIGN.md §2.8):
* one microbenchmark per (kernel, shape-bucket, dtype, device_kind) per
  process — cache hits never re-bench;
* ``device_kind`` is part of the key (a decision tuned on one device
  kind never leaks to another);
* decisions round-trip through the on-disk JSON cache, and a warm disk
  cache makes dispatch deterministic with zero benching;
* ``force=`` bypasses the cache entirely (both directions), and
  ``EngineConfig.kernel_block_params`` pins block parameters all the way
  through the fused driver without consulting the autotuner.
"""
import json

import numpy as np
import pytest

from repro.kernels import autotune


@pytest.fixture(autouse=True)
def fresh_cache():
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def make_bench(table=None, log=None):
    """bench_fn stub: records calls, returns scripted timings."""
    calls = [] if log is None else log

    def bench(c):
        calls.append(c)
        return float(table.get(c, 1.0)) if table else 1.0

    bench.calls = calls
    return bench


def test_microbench_picks_fastest_candidate():
    bench = make_bench({256: 5e-6, 128: 1e-6, 512: 5e-6, 1024: 5e-6})
    d = autotune.decide("segscan", 1 << 12, bench_fn=bench,
                        interpret=False, device_kind="testkind")
    assert d.source == "microbench"
    assert d.param == 128
    assert set(map(int, d.timings_us)) == set(d.candidates)


def test_cached_decision_reused_without_rebench():
    bench = make_bench()
    d1 = autotune.decide("segscan", 1000, bench_fn=bench,
                         interpret=False, device_kind="testkind")
    assert d1.source == "microbench" and bench.calls
    n_calls = len(bench.calls)
    # 900 and 1000 share the 2^10 shape bucket -> pure cache hit
    d2 = autotune.decide("segscan", 900, bench_fn=bench,
                         interpret=False, device_kind="testkind")
    assert d2 is d1
    assert len(bench.calls) == n_calls
    # a different bucket re-benches once
    autotune.decide("segscan", 5000, bench_fn=bench,
                    interpret=False, device_kind="testkind")
    assert len(bench.calls) > n_calls


def test_device_kind_is_part_of_the_key():
    bench_a = make_bench({256: 1e-6, 128: 5e-6, 512: 5e-6, 1024: 5e-6})
    bench_b = make_bench({256: 5e-6, 128: 5e-6, 512: 1e-6, 1024: 5e-6})
    da = autotune.decide("segscan", 1 << 12, bench_fn=bench_a,
                         interpret=False, device_kind="kind-a")
    db = autotune.decide("segscan", 1 << 12, bench_fn=bench_b,
                         interpret=False, device_kind="kind-b")
    assert da.key != db.key
    assert (da.param, db.param) == (256, 512)
    # both live in the cache simultaneously
    assert autotune.decide("segscan", 1 << 12, interpret=False,
                           device_kind="kind-a").param == 256
    assert autotune.decide("segscan", 1 << 12, interpret=False,
                           device_kind="kind-b").param == 512


def test_interpret_default_is_deterministic_and_matches_shipped_shapes():
    # interpret mode never times anything: the decision is the first
    # candidate == the hand-validated shipped constant, every process
    for kernel, shipped in (("segscan", 256), ("radix_partition", 256),
                            ("hash_probe", 128), ("megakernel", 4096)):
        d = autotune.decide(kernel, 1 << 12, interpret=True,
                            device_kind="testkind")
        assert d.source == "interpret-default"
        assert d.param == shipped
        assert autotune.decide(kernel, 1 << 12, interpret=True,
                               device_kind="testkind").param == shipped


def test_disk_cache_round_trip(tmp_path):
    path = str(tmp_path / "tune.json")
    bench = make_bench({256: 5e-6, 128: 1e-6, 512: 5e-6, 1024: 5e-6})
    d1 = autotune.decide("segscan", 1 << 12, bench_fn=bench,
                         interpret=False, device_kind="testkind",
                         cache_path=path)
    assert d1.param == 128
    with open(path) as f:
        stored = json.load(f)["decisions"]
    assert any(r["param"] == 128 and r["kernel"] == "segscan"
               for r in stored)

    # a fresh process (cleared cache) with the same disk cache must make
    # the SAME decision without benching at all
    autotune.clear_cache()
    bench2 = make_bench({256: 1e-6, 128: 9e-6, 512: 9e-6, 1024: 9e-6})
    d2 = autotune.decide("segscan", 1 << 12, bench_fn=bench2,
                         interpret=False, device_kind="testkind",
                         cache_path=path)
    assert d2.source == "disk"
    assert d2.param == 128
    assert not bench2.calls


def test_disk_cache_ignores_garbage(tmp_path):
    path = str(tmp_path / "tune.json")
    with open(path, "w") as f:
        f.write("{not json")
    d = autotune.decide("segscan", 1 << 12, interpret=True,
                        device_kind="testkind", cache_path=path)
    assert d.param == 256  # fell through to the default, no crash


def test_forced_override_beats_cache_and_never_benches():
    d1 = autotune.decide("segscan", 1 << 9, interpret=True,
                         device_kind="testkind")
    bench = make_bench()
    d2 = autotune.decide("segscan", 1 << 9, force=192, bench_fn=bench,
                         interpret=False, device_kind="testkind")
    assert d2.source == "forced" and d2.param == 192
    assert not bench.calls
    # the cache is untouched by the forced call
    d3 = autotune.decide("segscan", 1 << 9, interpret=True,
                         device_kind="testkind")
    assert d3.param == d1.param
    assert autotune.block_rows("segscan", 1 << 9, force=64) == 64


def test_decisions_logged_once_per_key(caplog):
    import logging
    with caplog.at_level(logging.INFO, logger="repro.kernels.autotune"):
        autotune.decide("segscan", 1 << 12, interpret=True,
                        device_kind="testkind")
        autotune.decide("segscan", 1 << 12, interpret=True,
                        device_kind="testkind")
    hits = [r for r in caplog.records if "autotune:" in r.getMessage()]
    assert len(hits) == 1


def test_decision_log_artifact(tmp_path, monkeypatch):
    logp = str(tmp_path / "decisions.jsonl")
    monkeypatch.setenv("REPRO_AUTOTUNE_LOG", logp)
    autotune.decide("segscan", 1 << 12, interpret=True,
                    device_kind="testkind")
    autotune.decide("hash_probe", 1 << 10, dtype="int32", interpret=True,
                    device_kind="testkind")
    with open(logp) as f:
        recs = [json.loads(line) for line in f]
    assert {r["kernel"] for r in recs} == {"segscan", "hash_probe"}


def test_engineconfig_pins_block_params_without_autotune(monkeypatch):
    """The fused driver with every block parameter pinned via
    ``EngineConfig.kernel_block_params`` must never consult the
    autotuner — and pinning the defaults reproduces the default run
    bit for bit."""
    from repro.apps import ALL_APPS
    from repro.core.scheduler import DualModeEngine, EngineConfig

    app = ALL_APPS["gs"]
    rng = np.random.default_rng(3)
    stream = app.gen_events(rng, 64)
    store = app.make_store()

    ref_eng = DualModeEngine(app, store, EngineConfig(use_pallas=True))
    outs_ref, vals_ref = ref_eng.run_stream(store.values, stream, 16,
                                            fused=True)

    def boom(*a, **kw):  # any lookup is a pin violation
        raise AssertionError("autotune consulted despite pinned params")

    monkeypatch.setattr(autotune, "block_rows", boom)
    cfg = EngineConfig(use_pallas=True,
                       kernel_block_params=(("segscan", 256),
                                            ("radix_partition", 256),
                                            ("hash_probe", 128)))
    assert cfg.block_param("segscan") == 256
    assert cfg.block_param("megakernel") is None
    eng = DualModeEngine(app, store, cfg)
    outs, vals = eng.run_stream(store.values, stream, 16, fused=True)

    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals_ref))
    for a, b in zip(outs, outs_ref):
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
