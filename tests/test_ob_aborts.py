"""OB bid rejection semantics (the paper's 'rejected' notifications) and
engine-stat invariants under the hypothesis harness."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.apps import OB
from repro.core.scheduler import DualModeEngine, EngineConfig


def test_bid_rejection_matches_oracle_decisions():
    rng = np.random.default_rng(5)
    stream = OB.gen_events(rng, 256)
    store = OB.make_store()
    out_t = DualModeEngine(OB, store, EngineConfig("tstream")).run_stream(
        store.values, stream, 128)
    out_l = DualModeEngine(OB, store, EngineConfig("lock")).run_stream(
        store.values, stream, 128)
    rej_t = np.concatenate([np.asarray(o["rejected"]) for o in out_t[0]])
    rej_l = np.concatenate([np.asarray(o["rejected"]) for o in out_l[0]])
    np.testing.assert_array_equal(rej_t, rej_l)
    assert rej_t.sum() > 0, "workload should produce some rejections"
    # quantities never negative (consistency property, paper §IV-D)
    vals = np.asarray(out_t[1])
    assert np.all(vals[:-1, 1] >= -1e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantities_never_negative(seed):
    """Consistency (paper §IV-D): bounded bids can never drive quantity
    below zero, whatever the interleaving."""
    rng = np.random.default_rng(seed)
    stream = OB.gen_events(rng, 128)
    stream["qtys"] = (stream["qtys"] * 100).astype(np.float32)  # aggressive
    store = OB.make_store()
    _, vals = DualModeEngine(OB, store, EngineConfig("tstream")).run_stream(
        store.values, stream, 64)
    assert np.all(np.asarray(vals)[:-1, 1] >= -1e-3)
