"""Substrate tests: checkpoint/restart exactness, elastic reshard,
straggler policy, optimizer, gradient compression, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, load_checkpoint, save_checkpoint
from repro.data import PipelineConfig, StreamingPipeline
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_int8, decompress_int8, cosine_schedule,
                         wsd_schedule)
from repro.runtime import StragglerPolicy, TrainLoop, TrainLoopConfig
from repro.runtime.controller import _shard_imbalance


def _tiny_setup(tmp):
    cfg = AdamWConfig(lr=1e-2, state_dtype=jnp.float32)
    params = dict(w=jnp.ones((4, 4)), b=jnp.zeros((4,)))
    opt = adamw_init(params, cfg)

    def step_fn(params, opt_state, batch):
        def loss(p):
            y = batch["x"] @ p["w"] + p["b"]
            return jnp.mean((y - batch["y"]) ** 2)
        l, g = jax.value_and_grad(loss)(params)
        p2, s2 = adamw_update(params, g, opt_state, cfg)
        return p2, s2, l

    def make_batch(step, rng):
        x = rng.normal(size=(8, 4)).astype(np.float32)
        return dict(x=jnp.asarray(x), y=jnp.asarray(x @ np.ones((4, 4),
                                                               np.float32)))

    return jax.jit(step_fn), make_batch, params, opt


def test_crash_restart_bitwise_exact(tmp_path):
    step_fn, make_batch, params, opt = _tiny_setup(tmp_path)
    cfg = TrainLoopConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=10,
                          max_steps=40)
    # uninterrupted run
    loop = TrainLoop(cfg, step_fn, make_batch, params, opt)
    ref = loop.run()

    # crashed + resumed run
    cfg2 = TrainLoopConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=10,
                           max_steps=40)
    loop2 = TrainLoop(cfg2, step_fn, make_batch, params, opt)
    with pytest.raises(RuntimeError):
        loop2.run(crash_at=25)
    loop3 = TrainLoop(cfg2, step_fn, make_batch, params, opt)
    assert loop3.try_resume() and loop3.start_step == 20
    out = loop3.run()
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(out[k]))


def test_ckpt_reshard_roundtrip(tmp_path):
    tree = dict(a=jnp.arange(12.0).reshape(3, 4),
                nested=dict(b=jnp.ones((5,), jnp.bfloat16)))
    save_checkpoint(str(tmp_path), 3, tree)
    assert latest_step(str(tmp_path)) == 3
    restored = load_checkpoint(str(tmp_path), 3, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_slow_shard_signal_path():
    """"A shard is slow" has one owner now: StragglerPolicy classifies
    slow source pulls (service deadline path) and the controller's
    imbalance ratio classifies slow device shards — the old standalone
    ShardDispatcher is gone."""
    pol = StragglerPolicy(deadline_s=0.5, max_backfill_ratio=0.25)
    assert pol.deadline_s == 0.5 and pol.max_backfill_ratio == 0.25
    with pytest.raises(ImportError):
        from repro.runtime import ShardDispatcher  # noqa: F401
    # imbalance ratio = hottest shard / mean shard load
    assert _shard_imbalance(dict(x_shard=[100, 100, 100, 100])) == 1.0
    assert _shard_imbalance(dict(x_shard=[700, 100, 100, 100])) == \
        pytest.approx(2.8)
    assert _shard_imbalance(dict(x_shard=[])) == 1.0
    assert _shard_imbalance(dict()) == 1.0


def test_schedules_monotone_segments():
    import jax.numpy as jnp
    s = wsd_schedule(jnp.asarray(0), warmup=10, stable=100, decay=50)
    assert float(s) == 0.0
    assert float(wsd_schedule(jnp.asarray(50), warmup=10, stable=100,
                              decay=50)) == 1.0
    end = float(wsd_schedule(jnp.asarray(160), warmup=10, stable=100,
                             decay=50))
    assert end <= 0.02
    assert 0.0 < float(cosine_schedule(jnp.asarray(500), warmup=10,
                                       total=1000)) < 1.0


def test_int8_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = compress_int8(x)
    y = decompress_int8(q, s, x.shape)
    err = np.abs(np.asarray(y - x))
    assert err.max() < np.abs(np.asarray(x)).max() / 100
    assert q.dtype == jnp.int8


def test_pipeline_deterministic_and_stats():
    pipe = StreamingPipeline(PipelineConfig())
    b1 = pipe.batch_for_step(5)
    b2 = pipe.batch_for_step(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    rng = np.random.default_rng(0)
    out = pipe.ingest(rng, 64)
    w = pipe.mixture_weights()
    assert np.isclose(w.sum(), 1.0) and np.all(w > 0)
    # domain counters actually accumulated through the TStream engine
    vals = np.asarray(pipe.stats_values)
    assert vals[:16, 1].sum() == 64  # doc_count lane
