"""Elastic-resharding worker (subprocess: forces 8 host devices).

Engine-level contracts of live skew-aware migration (DESIGN.md §2.10),
reported as JSON verdicts for tests/test_elastic_reshard.py:

* **Migrate mid-stream, stay bitwise**: a skew storm (calm -> aligned
  Zipf hot phase -> calm) trips the controller's ``reshard`` knob; the
  service live-migrates hot slots at a punctuation boundary and every
  interval output AND the final state stay bit-identical to the
  never-migrated single-device monolithic run on the same in-order
  events — across all four apps and both the tstream and mvlk schemes.
* **Crash during migration**: an injected ``reshard.apply`` crash lands
  after the rows moved but before any snapshot records the migrated
  run; restore + replay re-derives the same reshard decision from the
  same records and the resumed run is bitwise identical to the
  uninterrupted elastic run (and to the single-device reference).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys
import tempfile
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.apps import ALL_APPS                                 # noqa: E402
from repro.core.intervals import (PhasedReplaySource,           # noqa: E402
                                  WatermarkPolicy)
from repro.core.scheduler import DualModeEngine, EngineConfig   # noqa: E402
from repro.runtime.controller import ControllerConfig           # noqa: E402
from repro.runtime.faults import (RESHARD_APPLY, Fault,         # noqa: E402
                                  FaultPlane, InjectedCrashError)
from repro.runtime.service import ServiceConfig, StreamService  # noqa: E402

MESH = jax.make_mesh((8,), ("dev",))
INTERVAL = 64
JITTER = 4
# reshard-only controller: every other knob's lattice is empty
CTL = ControllerConfig(window=4, sustain=2, cooldown=4, slack_widen=False,
                       reshard_imbalance=3.0, reshard_max_moves=24)


def app_kwargs(app_name):
    # TP's segment table must stay divisible by align_mod=8
    return dict(n_segments=96) if app_name == "tp" else {}


def storm_source(app, base, seed=7):
    """calm -> aligned-Zipf hot phase -> calm, all one seeded stream."""
    hot = dict(base, theta=2.5, align_mod=8)
    return PhasedReplaySource(
        app.gen_events,
        [(4 * INTERVAL, base), (8 * INTERVAL, hot), (4 * INTERVAL, base)],
        seed=seed, arrival_batch=37, jitter=JITTER)


def elastic_cfg(**kw):
    return ServiceConfig(punct_interval=INTERVAL, chunk_intervals=2,
                         watermark=WatermarkPolicy(allowed_lateness=JITTER),
                         controller=CTL, **kw)


def _outputs_equal(a_list, b_list):
    for i, (a, b) in enumerate(zip(a_list, b_list)):
        for k in a:
            if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
                return f"output {k} interval {i} differs"
    if len(a_list) != len(b_list):
        return f"interval count {len(a_list)} != {len(b_list)}"
    return None


def _single_device_ref(app, store, scheme, src):
    eng1 = DualModeEngine(app, store, EngineConfig(scheme=scheme))
    return eng1.run_stream(store.values, src.in_order_events, INTERVAL,
                           fused=True)


def check_migrate_bitwise(app_name, scheme):
    app = ALL_APPS[app_name]
    kw = app_kwargs(app_name)
    store = app.make_store(**kw)
    src = storm_source(app, kw)
    outs_ref, vals_ref = _single_device_ref(app, store, scheme, src)

    eng8 = DualModeEngine(app, store, EngineConfig(scheme=scheme),
                          mesh=MESH, exchange_slack=8.0)
    rec = StreamService(eng8, elastic_cfg()).run(storm_source(app, kw))

    place = rec.stats.get("placement")
    if not place or not place["migrations"]:
        return dict(ok=False, why=f"no migration fired: {place}")
    if place["moved_rows"] <= 0:
        return dict(ok=False, why="migration fired but moved no rows")
    if not any(d["knob"] == "reshard" for d in rec.decisions):
        return dict(ok=False, why="no reshard decision in the trace")
    if not place["owners"]:
        return dict(ok=False, why="engine left on striping placement")
    if rec.stats["drops"]["exchange"]:
        return dict(ok=False, why="exchange dropped ops during the storm")
    if not np.array_equal(rec.final_values, np.asarray(vals_ref)):
        return dict(ok=False, why="final state differs vs 1dev reference")
    why = _outputs_equal(rec.outputs, outs_ref)
    if why:
        return dict(ok=False, why=f"vs 1dev reference: {why}")
    return dict(ok=True, migrations=len(place["migrations"]),
                moved=place["moved_rows"], imbalance=place["imbalance"])


def check_reshard_crash_recovery(app_name, scheme):
    app = ALL_APPS[app_name]
    kw = app_kwargs(app_name)
    store = app.make_store(**kw)
    src = storm_source(app, kw)
    outs_1, vals_1 = _single_device_ref(app, store, scheme, src)

    def fresh():
        return DualModeEngine(app, store, EngineConfig(scheme=scheme),
                              mesh=MESH, exchange_slack=8.0)

    with tempfile.TemporaryDirectory() as d:
        ref = StreamService(fresh(), elastic_cfg(
            snapshot_every=4, ckpt_dir=os.path.join(d, "ref"))).run(
                storm_source(app, kw))
        if not ref.stats["placement"]["migrations"]:
            return dict(ok=False, why="reference run never migrated")

        cfg = elastic_cfg(snapshot_every=4, ckpt_dir=os.path.join(d, "go"))
        plane = FaultPlane([Fault(site=RESHARD_APPLY, at=0, kind="crash")])
        svc = StreamService(fresh(), cfg)
        try:
            svc.run(storm_source(app, kw), faults=plane)
            return dict(ok=False, why="injected reshard crash did not fire")
        except InjectedCrashError:
            pass
        crashed = svc.last_run
        if not crashed.migrations:
            return dict(ok=False, why="crash fired before any migration")
        if not crashed.snapshots:
            return dict(ok=False, why="no snapshot before the crash")

        rec = StreamService(fresh(), cfg).resume(storm_source(app, kw))
        snap = rec.stats["replayed"] // INTERVAL
        if not rec.stats["placement"]["migrations"]:
            return dict(ok=False, why="resumed run never re-migrated")
        # consistent layout: the replayed trace folds to the same plan
        # (same ownership overrides) as the uninterrupted run
        if rec.stats["controller"]["plan"] != ref.stats["controller"]["plan"]:
            return dict(ok=False, why="resumed plan differs: "
                        f"{rec.stats['controller']['plan']} vs "
                        f"{ref.stats['controller']['plan']}")
        if not np.array_equal(rec.final_values, ref.final_values):
            return dict(ok=False,
                        why="final state differs vs uninterrupted elastic")
        if not np.array_equal(rec.final_values, np.asarray(vals_1)):
            return dict(ok=False, why="final state differs vs 1dev")
        why = _outputs_equal(rec.outputs, ref.outputs[snap:])
        if why:
            return dict(ok=False, why=f"post-resume {why}")
        why = _outputs_equal(crashed.outputs,
                             ref.outputs[: len(crashed.outputs)])
        if why:
            return dict(ok=False, why=f"pre-crash {why}")
        return dict(ok=True, resumed_from=snap,
                    migrations=len(rec.stats["placement"]["migrations"]))


def main():
    out = {}

    def run(name, fn, *a):
        try:
            out[name] = fn(*a)
        except Exception as e:  # pragma: no cover - surfaced via verdict
            traceback.print_exc(file=sys.stderr)
            out[name] = dict(ok=False, why=f"{type(e).__name__}: {e}")

    cases = [("gs", "tstream"), ("sl", "tstream"), ("ob", "tstream"),
             ("tp", "tstream"), ("gs", "mvlk"), ("ob", "mvlk")]
    only = sys.argv[1] if len(sys.argv) > 1 else ""
    for app_name, scheme in cases:
        if only and only not in (app_name, f"{app_name}/{scheme}"):
            continue
        run(f"{app_name}/{scheme}/migrate", check_migrate_bitwise,
            app_name, scheme)
    if not only or only in ("gs", "gs/tstream"):
        run("gs/tstream/crash", check_reshard_crash_recovery,
            "gs", "tstream")
    print(json.dumps(out))


if __name__ == "__main__":
    main()
