"""Elastic resharding: skew-aware placement + live state migration
(DESIGN.md §2.10).

Unit layer (single device, in-process): the ownership permutation with
overrides, the greedy skew-aware rebalancer, the exact migration plan,
the skew-storm key aligner, and the controller's ``reshard`` knob
(trigger, cooldown, trace replay, plan serialization).

Engine layer (subprocess, 8 forced host devices —
tests/reshard_worker.py): live migration mid-stream on all four apps
across tstream/mvlk stays bitwise identical to the never-migrated
single-device monolithic run, and an injected ``reshard.apply`` crash
recovers onto a consistent layout.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.common import align_keys                        # noqa: E402
from repro.core.ownership import (build_ownership,              # noqa: E402
                                  migration_plan, owner_of_uids,
                                  rebalance_ownership)
from repro.core.types import make_store                         # noqa: E402
from repro.runtime.controller import (ControllerConfig, Plan,   # noqa: E402
                                      PlanController, norm_owners,
                                      replay_plan)

# ---------------------------------------------------------------------------
# 1. ownership permutation with overrides
# ---------------------------------------------------------------------------


def test_build_ownership_striping_closed_form():
    """Empty overrides reproduce the pre-elastic closed form bit-exactly:
    owner-major uid-ascending == (uid % n) * per + uid // n."""
    for n_slots, n_owners in [(12, 4), (13, 4), (100, 8), (7, 1)]:
        store = make_store([n_slots], 4)
        own = build_ownership(store, n_owners)
        uid = np.arange(n_slots, dtype=np.int64)
        closed = (uid % n_owners) * own.per + uid // n_owners
        np.testing.assert_array_equal(np.asarray(own.fwd)[:-1], closed)
        assert int(np.asarray(own.fwd)[-1]) == own.s_pad
        assert own.overrides == ()


def test_build_ownership_overrides_layout():
    """With overrides every uid lands inside its owner's bin, the map
    stays a bijection, and bins stay uid-ascending."""
    store = make_store([16], 4)
    overrides = ((0, 3), (3, 0))    # a swap: sizes preserved
    own = build_ownership(store, 4, overrides)
    assert own.overrides == norm_owners(overrides)
    fwd = np.asarray(own.fwd)[:-1]
    assert sorted(fwd.tolist()) == sorted(set(fwd.tolist()))
    owner = owner_of_uids(16, 4, overrides)
    np.testing.assert_array_equal(fwd // own.per, owner)
    for o in range(4):
        uids = np.flatnonzero(owner == o)
        ranks = fwd[uids] % own.per
        np.testing.assert_array_equal(np.sort(ranks), np.arange(len(uids)))
        np.testing.assert_array_equal(uids[np.argsort(ranks)],
                                      np.sort(uids))


def test_build_ownership_rejects_bin_overflow():
    store = make_store([8], 4)
    with pytest.raises(AssertionError):
        build_ownership(store, 4, ((1, 0), (2, 0), (3, 0)))  # bin 0: 5 > 2


# ---------------------------------------------------------------------------
# 2. greedy skew-aware rebalance
# ---------------------------------------------------------------------------


def test_rebalance_moves_hot_and_preserves_bin_sizes():
    n_slots, n_owners = 64, 4
    # everything hot lives on shard 0 (uids 0, 4, 8, ...)
    load = np.array([1000, 10, 10, 10], np.int64)
    hot = [(0, 400), (4, 300), (8, 200)]
    new = rebalance_ownership(n_slots, n_owners, (), load, hot)
    assert new, "no overrides produced for a skewed histogram"
    owner = owner_of_uids(n_slots, n_owners, new)
    counts = np.bincount(owner, minlength=n_owners)
    np.testing.assert_array_equal(
        counts, np.bincount(owner_of_uids(n_slots, n_owners, ()),
                            minlength=n_owners))
    moved = dict(new)
    assert any(moved.get(u, u % n_owners) != u % n_owners for u, _ in hot)


def test_rebalance_deterministic_and_pure():
    load = np.array([900, 30, 20, 10], np.int64)
    hot = [(8, 500), (0, 300), (4, 100)]
    a = rebalance_ownership(64, 4, (), load, hot)
    b = rebalance_ownership(64, 4, (), load, list(hot))
    assert a == b
    # shuffling the hot list does not change the outcome (sorted inside)
    c = rebalance_ownership(64, 4, (), load, hot[::-1])
    assert a == c


def test_rebalance_flat_histogram_is_noop():
    load = np.array([100, 100, 100, 100], np.int64)
    assert rebalance_ownership(64, 4, (), load, [(0, 5)]) == ()


# ---------------------------------------------------------------------------
# 3. migration plan exactness
# ---------------------------------------------------------------------------


def test_migration_plan_scatter_semantics():
    """Applying (dst, nidx) as a scatter reproduces exactly the new
    permuted layout from the old one — zero rows dropped or duplicated."""
    n_slots, n_owners = 24, 4
    store = make_store([n_slots], 2)
    old = build_ownership(store, n_owners)
    load = np.array([800, 5, 5, 5], np.int64)
    hot = [(0, 300), (4, 250), (8, 150)]
    new = build_ownership(store, n_owners,
                          rebalance_ownership(n_slots, n_owners, (),
                                              load, hot))
    dst, nidx, cap = migration_plan(old, new)
    per = old.per
    vals = np.arange(n_slots, dtype=np.float64)        # uid as payload
    vo = np.zeros(n_owners * per)
    vo[np.asarray(old.fwd)[:-1]] = vals                # old permuted layout
    sim = np.zeros(n_owners * per)
    for d in range(n_owners):
        for r in range(per):
            if nidx[d, r] < per:
                sim[dst[d, r] * per + nidx[d, r]] = vo[d * per + r]
    want = np.zeros(n_owners * per)
    want[np.asarray(new.fwd)[:-1]] = vals              # new permuted layout
    np.testing.assert_array_equal(sim, want)
    src = np.repeat(np.arange(n_owners), per).reshape(n_owners, per)
    movers = (dst != src)
    pair = src[movers] * n_owners + dst[movers]
    assert cap == max(1, int(np.bincount(pair).max(initial=0)))


def test_migration_plan_identity_when_unchanged():
    store = make_store([16], 4)
    own = build_ownership(store, 4)
    dst, nidx, cap = migration_plan(own, own)
    src = np.repeat(np.arange(4), own.per).reshape(4, own.per)
    np.testing.assert_array_equal(dst, src)
    assert cap == 1


# ---------------------------------------------------------------------------
# 4. skew-storm key alignment (workload side)
# ---------------------------------------------------------------------------


def test_align_keys_bijection_and_residue():
    n_keys, mod = 1000, 8
    keys = np.arange(n_keys, dtype=np.int32)
    out = align_keys(keys, n_keys, mod)
    assert sorted(out.tolist()) == keys.tolist()       # bijection
    # the Zipf head (small key ids) lands on residue class 0 (mod 8):
    # striping uid % n_dev then maps every hot key to one device
    head = align_keys(np.arange(100, dtype=np.int32), n_keys, mod)
    assert np.all(head % mod == 0)
    assert np.array_equal(align_keys(keys, n_keys, 0), keys)


# ---------------------------------------------------------------------------
# 5. controller: the reshard knob
# ---------------------------------------------------------------------------
CTL = ControllerConfig(window=4, sustain=2, cooldown=4, slack_widen=False,
                       reshard_imbalance=3.0, reshard_max_moves=8)


def _skew_record(i, hot_shard=0, n=4, total=800):
    x = [total // (n * 8)] * n
    x[hot_shard] = total
    return dict(i=i, x_shard=x,
                hot=[[hot_shard + n * j, total // (j + 2)]
                     for j in range(4)])


def _flat_record(i, n=4):
    return dict(i=i, x_shard=[100] * n, hot=[])


def test_decide_reshard_trigger_and_cooldown():
    ctl = PlanController(CTL, Plan("tstream", "auto", 8.0, 2), sharded=True,
                         snap_align=0, queue_cap=16, n_owners=4, n_slots=64)
    # flat window: no decision
    assert ctl.step(4, [_flat_record(i) for i in range(3)]) == []
    # sustained skew: reshard fires with old/new override lists
    ds = ctl.step(6, [_skew_record(i) for i in range(4)])
    assert [d["knob"] for d in ds] == ["reshard"]
    assert ds[0]["old"] == [] and ds[0]["new"]
    assert ctl.plan.owners == norm_owners(ds[0]["new"])
    assert ds[0]["reason"].startswith("imbalance-")
    # cooldown: the same skew does not re-fire inside `cooldown` intervals
    assert ctl.step(8, [_skew_record(i) for i in range(4, 8)]) == []
    # ... and after cooldown a *different* skew re-fires
    ds2 = ctl.step(12, [_skew_record(i, hot_shard=2) for i in range(8, 12)])
    assert [d["knob"] for d in ds2] == ["reshard"]
    assert ds2[0]["old"] == ds[0]["new"]


def test_decide_reshard_respects_gates():
    # knob closed: n_owners=0 (engine not reshardable)
    ctl = PlanController(CTL, Plan("tstream", "auto", 8.0, 2), sharded=True,
                         snap_align=0, queue_cap=16)
    assert ctl.step(6, [_skew_record(i) for i in range(4)]) == []
    # knob closed: threshold disabled
    ctl = PlanController(
        ControllerConfig(window=4, sustain=2, slack_widen=False),
        Plan("tstream", "auto", 8.0, 2), sharded=True,
        snap_align=0, queue_cap=16, n_owners=4, n_slots=64)
    assert ctl.step(6, [_skew_record(i) for i in range(4)]) == []
    # not sustained: one skewed record among flat ones
    ctl = PlanController(CTL, Plan("tstream", "auto", 8.0, 2), sharded=True,
                         snap_align=0, queue_cap=16, n_owners=4, n_slots=64)
    assert ctl.step(6, [_flat_record(0), _flat_record(1),
                        _skew_record(2)]) == []


def test_reshard_trace_replays():
    ctl = PlanController(CTL, Plan("tstream", "auto", 8.0, 2), sharded=True,
                         snap_align=0, queue_cap=16, n_owners=4, n_slots=64)
    ctl.step(6, [_skew_record(i) for i in range(4)])
    assert ctl.trace
    folded = replay_plan(ctl.init_plan, ctl.trace)
    assert folded == ctl.plan and folded.owners == ctl.plan.owners
    # restore on a fresh controller reaches the same plan
    ctl2 = PlanController(CTL, Plan("tstream", "auto", 8.0, 2), sharded=True,
                          snap_align=0, queue_cap=16, n_owners=4, n_slots=64)
    ctl2.restore([dict(d) for d in ctl.trace],
                 plan_check=ctl.plan.as_dict())
    assert ctl2.plan == ctl.plan


def test_plan_owners_serialization():
    assert norm_owners([[3, 1], [0, 2]]) == ((0, 2), (3, 1))
    p = Plan("tstream", "auto", 8.0, 2, owners=norm_owners(((3, 1), (0, 2))))
    d = p.as_dict()
    assert d["owners"] == [[0, 2], [3, 1]]          # normalized (sorted)
    assert Plan.from_dict(d) == p
    # pre-elastic manifests have no "owners" key: default to striping
    legacy = dict(scheme="tstream", rung="auto", slack=8.0, chunk=2)
    assert Plan.from_dict(legacy).owners == ()
    assert json.loads(json.dumps(d)) == d           # JSON-safe


# ---------------------------------------------------------------------------
# 6. single-device service: elastic config composes with crash -> replay
#    (the reshard knob stays closed off the sharded driver)
# ---------------------------------------------------------------------------


def test_single_device_elastic_config_crash_replay(tmp_path):
    import jax.numpy as jnp  # noqa: F401  (engine import below needs jax)
    from repro.apps import ALL_APPS
    from repro.core.intervals import PhasedReplaySource, WatermarkPolicy
    from repro.core.scheduler import DualModeEngine, EngineConfig
    from repro.runtime.service import ServiceConfig, StreamService

    app = ALL_APPS["gs"]
    store = app.make_store()
    interval = 32

    def mk_source():
        return PhasedReplaySource(
            app.gen_events,
            [(4 * interval, {}),
             (4 * interval, dict(theta=2.5, align_mod=8))],
            seed=3, arrival_batch=23, jitter=4)

    def mk_cfg(**kw):
        return ServiceConfig(punct_interval=interval, chunk_intervals=2,
                             watermark=WatermarkPolicy(allowed_lateness=4),
                             controller=CTL, **kw)

    eng = DualModeEngine(app, store, EngineConfig())
    ref = StreamService(eng, mk_cfg()).run(mk_source())
    assert ref.migrations == [] and "placement" not in ref.stats
    assert all(d["knob"] != "reshard" for d in ref.decisions)

    cfg = mk_cfg(snapshot_every=4, ckpt_dir=str(tmp_path))
    svc = StreamService(eng, cfg)
    with pytest.raises(RuntimeError):
        svc.run(mk_source(), crash_after_interval=5)
    rec = StreamService(eng, cfg).resume(mk_source())
    np.testing.assert_array_equal(rec.final_values, ref.final_values)
    snap = rec.stats["replayed"] // interval
    for a, b in zip(rec.outputs, ref.outputs[snap:]):
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))


# ---------------------------------------------------------------------------
# 7. engine layer: subprocess on 8 forced host devices
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def worker_verdicts():
    worker = os.path.join(os.path.dirname(__file__), "reshard_worker.py")
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("case", [
    "gs/tstream/migrate", "sl/tstream/migrate", "ob/tstream/migrate",
    "tp/tstream/migrate", "gs/mvlk/migrate", "ob/mvlk/migrate",
    "gs/tstream/crash",
])
def test_elastic_reshard_sharded(worker_verdicts, case):
    v = worker_verdicts[case]
    assert v["ok"], f"{case}: {v.get('why')}"
    if case.endswith("/migrate"):
        assert v["migrations"] >= 1 and v["moved"] > 0
