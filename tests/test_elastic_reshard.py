"""Elastic restart: a checkpoint written under one mesh restores onto a
different mesh (reshard), bitwise-equal values.  Runs in a subprocess with
8 placeholder devices (pytest itself stays on the real single device)."""
import os
import subprocess
import sys

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import save_checkpoint, load_checkpoint

tree = dict(
    w=jnp.arange(float(16 * 8)).reshape(16, 8),
    moe=dict(e=jnp.arange(float(8 * 4 * 2)).reshape(8, 4, 2)),
)
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
place_a = dict(
    w=jax.device_put(tree["w"], NamedSharding(mesh_a, P("data", "model"))),
    moe=dict(e=jax.device_put(tree["moe"]["e"],
                              NamedSharding(mesh_a, P(("data", "model"),
                                                      None, None)))),
)
save_checkpoint("/tmp/elastic_ckpt", 1, place_a)

# "failure": restore onto a different topology (4x2) and a shrunken (1x8)
for shape, axes in [((4, 2), ("data", "model")), ((1, 8), ("data", "model"))]:
    mesh_b = jax.make_mesh(shape, axes)
    shardings = dict(
        w=NamedSharding(mesh_b, P("data", "model")),
        moe=dict(e=NamedSharding(mesh_b, P(("data", "model"), None, None))),
    )
    restored = load_checkpoint("/tmp/elastic_ckpt", 1,
                               jax.eval_shape(lambda: tree), shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(restored["moe"]["e"]),
                                  np.asarray(tree["moe"]["e"]))
    assert restored["w"].sharding.mesh.shape == dict(zip(axes, shape))
print("ELASTIC_OK")
"""


def test_reshard_across_meshes(tmp_path):
    script = tmp_path / "elastic_worker.py"
    script.write_text(WORKER)
    proc = subprocess.run([sys.executable, str(script)], cwd=os.getcwd(),
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ELASTIC_OK" in proc.stdout
