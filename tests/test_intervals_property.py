"""Hypothesis sweep: watermarked interval assembly (DESIGN.md §2.6).

Random arrival jitter, duplicate timestamps, bursty arrival batch sizes
and both late policies, against three invariants:

* **conservation** — every arrived row is emitted exactly once, counted
  dropped, or still pending; no row is duplicated or lost;
* **watermark monotonicity** — the per-interval watermark sequence never
  decreases (and the live watermark tracks max(event_time) - lateness);
* **bit-identity** — when jitter stays within the lateness window the
  assembler reproduces the exact in-order stream, and the K-chunked
  engine over that assembly equals the monolithic ``run_stream`` bitwise
  (the engine-level pin, on a tiny GS instance).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.intervals import (IntervalAssembler, ReplaySource,
                                  WatermarkPolicy)


def _arrival_stream(rng, n, jitter, dupes):
    """(payload ids, event times) in a jitter-bounded arrival order."""
    t = np.arange(n, dtype=np.int64)
    if dupes:
        t = t // 3  # duplicate timestamps (bursts at one event time)
    order = (np.argsort(t + rng.uniform(0.0, float(jitter), n),
                        kind="stable") if jitter else np.arange(n))
    return np.arange(n, dtype=np.int64)[order], t[order]


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 300),
       interval=st.integers(1, 16), jitter=st.integers(0, 40),
       lateness=st.integers(0, 40), dupes=st.booleans(),
       late=st.sampled_from(["reroute", "drop"]))
def test_conservation_and_watermark_monotonic(seed, n, interval, jitter,
                                              lateness, dupes, late):
    rng = np.random.default_rng(seed)
    ids, times = _arrival_stream(rng, n, jitter, dupes)
    asm = IntervalAssembler(interval, WatermarkPolicy(
        allowed_lateness=lateness, late=late))
    emitted_ids, emitted_seqs = [], []

    def drain():
        for ev, info in asm.pop_ready():
            emitted_ids.append(ev["id"])
            emitted_seqs.append(info.seq)
            assert ev["id"].shape == (interval,)

    # bursty arrival batches: random split points, pops interleaved
    cuts = np.sort(rng.integers(0, n + 1, rng.integers(0, 8)))
    for lo, hi in zip(np.r_[0, cuts], np.r_[cuts, n]):
        if hi > lo:
            asm.push(dict(id=ids[lo:hi]), times[lo:hi])
            if rng.random() < 0.5:
                drain()
    asm.close()
    drain()

    # conservation: emitted exactly once + dropped + pending == arrived
    assert asm.conservation_ok()
    got = (np.concatenate(emitted_ids) if emitted_ids
           else np.zeros((0,), np.int64))
    assert got.size == asm.assembled
    assert np.unique(got).size == got.size, "a row was emitted twice"
    assert asm.arrived == n
    assert asm.assembled + asm.watermark_dropped + asm.pending == n
    assert asm.pending < interval  # close() seals everything emittable
    if late == "reroute":
        assert asm.watermark_dropped == 0
    # arrival sequences are globally unique across intervals too
    if emitted_seqs:
        seqs = np.concatenate(emitted_seqs)
        assert np.unique(seqs).size == seqs.size

    # watermark monotonicity
    wms = np.asarray(asm.watermarks)
    assert np.all(np.diff(wms) >= 0)
    assert asm.watermark == int(times.max()) - lateness


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 300),
       interval=st.integers(1, 16), jitter=st.integers(0, 20),
       slack=st.integers(0, 10), batch=st.integers(1, 64))
def test_in_window_jitter_reassembles_exact_order(seed, n, interval, jitter,
                                                  slack, batch):
    """jitter <= allowed_lateness + unique times => the emitted stream is
    the exact in-order stream (no drops, no reroutes) — the assembly-level
    foundation of the service's chunked-vs-monolithic bit-identity."""
    rng = np.random.default_rng(seed)
    ids, times = _arrival_stream(rng, n, jitter, dupes=False)
    asm = IntervalAssembler(interval, WatermarkPolicy(
        allowed_lateness=jitter + slack))
    out = []
    for lo in range(0, n, batch):
        asm.push(dict(id=ids[lo : lo + batch]), times[lo : lo + batch])
        out.extend(ev["id"] for ev, _ in asm.pop_ready())
    asm.close()
    out.extend(ev["id"] for ev, _ in asm.pop_ready())
    assert asm.watermark_dropped == 0 and asm.late_rerouted == 0
    got = np.concatenate(out) if out else np.zeros((0,), np.int64)
    k = n // interval
    np.testing.assert_array_equal(got, np.arange(k * interval))


# ---------------------------------------------------------------------------
# engine-level chunked-vs-monolithic bit-identity under random arrivals
# ---------------------------------------------------------------------------
_ENGINE_CACHE = {}


def _tiny_gs():
    if "eng" not in _ENGINE_CACHE:
        from repro.apps import ALL_APPS
        from repro.core.scheduler import DualModeEngine, EngineConfig
        app = ALL_APPS["gs"]
        store = app.make_store()
        _ENGINE_CACHE["app"] = app
        _ENGINE_CACHE["store"] = store
        _ENGINE_CACHE["eng"] = DualModeEngine(app, store, EngineConfig())
        _ENGINE_CACHE["refs"] = {}
    return (_ENGINE_CACHE["app"], _ENGINE_CACHE["store"],
            _ENGINE_CACHE["eng"], _ENGINE_CACHE["refs"])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), chunk=st.integers(1, 3),
       jitter=st.integers(0, 6), batch=st.sampled_from([7, 16, 48]))
def test_chunked_engine_matches_monolithic_property(seed, chunk, jitter,
                                                    batch):
    from repro.core.scheduler import DualModeEngine  # noqa: F401 (cache)
    from repro.runtime.service import ServiceConfig, StreamService
    app, store, eng, refs = _tiny_gs()
    src = ReplaySource(app.gen_events, 48, seed=seed, arrival_batch=batch,
                       jitter=jitter)
    if seed not in refs:  # one monolithic reference per event set
        refs[seed] = eng.run_stream(store.values, src.in_order_events, 8,
                                    fused=True)
    outs_ref, vals_ref = refs[seed]
    rec = StreamService(eng, ServiceConfig(
        punct_interval=8, chunk_intervals=chunk,
        watermark=WatermarkPolicy(allowed_lateness=jitter))).run(src)
    np.testing.assert_array_equal(rec.final_values, np.asarray(vals_ref))
    assert len(rec.outputs) == len(outs_ref)
    for a, b in zip(rec.outputs, outs_ref):
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
