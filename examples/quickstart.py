"""Quickstart: concurrent stateful stream processing in 40 lines.

Defines a tiny word-count-style application over shared state, runs it
through TStream's dual-mode engine, and checks the result against the
sequential oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import AppSpec, DualModeEngine, EngineConfig, make_store
from repro.core.types import ASSOC_FUNS

N_KEYS = 100


def make_app():
    def state_access(blt, eb):
        # one transaction: bump the key's counter, read it back
        blt.read_modify(0, eb["key"], eb["amount"], "add")
        blt.read(0, eb["key"])

    return AppSpec(
        name="counter", funs=ASSOC_FUNS, max_ops=2, width=1,
        make_store=lambda **_: make_store([N_KEYS], 1),
        gen_events=lambda rng, n: dict(
            key=rng.integers(0, N_KEYS, n).astype(np.int32),
            amount=rng.uniform(0, 10, n).astype(np.float32)),
        pre_process=lambda ev: ev,
        state_access=state_access,
        post_process=lambda eb, res: dict(count_after=res.pre[1, 0]),
    )


def main():
    app = make_app()
    store = app.make_store()
    rng = np.random.default_rng(0)
    stream = app.gen_events(rng, 256)

    engine = DualModeEngine(app, store, EngineConfig(scheme="tstream"))
    outs, values = engine.run_stream(store.values, stream,
                                     punct_interval=64)

    oracle = DualModeEngine(app, store, EngineConfig(scheme="lock"))
    outs_o, values_o = oracle.run_stream(store.values, stream,
                                         punct_interval=64)
    np.testing.assert_allclose(np.asarray(values), np.asarray(values_o),
                               rtol=1e-5)
    total = float(np.asarray(values)[:N_KEYS].sum())
    print(f"quickstart OK — {len(outs)} punctuation intervals, "
          f"total count {total:.1f}, matches oracle ✓")


if __name__ == "__main__":
    main()
