"""Continuous streaming service on the GS app (DESIGN.md §2.6).

Runs the GS workload through ``StreamService``: an out-of-order
replayable source, watermarked interval assembly, double-buffered chunked
execution over the fused driver, punctuation-aligned snapshots, and —
with ``--inject-restart`` — a crash/restore/replay drill that asserts the
recovered run is bitwise identical to the uninterrupted one.

With ``--corrupt-latest`` on top, the newest snapshot is damaged on disk
after the crash (torn-write simulation): ``resume`` must fall back to the
previous *valid* snapshot — never leak an exception — and still
reproduce the uninterrupted run bitwise (DESIGN.md §2.7).

With ``--storm`` the source becomes a deterministic multi-phase workload
storm (calm -> hot-key skew -> multi-partition burst -> calm) and the
adaptive control plane (DESIGN.md §2.9) is switched on: the controller
degrades tstream -> lock under the sustained conflict storm and probes
back (single-device), or ramps the exchange slack from a starved start
(sharded).  ``--trace-out`` writes the decision trace as JSONL; with
``--inject-restart`` the drill additionally asserts the recovered run's
decision trace equals the uninterrupted one.

    PYTHONPATH=src python examples/streaming_service.py
    PYTHONPATH=src python examples/streaming_service.py --inject-restart
    PYTHONPATH=src python examples/streaming_service.py --inject-restart \
        --corrupt-latest        # recovery past a corrupted latest snapshot
    PYTHONPATH=src python examples/streaming_service.py --devices 8 \
        --inject-restart        # sharded service on 8 forced host devices
    PYTHONPATH=src python examples/streaming_service.py --storm \
        --inject-restart --trace-out trace.jsonl   # adaptive storm drill
"""
import argparse
import json
import os
import sys
import tempfile

ap = argparse.ArgumentParser()
ap.add_argument("--intervals", type=int, default=12,
                help="punctuation intervals to run")
ap.add_argument("--interval", type=int, default=64, help="events/interval")
ap.add_argument("--chunk", type=int, default=2, help="intervals per dispatch")
ap.add_argument("--jitter", type=int, default=8,
                help="arrival jitter (<= watermark lateness)")
ap.add_argument("--inject-restart", action="store_true",
                help="crash mid-run, restore the snapshot, assert bitwise "
                     "recovery")
ap.add_argument("--corrupt-latest", action="store_true",
                help="with --inject-restart: corrupt the newest snapshot "
                     "before resuming — recovery must fall back to the "
                     "previous valid one")
ap.add_argument("--devices", type=int, default=0,
                help="force N host devices and run the sharded driver")
ap.add_argument("--storm", action="store_true",
                help="multi-phase workload storm + adaptive control plane")
ap.add_argument("--trace-out", default="",
                help="write the controller decision trace as JSONL")
ap.add_argument("--perfetto-out", default="",
                help="write a Chrome-trace/Perfetto span trace of the "
                     "pipeline (DESIGN.md §2.11) + a sibling "
                     "<path>.telemetry.json registry snapshot; the trace "
                     "is schema-validated after the run")
ap.add_argument("--profile-dir", default="",
                help="with --perfetto-out: jax.profiler per-chunk windows "
                     "into this directory")
ap.add_argument("--hlo-cost", action="store_true",
                help="with --perfetto-out: annotate execute spans with "
                     "compiled-HLO flops/bytes + roofline fractions")
args = ap.parse_args()
if args.devices:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}")

import jax                      # noqa: E402  (after XLA_FLAGS)
import numpy as np              # noqa: E402

from repro.apps import ALL_APPS                                # noqa: E402
from repro.core.intervals import (PhasedReplaySource, ReplaySource,
                                  WatermarkPolicy)              # noqa: E402
from repro.core.scheduler import DualModeEngine, EngineConfig   # noqa: E402
from repro.runtime.controller import ControllerConfig           # noqa: E402
from repro.runtime.faults import corrupt_snapshot               # noqa: E402
from repro.runtime.service import ServiceConfig, StreamService  # noqa: E402
from repro.runtime.telemetry import (PIPELINE_STAGES, TelemetryConfig,
                                     stage_summary,
                                     validate_trace)            # noqa: E402


def outputs_identical(a_list, b_list):
    return len(a_list) == len(b_list) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
        for a, b in zip(a_list, b_list) for k in a)


def main():
    app = ALL_APPS["gs"]
    store = app.make_store()
    iv = args.interval
    controller = None
    if args.storm:
        # calm -> hot-key skew storm -> multi-partition burst -> calm; at
        # least 4 intervals per phase so sustained triggers can fire
        per = max(4, args.intervals // 4) * iv
        mk = lambda: PhasedReplaySource(app.gen_events, [
            (per, dict(theta=0.2)),
            (per, dict(theta=2.5)),
            (per, dict(theta=0.2, n_partitions=16, mp_ratio=0.9, mp_len=8)),
            (per, dict(theta=0.2)),
        ], seed=42, arrival_batch=2 * iv, jitter=args.jitter)
        n_events = 4 * per
        controller = ControllerConfig(
            window=2, sustain=2, cooldown=2,
            degrade_scheme="lock", degrade_chain_frac=0.6,
            slack_widen=True, slack_factor=2.0, fill_widen=0.9)
    else:
        n_events = iv * args.intervals
        mk = lambda: ReplaySource(app.gen_events, n_events, seed=42,
                                  arrival_batch=max(1, iv // 4),
                                  jitter=args.jitter)
    mesh = (jax.make_mesh((args.devices,), ("dev",)) if args.devices
            else None)
    # storm: start the sharded exchange starved (slack 1.5) so the
    # controller's widening decisions actually have work to do
    eng = DualModeEngine(app, store, EngineConfig(scheme="tstream"),
                         mesh=mesh,
                         exchange_slack=1.5 if args.storm else 8.0)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        cfg = ServiceConfig(
            punct_interval=iv, chunk_intervals=args.chunk,
            snapshot_every=2 * args.chunk, ckpt_dir=ckpt_dir,
            controller=controller,
            watermark=WatermarkPolicy(allowed_lateness=args.jitter))
        # uninterrupted reference: no snapshots (and none left behind for
        # the restart drill to accidentally resume from).  Tracing rides
        # on the *reference* run, so the restart drill's bitwise assertion
        # doubles as the replay-safety proof: a traced run reproduces the
        # untraced recovery bit-for-bit (DESIGN.md §2.11).
        tcfg = None
        if args.perfetto_out:
            tcfg = TelemetryConfig(trace_path=args.perfetto_out,
                                   profile_dir=args.profile_dir,
                                   hlo_attribution=args.hlo_cost)
        ref_cfg = ServiceConfig(
            punct_interval=iv, chunk_intervals=args.chunk,
            controller=controller, telemetry=tcfg,
            watermark=WatermarkPolicy(allowed_lateness=args.jitter))
        ref = StreamService(eng, ref_cfg).run(mk())
        if args.perfetto_out:
            snap_path = args.perfetto_out + ".telemetry.json"
            ref.telemetry.dump(snap_path)
            want = [s for s in PIPELINE_STAGES if s != "snapshot.publish"]
            ok, why, info = validate_trace(args.perfetto_out,
                                           require_stages=want)
            assert ok, f"invalid Perfetto trace: {why}"
            print(f"  perfetto trace -> {args.perfetto_out} "
                  f"({info['n_events']} events, "
                  f"stages: {', '.join(sorted(info['stages']))})")
            print(f"  telemetry snapshot -> {snap_path}")
            for r in stage_summary(args.perfetto_out):
                print(f"    {r['stage']:<16s} x{r['count']:<4d} "
                      f"mean {r['mean_ms']:8.3f} ms   "
                      f"p99 {r['p99_ms']:8.3f} ms")
        pct = ref.latency_percentiles((50, 99))
        print(f"service: {len(ref.outputs)} intervals × {iv} "
              f"events on {args.devices or 1} device(s)")
        print(f"  latency p50 {pct['p50'] * 1e3:.2f} ms   "
              f"p99 {pct['p99'] * 1e3:.2f} ms   "
              f"sustained {ref.sustained_events_per_s():,.0f} ev/s")
        print(f"  stats: {ref.stats}")
        if args.storm:
            for d in ref.decisions:
                print(f"  decision @g={d['g']:>3} {d['knob']}: "
                      f"{d['old']} -> {d['new']} ({d['reason']})")
            assert ref.decisions, \
                "storm drill made no adaptive decisions — no storm?"
            if args.devices:
                assert any(d["knob"] == "slack" for d in ref.decisions)
            else:
                schemes = [(d["old"], d["new"]) for d in ref.decisions
                           if d["knob"] == "scheme"]
                assert ("tstream", "lock") in schemes, \
                    "storm never degraded the scheme"
                assert ("lock", "tstream") in schemes, \
                    "controller never probed back after the storm"
        if args.trace_out:
            with open(args.trace_out, "w") as f:
                for d in ref.decisions:
                    f.write(json.dumps(d) + "\n")
            print(f"  decision trace -> {args.trace_out} "
                  f"({len(ref.decisions)} decisions)")

        if not args.inject_restart:
            print("streaming service demo OK ✓")
            return

        if args.storm and args.devices:
            # the ref run's slack escalations mutated the shared engine:
            # reset the exchange to the storm's starved starting point so
            # the restart drill begins from the same initial plan
            eng._sharded.set_exchange_slack(1.5)
        crash_at = 2 * len(ref.outputs) // 3
        svc = StreamService(eng, cfg)
        try:
            svc.run(mk(), crash_after_interval=crash_at)
            sys.exit("injected crash did not fire")
        except RuntimeError as e:
            print(f"  {e} (snapshots at {svc.last_run.snapshots})")
        newest = svc.last_run.snapshots[-1]
        if args.corrupt_latest:
            assert len(svc.last_run.snapshots) >= 2, \
                "corrupt-latest drill needs a fallback snapshot"
            what = corrupt_snapshot(
                os.path.join(ckpt_dir, f"step_{newest:08d}"),
                "truncate_leaf")
            print(f"  corrupted snapshot @{newest}: {what}")
        rec = StreamService(eng, cfg).resume(mk())
        if args.storm:
            assert rec.decisions == ref.decisions, \
                (f"replayed decision trace differs:\n  {rec.decisions}\n  "
                 f"!= {ref.decisions}")
            print(f"  replayed decision trace matches "
                  f"({len(rec.decisions)} decisions) ✓")
        snap = rec.stats["replayed"] // iv
        if args.corrupt_latest:
            assert snap < newest, \
                "resume used the corrupted snapshot instead of falling back"
            print(f"  resume fell back past corrupted @{newest} "
                  f"to valid @{snap} ✓")
        print(f"  restored snapshot @{snap}, replayed "
              f"{rec.stats['replayed']} events, re-executed "
              f"{len(rec.outputs)} intervals")
        assert np.array_equal(rec.final_values, ref.final_values), \
            "final state differs after recovery"
        assert outputs_identical(rec.outputs, ref.outputs[snap:]), \
            "post-resume outputs differ"
        assert outputs_identical(svc.last_run.outputs,
                                 ref.outputs[: len(svc.last_run.outputs)]), \
            "pre-crash outputs differ"
        print("recovery bit-identity OK ✓ (crash → restore → replay "
              "reproduced the uninterrupted run bitwise)")


if __name__ == "__main__":
    main()
