"""Toll Processing end-to-end (the paper's motivating application, Fig 2b).

Streams Linear-Road position reports through the fused RS/VC/TN operator
with concurrent shared state, comparing all consistency-preserving engines.

    PYTHONPATH=src python examples/toll_processing.py
"""
import time

import numpy as np

from repro.apps import TP
from repro.core import DualModeEngine, EngineConfig


def main():
    rng = np.random.default_rng(42)
    stream = TP.gen_events(rng, 2000)
    store = TP.make_store()

    results = {}
    for scheme in ["tstream", "lock", "pat"]:
        eng = DualModeEngine(TP, store, EngineConfig(scheme=scheme))
        t0 = time.time()
        outs, values = eng.run_stream(store.values, stream,
                                      punct_interval=500)
        dt = time.time() - t0
        tolls = np.concatenate([np.asarray(o["toll"]) for o in outs])
        results[scheme] = (values, tolls, dt)
        print(f"[tp] {scheme:8s}: {len(tolls)} tolls in {dt:.2f}s, "
              f"mean toll {tolls.mean():.3f}, "
              f"congested events {(tolls > 0).sum()}")

    v_t, tolls_t, _ = results["tstream"]
    v_l, tolls_l, _ = results["lock"]
    np.testing.assert_allclose(np.asarray(v_t), np.asarray(v_l), rtol=1e-4)
    np.testing.assert_allclose(tolls_t, tolls_l, rtol=1e-4)
    print("[tp] all schemes agree with the sequential oracle ✓")


if __name__ == "__main__":
    main()
