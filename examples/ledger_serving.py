"""Streaming Ledger under load: concurrent transfers with aborts.

Demonstrates §IV-C2 abort handling (rejected transfers leave no partial
effects) and conservation of money under the dual-mode engine.

    PYTHONPATH=src python examples/ledger_serving.py
"""
import numpy as np

from repro.apps import SL
from repro.core import DualModeEngine, EngineConfig


def main():
    rng = np.random.default_rng(7)
    stream = SL.gen_events(rng, 3000)
    store = SL.make_store()
    before = float(np.asarray(store.values).sum())

    eng = DualModeEngine(SL, store, EngineConfig(scheme="tstream",
                                                 abort_repass=True))
    outs, values = eng.run_stream(store.values, stream, punct_interval=500)

    rejected = np.concatenate([np.asarray(o["rejected"]) for o in outs])
    after = float(np.asarray(values).sum())
    deposits = stream["amount"][~stream["is_transfer"]][: len(rejected)]
    n_proc = (len(rejected) // 500) * 500
    dep_amt = stream["amount"][:n_proc][~stream["is_transfer"][:n_proc]]
    print(f"[sl] processed {n_proc} events, "
          f"{int(rejected.sum())} transfers rejected (insufficient funds)")
    print(f"[sl] ledger total {before:.1f} -> {after:.1f} "
          f"(deposited {2 * dep_amt.sum():.1f})")
    np.testing.assert_allclose(after - before, 2 * dep_amt.sum(), rtol=1e-3)
    print("[sl] conservation holds: committed transfers moved, "
          "rejected ones left no partial effects ✓")


if __name__ == "__main__":
    main()
