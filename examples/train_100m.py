"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
through the full stack (TStream data pipeline, AdamW+WSD, checkpointing,
crash-resume).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import dataclasses
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import PipelineConfig, StreamingPipeline
from repro.models import init_params, loss_fn
from repro.optim import AdamWConfig, adamw_init, adamw_update, wsd_schedule
from repro.runtime import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="results/ckpt_100m")
    args = ap.parse_args()

    # ~100M params: 8 layers, d=768, ffn 3072, vocab 32k
    base = get_arch("minicpm-2b")
    cfg = dataclasses.replace(
        base, name="dense-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=12, head_dim=64, d_ff=3072, vocab=32_000,
        residual_scale=1.0)
    n = cfg.param_count()
    print(f"[100m] {cfg.name}: {n/1e6:.1f}M params")

    pipe = StreamingPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=256,
                                            batch=8))
    # keep the stream-side statistics engine hot during training
    ingest_rng = np.random.default_rng(1)
    pipe.ingest(ingest_rng, 256)
    print(f"[100m] mixture weights from TStream stats engine: "
          f"{np.round(pipe.mixture_weights()[:4], 4)} ...")

    opt_cfg = AdamWConfig(lr=3e-4, state_dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    opt_state = adamw_init(params, opt_cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat="none"))(params)
        lr = wsd_schedule(opt_state["step"], warmup=20,
                          stable=args.steps - 80, decay=60)
        p2, s2 = adamw_update(params, grads, opt_state, opt_cfg, lr)
        return p2, s2, loss

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    loop = TrainLoop(
        TrainLoopConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100,
                        max_steps=args.steps),
        jax.jit(train_step, donate_argnums=(0, 1)),
        lambda step, rng: pipe.batch_for_step(step),
        params, opt_state)

    t0 = time.time()
    loop.run()
    dt = time.time() - t0
    first = np.mean(loop.losses[:10])
    last = np.mean(loop.losses[-10:])
    tok_s = args.steps * 8 * 256 / dt
    print(f"[100m] {args.steps} steps in {dt/60:.1f} min "
          f"({tok_s:.0f} tok/s host)")
    print(f"[100m] loss {first:.3f} -> {last:.3f}")
    assert last < first - 0.5, "loss must fall substantially"
    print("[100m] training learns ✓ (checkpoints in " + args.ckpt_dir + ")")


if __name__ == "__main__":
    main()
